"""Module-level experiment functions for the execution-backend tests.

Process backends may run under the ``spawn`` start method (and the queue
worker is a separate interpreter entirely), so everything a child needs to
import lives here, free of pytest/hypothesis dependencies — the
``_store_workers`` pattern.
"""

import os
import sys

# Children must resolve `repro` even when launched without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:  # pragma: no cover - depends on launcher env
    sys.path.insert(0, _SRC)

from repro.core import (ActionSpace, DiscoverySpace, Dimension,
                        FunctionExperiment, MeasurementError, ProbabilitySpace,
                        SampleStore)

POISON_X = 2  # the configuration coordinate that triggers hostile behavior


def grid_fn(c):
    return {"m": c["x"] * 10.0 + c["y"]}


def exit_fn(c):
    """A hostile experiment: hard-kills its process mid-measurement (the
    no-cleanup analogue of a segfault) for the poison configuration."""
    if c["x"] == POISON_X:
        os._exit(42)
    return {"m": float(c["x"])}


def raise_fn(c):
    """An experiment bug: raises a non-MeasurementError for the poison
    configuration."""
    if c["x"] == POISON_X:
        raise RuntimeError("experiment bug: wild pointer")
    return {"m": float(c["x"])}


def flaky_fn(c):
    """A non-deployable configuration: raises MeasurementError."""
    if c["x"] == POISON_X:
        raise MeasurementError("insufficient quota")
    return {"m": float(c["x"])}


def line_space(n=4):
    return ProbabilitySpace.make([Dimension.discrete("x", list(range(n)))])


def make_line_ds(fn, store):
    return DiscoverySpace(
        space=line_space(),
        actions=ActionSpace.make([FunctionExperiment(
            fn=fn, properties=("m",), name="line")]),
        store=store,
        claim_timeout_s=2.0,
    )


def build_queue_ds(store_path):
    """Worker factory (``--factory _execution_workers:build_queue_ds``):
    rebuild the same (Ω, A) from the store path — same space_id, one study."""
    space = ProbabilitySpace.make([
        Dimension.discrete("x", list(range(8))),
        Dimension.discrete("y", list(range(4))),
    ])
    exp = FunctionExperiment(fn=grid_fn, properties=("m",), name="grid")
    return DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                          store=SampleStore(store_path), claim_timeout_s=5.0)
