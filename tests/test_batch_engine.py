"""Tests for the batched ask/tell evaluation engine (paper §III-D).

Two guarantees matter:

* **serial equivalence** — the engine with ``workers=4`` produces the same
  reconciled sample set, sampling record, and trial sequence as ``workers=1``
  for a fixed seed (parallelism changes wall-clock, never results);
* **protocol fidelity** — each ported optimizer's ``ask``/``tell`` path at
  batch size 1 reproduces the classic one-step suggest/evaluate loop
  draw-for-draw (same rng stream, same trials).
"""

import numpy as np
import pytest

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, MeasurementError, ProbabilitySpace,
                        SampleStore)
from repro.core.entities import canonical_json
from repro.core.optimizers import OPTIMIZER_REGISTRY, run_optimizer
from repro.core.optimizers.base import SearchAdapter


def make_space(n=8):
    vals = [round(v, 3) for v in np.linspace(-2, 2, n)]
    return ProbabilitySpace.make([
        Dimension.discrete("x", vals),
        Dimension.discrete("y", vals),
        Dimension.categorical("mode", ["slow", "fast"]),
    ])


def make_ds(store=None, noise=0.0):
    def fn(c):
        penalty = 0.0 if c["mode"] == "fast" else 1.0
        return {"loss": (c["x"] - 0.5) ** 2 + (c["y"] + 0.5) ** 2 + penalty}
    exp = FunctionExperiment(fn=fn, properties=("loss",), name="quad")
    return DiscoverySpace(space=make_space(), actions=ActionSpace.make([exp]),
                          store=store or SampleStore(":memory:"))


def reconciled(ds):
    payload = sorted(
        (s.configuration.digest,
         sorted((v.name, v.value, v.experiment_id, v.predicted)
                for v in s.properties.values()))
        for s in ds.read()
    )
    return canonical_json(payload)


def trail(run):
    return [(t.configuration.digest, t.value, t.action) for t in run.trials]


# ------------------------------------------------------------ ask() contract


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
@pytest.mark.parametrize("n", [1, 4, 7])
def test_ask_proposes_distinct_unseen_batches(name, n):
    ds = make_ds()
    opt = OPTIMIZER_REGISTRY[name](seed=0)
    rng = np.random.default_rng(0)
    adapter = SearchAdapter(ds, "loss", "min", optimizer_name=opt.name)
    # warm the history so model-based optimizers leave their init phase
    warm = opt.ask(adapter, rng, n=5)
    adapter.evaluate_batch(warm)
    batch = opt.ask(adapter, rng, n=n)
    assert len(batch) == n
    digests = [c.digest for c in batch]
    assert len(set(digests)) == n, "batch must not contain duplicates"
    assert not set(digests) & adapter.seen_digests(), "batch must be unseen"


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_ask_exhausts_finite_space(name):
    space = ProbabilitySpace.make([Dimension.discrete("x", [1, 2, 3])])
    exp = FunctionExperiment(fn=lambda c: {"m": float(c["x"])},
                             properties=("m",), name="tiny")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]))
    opt = OPTIMIZER_REGISTRY[name](seed=0)
    run = run_optimizer(opt, ds, "m", "min", max_trials=50, patience=50,
                        batch_size=4)
    assert run.num_trials == 3  # ask returns a short batch, then []


# ------------------------------------- batch size 1 == classic one-step loop


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_batch1_reproduces_single_step_loop(name, max_trials=20):
    """run_optimizer(batch_size=1) must equal a hand-rolled suggest/evaluate
    loop with the same seed: same configurations, values, actions, and the
    same rng stream consumption throughout."""
    cls = OPTIMIZER_REGISTRY[name]

    # reference: classic serial loop via the suggest() wrapper
    ds_ref = make_ds()
    opt = cls(seed=0)
    rng = np.random.default_rng(42)
    adapter = SearchAdapter(ds_ref, "loss", "min", optimizer_name=opt.name)
    while len(adapter.trials) < max_trials:
        config = opt.suggest(adapter, rng)
        if config is None:
            break
        adapter.evaluate(config)
    ref = [(t.configuration.digest, t.value, t.action) for t in adapter.trials]

    # engine: batched ask/tell with batch_size=1, no early stop
    ds_new = make_ds()
    run = run_optimizer(cls(seed=0), ds_new, "loss", "min",
                        max_trials=max_trials, patience=max_trials + 1,
                        rng=np.random.default_rng(42), batch_size=1)
    assert trail(run) == ref
    assert reconciled(ds_ref) == reconciled(ds_new)


# --------------------------------------------- parallel == serial, same seed


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_parallel_workers_match_serial(name):
    """4 experiment workers vs 1, same seed and batch plan: identical trial
    sequence, sampling record, and reconciled sample set."""
    cls = OPTIMIZER_REGISTRY[name]

    def run_with(workers):
        ds = make_ds()
        run = run_optimizer(cls(seed=0), ds, "loss", "min", max_trials=24,
                            patience=25, rng=np.random.default_rng(7),
                            batch_size=6, workers=workers)
        records = [(r.seq, r.config_digest, r.action)
                   for r in ds.timeseries(run.operation_id)]
        return trail(run), records, reconciled(ds)

    t1, r1, s1 = run_with(1)
    t4, r4, s4 = run_with(4)
    assert t1 == t4
    assert r1 == r4
    assert s1 == s4  # byte-identical reconciled sample set


def test_sample_batch_duplicates_measure_once():
    ds = make_ds()
    c = Configuration.make({"x": -2.0, "y": 2.0, "mode": "fast"})
    results = ds.sample_batch([c, c, c], workers=3)
    assert [r.action for r in results] == ["measured", "reused", "reused"]
    assert ds.store.count_measured(ds.space_id) == 1


def test_sample_batch_failures_do_not_abort():
    def fn(c):
        if c["x"] > 1:
            raise MeasurementError("OOM")
        return {"m": float(c["x"])}

    space = ProbabilitySpace.make([Dimension.discrete("x", [0, 1, 2, 3])])
    ds = DiscoverySpace(space=space, actions=ActionSpace.make(
        [FunctionExperiment(fn=fn, properties=("m",), name="flaky")]))
    configs = [Configuration.make({"x": v}) for v in (0, 2, 1, 3)]
    results = ds.sample_batch(configs, workers=2)
    assert [r.action for r in results] == ["measured", "failed", "measured", "failed"]
    assert [r.ok for r in results] == [True, False, True, False]
    assert all(isinstance(r.error, MeasurementError) for r in results if not r.ok)
    assert ds.count_sampled() == 2  # failed points excluded from {x}
    # failed trials surface as value-None in the adapter
    adapter = SearchAdapter(ds, "m", "min")
    values = adapter.evaluate_batch(configs, workers=2)
    assert [v is None for v in values] == [False, True, False, True]
    assert [t.action for t in adapter.trials] == ["reused", "failed", "reused", "failed"]


def test_non_numeric_property_is_failed_not_crashed():
    """A non-float-coercible property value is the experiment's measurement
    going wrong, not an engine bug: it must surface as a structured
    ``failed`` trial (MeasurementError naming the configuration), never as
    a bare ValueError/TypeError crash that aborts the whole batch."""
    def fn(c):
        if c["x"] == 2:
            return {"m": "not-a-number"}
        return {"m": float(c["x"])}

    space = ProbabilitySpace.make([Dimension.discrete("x", [0, 1, 2, 3])])
    exp = FunctionExperiment(fn=fn, properties=("m",), name="buggy")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]))
    configs = [Configuration.make({"x": v}) for v in (0, 1, 2, 3)]
    results = ds.sample_batch(configs, operation_id="op", workers=2)
    assert [r.action for r in results] == \
        ["measured", "measured", "failed", "measured"]
    bad = results[2]
    assert isinstance(bad.error, MeasurementError)
    assert configs[2].digest in str(bad.error)  # names the culprit


def test_crashed_slot_keeps_other_records_and_releases_claim():
    """A non-MeasurementError in one slot (experiment bug) must not lose the
    other slots' sampling records, must release the crashed cell's claim so
    other investigators don't stall, and must re-raise."""
    def fn(c):
        if c["x"] == 2:
            raise ValueError("experiment bug")  # not a MeasurementError
        return {"m": float(c["x"])}

    space = ProbabilitySpace.make([Dimension.discrete("x", [0, 1, 2, 3])])
    exp = FunctionExperiment(fn=fn, properties=("m",), name="buggy")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]))
    configs = [Configuration.make({"x": v}) for v in (0, 1, 2, 3)]
    with pytest.raises(ValueError):
        ds.sample_batch(configs, operation_id="op", workers=2)
    # the three healthy slots' events landed despite the crash
    recs = [(r.config_digest, r.action) for r in ds.timeseries("op")]
    good = [c.digest for c in configs if c["x"] != 2]
    assert recs == [(d, "measured") for d in good]
    # the crashed cell's claim was released: nobody stalls on it
    assert not ds.store.claim_exists(configs[2].digest, exp.identifier)


def test_reuse_across_batched_runs():
    """Two batched runs over one store: the second fully reuses the first's
    measurements (paper Fig. 7 mechanism, now through the parallel path)."""
    store = SampleStore(":memory:")
    ds = make_ds(store)
    cls = OPTIMIZER_REGISTRY["random"]
    r1 = run_optimizer(cls(seed=0), ds, "loss", "min", max_trials=24,
                       patience=25, rng=np.random.default_rng(0),
                       batch_size=6, workers=4)
    assert r1.num_measured == r1.num_trials
    r2 = run_optimizer(cls(seed=1), ds, "loss", "min", max_trials=24,
                       patience=25, rng=np.random.default_rng(0),
                       batch_size=6, workers=4)
    assert r2.num_measured == 0  # same rng stream => full transparent reuse
    assert r2.normalized_cost == 0.0
