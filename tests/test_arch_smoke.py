"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train-gradient step + (for decoders) prefill/decode on CPU,
asserting shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.models import LMModel
from repro.models.blocks import ModelOptions
from repro.models.attention import AttnOptions
from repro.models.common import DTypePolicy

ARCH_IDS = sorted(ARCHITECTURES)

# Families kept in the default (fast) tier-1 run: the two cheapest
# representatives spanning the recurrent and attention block types.  The
# rest compile for tens of seconds each on CPU and run under `-m slow`
# (and in the CI slow-suite step) instead; see the tier-1 runtime budget
# note in pyproject.toml.
FAST_ARCHS = {"xlstm-125m", "chatglm3-6b"}
ARCH_PARAMS = [a if a in FAST_ARCHS
               else pytest.param(a, marks=pytest.mark.slow)
               for a in ARCH_IDS]

B, S = 2, 32

SMOKE_OPTIONS = ModelOptions(
    attn=AttnOptions(impl="xla", q_chunk=16, kv_chunk=16),
    policy=DTypePolicy(param_dtype=jnp.float32, compute_dtype=jnp.float32),
    remat="none",
)


def make_batch(cfg, batch=B, seq=S, seed=0):
    rng = np.random.default_rng(seed)
    out = {"labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)))}
    if cfg.uses_tokens:
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)))
    else:
        out["embeds"] = jnp.asarray(
            rng.normal(size=(batch, seq, cfg.frontend_dim)), jnp.float32)
    return out


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = get_config(arch_id, smoke=True)
            model = LMModel(cfg, SMOKE_OPTIONS)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch_id] = (model, params)
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_forward_shapes_and_finite(models, arch_id):
    model, params = models(arch_id)
    batch = make_batch(model.cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, model.cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_train_gradient_step(models, arch_id):
    model, params = models(arch_id)
    batch = make_batch(model.cfg)

    @jax.jit
    def step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        new_params = jax.tree.map(lambda p, g: p - 2e-4 * g, params, grads)
        return loss, metrics, new_params

    loss, metrics, new_params = step(params, batch)
    assert jnp.isfinite(loss)
    assert loss > 0  # CE against random labels
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(new_params)))
    assert jnp.isfinite(gnorm)
    # loss decreases after one SGD step on the same batch (sanity)
    loss2, _, _ = step(new_params, batch)
    assert loss2 < loss + 1e-3


@pytest.mark.parametrize("arch_id", ARCH_PARAMS)
def test_prefill_decode_consistency(models, arch_id):
    """Prefill + decode of token S must match full forward at position S.

    MoE archs use drop-free capacity here: capacity token-dropping is batch-
    size dependent by design, so consistency is only defined without drops."""
    from dataclasses import replace
    from repro.models.moe import MoEOptions

    model, params = models(arch_id)
    cfg = model.cfg
    if cfg.is_encoder_only:
        pytest.skip("encoder-only: no decode path")
    if cfg.num_experts:
        opts = replace(SMOKE_OPTIONS,
                       moe=MoEOptions(capacity_factor=50.0, min_capacity=128))
        model = LMModel(cfg, opts)
    capacity = S + 4
    batch = make_batch(cfg)
    logits_last, caches = jax.jit(
        lambda p, b: model.prefill(p, b, capacity))(params, batch)
    assert logits_last.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(logits_last).all()

    # extend the sequence by one token; decode must equal full forward
    batch2 = make_batch(cfg, seq=S + 1, seed=0)
    if cfg.uses_tokens:
        batch2["tokens"] = jnp.concatenate(
            [batch["tokens"], batch2["tokens"][:, -1:]], axis=1)
        step_input = {"tokens": batch2["tokens"][:, -1:]}
    else:
        batch2["embeds"] = jnp.concatenate(
            [batch["embeds"], batch2["embeds"][:, -1:]], axis=1)
        step_input = {"embeds": batch2["embeds"][:, -1:]}

    logits_dec, _ = jax.jit(
        lambda p, b, c: model.decode_step(p, b, c, S))(params, step_input, caches)
    logits_full, _ = jax.jit(model.forward)(params, batch2)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_structure(arch_id):
    """The FULL configs are structurally valid (layer math checks out) —
    they are only lowered via the dry-run, never allocated here."""
    cfg = get_config(arch_id)
    assert sum(s.num_layers for s in cfg.stages) == cfg.num_layers
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()
    model = LMModel(cfg)
    defs = model.param_defs()
    specs = model.logical_specs()
    assert set(defs) == set(specs)
