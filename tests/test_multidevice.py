"""Multi-device integration tests (subprocess with 8 placeholder devices):
pipeline parallelism, compressed cross-pod gradient sync, elastic-mesh
checkpoint restore.  Each runs in its own process because jax device count
locks at first init.

On hosts where the forced-host-platform flag cannot provide the devices
(e.g. a GPU/TPU backend pinned by env), the tests SKIP rather than fail —
probed once per session below."""

import functools
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REQUIRED_DEVICES = 8


def _env(devices: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return env


@functools.lru_cache(maxsize=None)
def _forced_device_count(devices: int = REQUIRED_DEVICES) -> int:
    """How many devices a fresh subprocess actually gets under the flag.

    Cached, and only probed from inside a test body (not at collection) so
    deselected runs (``-m "not slow"``) never pay for the subprocess.
    """
    try:
        p = subprocess.run(
            [sys.executable, "-c", "import jax; print(jax.device_count())"],
            capture_output=True, text=True, timeout=120, env=_env(devices))
        return int(p.stdout.strip()) if p.returncode == 0 else 0
    except (subprocess.SubprocessError, ValueError):
        return 0


def _require_devices() -> None:
    count = _forced_device_count()
    if count < REQUIRED_DEVICES:
        pytest.skip(f"host provides {count} < {REQUIRED_DEVICES} "
                    "(placeholder) jax devices")


def _run(code: str, devices: int = REQUIRED_DEVICES):
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=420,
                       env=_env(devices), cwd=REPO)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    return p.stdout


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    _require_devices()
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_forward

        S, M = 4, 8                     # 4 stages, 8 microbatches
        mesh = jax.make_mesh((S,), ("stage",))
        rng = np.random.default_rng(0)
        d = 16
        ws = jnp.asarray(rng.normal(size=(S, d, d)) / np.sqrt(d), jnp.float32)
        xs = jnp.asarray(rng.normal(size=(M * 2, d)), jnp.float32)

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        run = pipeline_forward(stage_fn, S, M, mesh, "stage")
        got = run(ws, xs)

        ref = xs
        for s in range(S):
            ref = jnp.tanh(ref @ ws[s])
        err = float(jnp.abs(got - ref).max())
        assert err < 1e-5, f"pipeline mismatch {err}"
        print("PIPELINE_OK", err)
    """)
    assert "PIPELINE_OK" in out


@pytest.mark.slow
def test_compressed_psum_across_real_pod_axis():
    _require_devices()
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro._compat.jaxshims import shard_map
        from repro.distributed.collectives import compressed_psum

        mesh = jax.make_mesh((8,), ("pod",))
        rng = np.random.default_rng(0)
        g_all = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)

        @partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
                 out_specs=(P("pod"), P("pod")))
        def step(g, err):
            m, ne = compressed_psum(g[0], "pod", err[0])
            return m[None], ne[None]

        err = jnp.zeros_like(g_all)
        true_mean = np.asarray(g_all.mean(axis=0))
        # one-shot error <= int8 quantization bound; averaged over steps
        # with feedback it converges
        total = np.zeros(64)
        n = 30
        for _ in range(n):
            out, err = step(g_all, err)
            total += np.asarray(out[0])
        np.testing.assert_allclose(total / n, true_mean, atol=3e-3)
        print("COMPRESSED_OK")
    """)
    assert "COMPRESSED_OK" in out


@pytest.mark.slow
def test_elastic_remesh_checkpoint_restore():
    """A checkpoint written on an 8-device (4×2) mesh restores onto the
    6-device (3×2) mesh chosen by the failure planner after losing a host."""
    _require_devices()
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpoint import load_checkpoint, save_checkpoint
        from repro.checkpoint.failure import elastic_remesh

        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        w = jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6)
        tree = {"w": jax.device_put(
            w, NamedSharding(mesh8, P("data", "model")))}
        d = tempfile.mkdtemp()
        save_checkpoint(d, 5, tree)

        # lose one host (2 devices): planner keeps model axis = 2
        shape, idle = elastic_remesh(6, 2)
        assert shape == (3, 2) and idle == 0, (shape, idle)
        mesh6 = jax.make_mesh(shape, ("data", "model"))
        # 8 rows don't divide 3 -> restore replicated on data, sharded on model
        shardings = {"w": NamedSharding(mesh6, P(None, "model"))}
        restored, manifest = load_checkpoint(
            d, jax.eval_shape(lambda: {"w": w}), shardings=shardings)
        assert manifest["step"] == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
        assert restored["w"].sharding == shardings["w"]
        print("ELASTIC_OK")
    """, devices=8)
    assert "ELASTIC_OK" in out
