"""Tests for the declarative Investigation API (spec + engine + CLI).

Contracts:

* **spec** — JSON round-trip at every nesting level (including non-string
  mapping values), STRICT parsing (unknown fields and schema-version
  mismatches raise), registry/import-path experiment resolution;
* **engine** — a spec-driven Investigation reproduces ``run_optimizer``
  draw-for-draw (the existing gates pin the shims; this pins the spec
  path), engine dispatch matches the execution block, multi-optimizer specs
  run as sharing campaigns, ``resume()`` folds prior history and reuses;
* **CLI** — ``python -m repro.core.api`` run/--dry-run/validate/catalog.
"""

import json

import numpy as np
import pytest

from repro.core import (ActionSpace, DiscoverySpace, Dimension,
                        FunctionExperiment, Investigation, InvestigationSpec,
                        ProbabilitySpace, SampleStore)
from repro.core.api.__main__ import main as cli_main
from repro.core.api.spec import (SCHEMA_VERSION, BudgetSpec, ExecutionSpec,
                                 ExperimentSpec, OptimizerSpec, TransferSpec)
from repro.core.optimizers import OPTIMIZER_REGISTRY, run_optimizer


def quad_space(n=8):
    vals = [round(v, 3) for v in np.linspace(-2, 2, n)]
    return ProbabilitySpace.make([
        Dimension.discrete("x", vals),
        Dimension.discrete("y", vals),
    ])


def full_spec(**overrides):
    base = dict(
        name="test-study",
        space=quad_space(),
        metric="loss",
        experiments=(ExperimentSpec("quad"),),
        optimizers=(OptimizerSpec("tpe", seed=3),),
        execution=ExecutionSpec(backend="serial", workers=2),
        budget=BudgetSpec(max_trials=9, patience=9),
        transfer=TransferSpec(enabled=True, max_warm=32,
                              mappings={"x": ((1.0, 2.0),)}),
        share_history=False,
        warm_start=True,
    )
    base.update(overrides)
    return InvestigationSpec(**base)


def trail(trials):
    return [(t.configuration.digest, t.value, t.action) for t in trials]


# ------------------------------------------------------------ spec round-trip


def test_spec_round_trips_through_json():
    spec = full_spec()
    rt = InvestigationSpec.loads(spec.dumps())
    assert rt == spec
    # mappings preserve non-string value types through the pair-list encoding
    assert rt.transfer.mappings["x"] == ((1.0, 2.0),)
    assert json.loads(spec.dumps())["schema_version"] == SCHEMA_VERSION


def test_spec_file_round_trip(tmp_path):
    path = str(tmp_path / "spec.json")
    spec = full_spec()
    spec.save(path)
    assert InvestigationSpec.load(path) == spec


@pytest.mark.parametrize("mutate, ctx", [
    (lambda d: d.update(surprise=1), "investigation"),
    (lambda d: d["execution"].update(wrkers=4), "execution"),
    (lambda d: d["budget"].update(maxtrials=4), "budget"),
    (lambda d: d["transfer"].update(minr=0.5), "transfer"),
    (lambda d: d["optimizers"][0].update(sed=1), "optimizer"),
    (lambda d: d["experiments"][0].update(factry="quad"), "experiment"),
])
def test_spec_rejects_unknown_fields_at_every_level(mutate, ctx):
    d = full_spec().to_json()
    mutate(d)
    with pytest.raises(ValueError, match=f"{ctx}: unknown field"):
        InvestigationSpec.from_json(d)


def test_spec_rejects_wrong_schema_version():
    d = full_spec().to_json()
    d["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version"):
        InvestigationSpec.from_json(d)


def test_spec_rejects_bad_values():
    with pytest.raises(ValueError, match="unknown optimizer"):
        OptimizerSpec("definitely-not-registered")
    with pytest.raises(ValueError, match="unknown backend"):
        ExecutionSpec(backend="teleport")
    with pytest.raises(ValueError, match="mode"):
        full_spec(mode="median")
    with pytest.raises(ValueError, match="batch_size must be 1"):
        full_spec(optimizers=(OptimizerSpec("random"),
                              OptimizerSpec("tpe")),
                  execution=ExecutionSpec(batch_size=3))
    with pytest.raises(ValueError, match="required"):
        InvestigationSpec.from_json({"schema_version": SCHEMA_VERSION,
                                     "name": "x"})


def test_experiment_factory_resolution_registry_and_import_path():
    by_name = ExperimentSpec("quad").build()
    by_path = ExperimentSpec("repro.core.api.workloads:quad").build()
    assert by_name.identifier == by_path.identifier
    with pytest.raises(ValueError, match="unknown experiment"):
        ExperimentSpec("no-such-factory").build()


# ------------------------------------------------------- engine equivalence


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_spec_driven_run_matches_run_optimizer(name):
    """The declarative path and the legacy shim produce identical
    trajectories for the same seed/budget — one engine, two doors."""
    def make_ds():
        exp = FunctionExperiment(
            fn=lambda c: {"loss": (c["x"] - 0.5) ** 2 + (c["y"] + 0.5) ** 2},
            properties=("loss",), name="quad")
        return DiscoverySpace(space=quad_space(),
                              actions=ActionSpace.make([exp]),
                              store=SampleStore(":memory:"))

    ds_ref = make_ds()
    ref = run_optimizer(OPTIMIZER_REGISTRY[name](seed=5), ds_ref, "loss",
                        max_trials=7, patience=99,
                        rng=np.random.default_rng(5))
    spec = InvestigationSpec(
        name="eq", space=quad_space(), metric="loss",
        experiments=(ExperimentSpec("quad"),),
        optimizers=(OptimizerSpec(name, seed=5),),
        budget=BudgetSpec(max_trials=7, patience=99))
    res = Investigation(spec).run()
    assert res.engine == "batched"
    assert trail(res.members[0].run.trials) == trail(ref.trials)


def test_engine_dispatch_follows_execution_block():
    spec = full_spec(transfer=TransferSpec(), warm_start=False)
    assert Investigation(spec).engine == "batched"
    spec2 = full_spec(transfer=TransferSpec(), warm_start=False,
                      execution=ExecutionSpec(max_inflight=2))
    assert Investigation(spec2).engine == "pipelined"
    spec3 = full_spec(transfer=TransferSpec(), warm_start=False,
                      optimizers=(OptimizerSpec("random"),
                                  OptimizerSpec("tpe")),
                      execution=ExecutionSpec())
    assert Investigation(spec3).engine == "campaign"


def test_multi_optimizer_spec_runs_sharing_campaign():
    spec = InvestigationSpec(
        name="fleet", space=quad_space(), metric="loss",
        experiments=(ExperimentSpec("quad"),),
        optimizers=(OptimizerSpec("random", seed=0),
                    OptimizerSpec("tpe", seed=1)),
        budget=BudgetSpec(max_trials=6, patience=99))
    res = Investigation(spec).run()
    assert res.engine == "campaign"
    assert len(res.members) == 2
    assert [m.optimizer for m in res.members] == ["random", "tpe"]
    for m in res.members:
        assert m.run.num_trials == 6
        assert m.foreign_trials > 0          # sharing really happened
    assert res.num_trials == 12
    s = res.summary()
    assert s["trials"] == 12 and len(s["members"]) == 2


def test_duplicate_family_members_get_unique_labels_and_operations():
    spec = InvestigationSpec(
        name="twins", space=quad_space(), metric="loss",
        experiments=(ExperimentSpec("quad"),),
        optimizers=(OptimizerSpec("random", seed=0),
                    OptimizerSpec("random", seed=1)),
        budget=BudgetSpec(max_trials=3, patience=99))
    res = Investigation(spec).run()
    labels = [m.optimizer for m in res.members]
    assert labels == ["random", "random#2"]
    assert len({m.operation_id for m in res.members}) == 2


def test_resume_folds_prior_history_and_reuses():
    """resume() continues a study: everything already recorded enters each
    member's history before the first ask, and re-proposals come back as
    free 'reused' trials — the cross-session continuation path."""
    store = SampleStore(":memory:")
    spec = InvestigationSpec(
        name="sess", space=quad_space(), metric="loss",
        experiments=(ExperimentSpec("quad"),),
        optimizers=(OptimizerSpec("random", seed=0),),
        budget=BudgetSpec(max_trials=5, patience=99))
    first = Investigation(spec, store=store).run()
    assert first.num_measured == 5
    second = Investigation(spec, store=store).resume()
    member = second.members[0]
    assert member.foreign_trials >= 5        # prior history folded pre-ask
    # the fold enters the model-visible history, so the same rng stream
    # proposes NEW configurations: nothing is re-paid
    prior = {t.configuration.digest for t in first.members[0].run.trials}
    new = {t.configuration.digest for t in member.run.trials}
    assert not (prior & new)
    assert store.count_measured() == 10


def test_plan_is_free_and_reports_transfer_candidates():
    store = SampleStore(":memory:")
    src_spec = InvestigationSpec(
        name="src", space=quad_space(), metric="loss",
        experiments=(ExperimentSpec("quad"),),
        optimizers=(OptimizerSpec("random", seed=0),),
        budget=BudgetSpec(max_trials=8, patience=99))
    Investigation(src_spec, store=store).run()
    tgt_spec = InvestigationSpec(
        name="tgt", space=quad_space(), metric="loss",
        experiments=(ExperimentSpec(
            "linear-shift", {"base": "quad", "scale": 1.2, "offset": 3.0}),),
        optimizers=(OptimizerSpec("tpe", seed=0),),
        budget=BudgetSpec(max_trials=4, patience=99),
        transfer=TransferSpec(enabled=True))
    before = store.count_measured()
    plan = Investigation(tgt_spec, store=store).plan()
    assert store.count_measured() == before  # planning paid for nothing
    assert plan.transfer_enabled
    assert len(plan.transfer_candidates) == 1
    assert plan.transfer_candidates[0]["measured"] >= 8
    assert "transfer" in plan.describe()


# ----------------------------------------------------------------------- CLI


def write_cli_spec(tmp_path, **spec_overrides):
    spec = InvestigationSpec(
        name="cli-smoke", space=quad_space(), metric="loss",
        experiments=(ExperimentSpec("quad"),),
        optimizers=(OptimizerSpec("random", seed=0),),
        budget=BudgetSpec(max_trials=4, patience=99), **spec_overrides)
    path = str(tmp_path / "spec.json")
    spec.save(path)
    return path


def test_cli_dry_run_executes_nothing(tmp_path, capsys):
    path = write_cli_spec(tmp_path)
    store_path = str(tmp_path / "store.db")
    assert cli_main(["run", path, "--store", store_path, "--dry-run"]) == 0
    out = capsys.readouterr().out
    assert "engine    : batched" in out
    assert SampleStore(store_path).count_measured() == 0


def test_cli_run_and_catalog_end_to_end(tmp_path, capsys):
    path = write_cli_spec(tmp_path)
    store_path = str(tmp_path / "store.db")
    out_path = str(tmp_path / "result.json")
    assert cli_main(["run", path, "--store", store_path,
                     "--out", out_path]) == 0
    summary = json.load(open(out_path))
    assert summary["trials"] == 4 and summary["best"] is not None
    assert SampleStore(store_path).count_measured() == 4
    assert cli_main(["catalog", "--store", store_path]) == 0
    assert "measured=4" in capsys.readouterr().out


def test_cli_validate_round_trips_and_rejects_bad_spec(tmp_path, capsys):
    path = write_cli_spec(tmp_path)
    assert cli_main(["validate", path]) == 0
    emitted = capsys.readouterr().out
    assert InvestigationSpec.loads(emitted) == InvestigationSpec.load(path)
    bad = str(tmp_path / "bad.json")
    d = InvestigationSpec.load(path).to_json()
    d["typo_field"] = True
    with open(bad, "w") as f:
        json.dump(d, f)
    with pytest.raises(SystemExit, match="unknown field"):
        cli_main(["validate", bad])
