"""Tests: data pipeline, checkpointing, fault tolerance, compressed
collectives, pipeline parallelism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.checkpoint import (CheckpointManager, latest_step,
                                         load_checkpoint, save_checkpoint)
from repro.checkpoint.failure import (ElasticPlan, FailureManager,
                                      StragglerPolicy, elastic_remesh)
from repro.data.pipeline import DataConfig, TokenPipeline


# ------------------------------------------------------------------ data


def test_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=4, seed=7)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b5a = p1.batch_at(5)
    b5b = p2.batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(p1.batch_at(6)["tokens"], b5a["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b5a["tokens"][:, 1:], b5a["labels"][:, :-1])


def test_pipeline_prefetch_matches_direct():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=1)
    p = TokenPipeline(cfg)
    p.start(cursor=3)
    idx, batch = next(p)
    assert idx == 3
    np.testing.assert_array_equal(batch["tokens"], p.batch_at(3)["tokens"])
    idx2, _ = next(p)
    assert idx2 == 4
    p.stop()


def test_pipeline_host_sharding():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1)
    shards = [TokenPipeline(cfg, host_index=i, host_count=4) for i in range(4)]
    batches = [s.batch_at(0)["tokens"] for s in shards]
    assert all(b.shape == (2, 8) for b in batches)
    # host shards differ (independent slices of the global batch)
    assert not np.array_equal(batches[0], batches[1])


def test_pipeline_learnable_structure():
    """The Markov overlay must make next-token prediction beat chance."""
    cfg = DataConfig(vocab_size=50, seq_len=256, global_batch=8, seed=0,
                     markov_strength=0.9)
    p = TokenPipeline(cfg)
    b = p.batch_at(0)
    follows = (p._perm[b["tokens"]] == b["labels"]).mean()
    assert follows > 0.5  # most transitions follow the permutation


# ------------------------------------------------------------------ checkpoint


def tree_example(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "m": {"w": jnp.ones((8, 16)), "b": jnp.zeros((16,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip_bit_exact(tmp_path):
    tree = tree_example()
    save_checkpoint(str(tmp_path), 7, tree, {"note": "hi"})
    template = jax.eval_shape(lambda: tree)
    restored, manifest = load_checkpoint(str(tmp_path), template)
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, save_every=10)
    tree = tree_example()
    for step in (10, 20, 30):
        mgr.save(step, tree, async_=False)
    assert mgr.latest_step() == 30
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000020", "step_00000030"]
    assert mgr.should_save(10) and not mgr.should_save(11)


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, save_every=1)
    mgr.save(5, tree_example(), async_=True)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_resharding_load(tmp_path):
    """A checkpoint saved unsharded restores onto an explicit sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = load_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree),
                                  shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == shardings["w"]


@pytest.mark.slow
def test_train_restart_bit_exact(tmp_path):
    """Kill a training run mid-stream; resume; final state must be bit-exact
    equal to an uninterrupted run (fault-tolerance integration test)."""
    from repro.launch.train import main as train_main

    common = ["--arch", "xlstm-125m", "--smoke", "--batch", "2", "--seq", "32",
              "--steps", "6", "--ckpt-every", "2", "--log-every", "100"]
    d1 = str(tmp_path / "interrupted")
    out1 = train_main(common + ["--ckpt-dir", d1, "--stop-after", "3"])
    assert out1["steps_run"] == 3
    out2 = train_main(common + ["--ckpt-dir", d1])  # resume
    assert out2["resumed_from"] == 2  # last checkpoint before the failure
    d2 = str(tmp_path / "clean")
    out3 = train_main(common + ["--ckpt-dir", d2])
    assert out3["steps_run"] == 6

    t1, m1 = load_checkpoint(d1, None) if False else (None, None)
    from repro.checkpoint.checkpoint import load_checkpoint as lc
    import jax
    # compare final checkpoints bit-exactly
    with open(os.path.join(d1, "step_00000006", "manifest.json")) as f:
        pass
    tree1, man1 = _load_raw(d1, 6)
    tree2, man2 = _load_raw(d2, 6)
    assert set(tree1) == set(tree2)
    for k in tree1:
        np.testing.assert_array_equal(tree1[k], tree2[k], err_msg=k)


def _load_raw(directory, step):
    import json
    import msgpack

    from repro.checkpoint.checkpoint import decompress_payload

    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, "arrays.msgpack.zst"), "rb") as f:
        raw = decompress_payload(f.read(), manifest.get("codec", "zstd"))
    payload = msgpack.unpackb(raw, raw=False)
    out = {}
    for key, info in manifest["arrays"].items():
        out[key] = np.frombuffer(payload[key], np.dtype(info["dtype"])) \
            .reshape(info["shape"])
    return out, manifest


# ------------------------------------------------------------------ failure


def test_elastic_remesh_preserves_model_axis():
    shape, idle = elastic_remesh(256, 16)
    assert shape == (16, 16) and idle == 0
    # lose one 8-device host: 248 devices -> 15x16 used, 8 idle
    shape, idle = elastic_remesh(248, 16)
    assert shape == (15, 16) and idle == 8
    with pytest.raises(ValueError):
        elastic_remesh(8, 16)


def test_failure_manager_detects_and_plans():
    fm = FailureManager(hosts=range(4), devices_per_host=64, model_axis=16,
                        timeout=10.0)
    now = 1000.0
    for h in range(4):
        fm.heartbeat(h, now)
    assert fm.check(now + 5) == []
    fm.heartbeat(0, now + 8)
    fm.heartbeat(1, now + 8)
    fm.heartbeat(2, now + 8)
    dead = fm.check(now + 12)
    assert dead == [3]
    plan = fm.plan(resume_step=120)
    assert plan.dropped_hosts == (3,)
    assert plan.devices_used == 192  # 3 hosts × 64, 12×16 mesh
    assert plan.mesh_shape == (12, 16)
    assert plan.resume_step == 120
    # rejoin
    fm.admit(3, now + 20)
    assert 3 in fm.alive


def test_straggler_policy_escalates():
    sp = StragglerPolicy(deadline_s=1.0, misses_to_fail=3, window=5)
    assert not sp.observe(0, 0.5)
    assert not sp.observe(0, 2.0)
    assert not sp.observe(0, 2.0)
    assert sp.observe(0, 2.0)  # third miss
    sp.reset(0)
    assert not sp.observe(0, 2.0)


# ------------------------------------------------------------------ collectives


def test_quantize_roundtrip_exact_for_representable():
    from repro.distributed.collectives import dequantize_int8, quantize_int8

    # values that are integer multiples of the scale roundtrip exactly
    x = jnp.asarray([0.0, 127.0, -127.0, 64.0, 32.0])
    q, s = quantize_int8(x)
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s)),
                               np.asarray(x), rtol=1e-6)


@pytest.mark.slow
def test_compressed_psum_error_feedback_converges():
    """Mean of a constant gradient over repeated steps: error feedback makes
    the time-averaged compressed mean converge to the true mean."""
    from repro._compat.jaxshims import shard_map
    from repro.distributed.collectives import compressed_psum

    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)

    from functools import partial

    @partial(shard_map, mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),
                                             jax.sharding.PartitionSpec()),
             out_specs=(jax.sharding.PartitionSpec(),
                        jax.sharding.PartitionSpec()))
    def step(x, err):
        return compressed_psum(x, "pod", err)

    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        out, err = step(g, err)
        total = total + out
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g),
                               atol=2e-3)


def test_compressed_grad_sync_tree():
    from repro.distributed.collectives import compressed_grad_sync

    mesh = jax.make_mesh((1,), ("pod",))
    grads = {"a": jnp.ones((4, 4)), "b": {"c": jnp.full((3,), -2.0)}}
    out, errs = compressed_grad_sync(grads, None, mesh)
    for k, v in [("a", 1.0)]:
        np.testing.assert_allclose(np.asarray(out["a"]), 1.0, atol=1e-2)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), -2.0, atol=2e-2)
    assert jax.tree.structure(errs) == jax.tree.structure(grads)


# ------------------------------------------------------------------ pipeline PP


def test_pipeline_forward_matches_sequential():
    pytest.importorskip("jax")
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >=2 devices for a stage axis")


def test_pipeline_bubble_fraction():
    from repro.distributed.pipeline import bubble_fraction

    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
