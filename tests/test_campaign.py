"""Tests for cooperative multi-optimizer campaigns (paper §V sharing).

Three contracts matter:

* **determinism** — a single-member campaign reproduces
  ``run_optimizer(max_inflight=1)`` (and therefore the classic serial loop)
  draw-for-draw, per optimizer family: the sharing machinery must be
  strictly additive;
* **sharing** — under ``share_history=True`` every member's history folds
  the other operations' measurements (digest-deduplicated, incrementally
  watermark-read via ``records_since``), including across processes;
* **tolerance** — a legacy optimizer returning bare configurations from
  ``ask`` runs through every driver (batched, pipelined, campaign) because
  normalization happens once at the driver boundary.
"""

import threading

import numpy as np
import pytest

from repro.core import (ActionSpace, Campaign, Configuration, DiscoverySpace,
                        Dimension, FunctionExperiment, MeasurementError,
                        ProbabilitySpace, SampleStore, run_campaign)
from repro.core.optimizers import (FOREIGN_ACTION, OPTIMIZER_REGISTRY,
                                   ScoredCandidate, run_optimizer)
from repro.core.optimizers.base import Optimizer, SearchAdapter, as_scored


def quad_space(n=8):
    vals = [round(v, 3) for v in np.linspace(-2, 2, n)]
    return ProbabilitySpace.make([
        Dimension.discrete("x", vals),
        Dimension.discrete("y", vals),
    ])


def quad_fn(c):
    return {"loss": (c["x"] - 0.5) ** 2 + (c["y"] + 0.5) ** 2}


def make_ds(store=None, fn=quad_fn, space=None):
    exp = FunctionExperiment(fn=fn, properties=("loss",), name="quad")
    return DiscoverySpace(space=space or quad_space(),
                          actions=ActionSpace.make([exp]),
                          store=store or SampleStore(":memory:"))


def trail(trials):
    return [(t.configuration.digest, t.value, t.action) for t in trials]


# ------------------------------------------------- records_since (store layer)


def test_records_since_is_incremental_and_ordered():
    ds = make_ds()
    configs = list(ds.space.all_configurations())[:5]
    ds.sample_batch(configs[:3], operation_id="op-a")
    first = ds.store.records_since(ds.space_id, 0)
    assert [r.seq for r in first] == [0, 1, 2]
    assert [r.rowid for r in first] == sorted(r.rowid for r in first)
    # nothing new => empty, watermark unchanged
    assert ds.store.records_since(ds.space_id, first[-1].rowid) == []
    ds.sample_batch(configs[3:], operation_id="op-b")
    fresh = ds.store.records_since(ds.space_id, first[-1].rowid)
    assert [r.operation_id for r in fresh] == ["op-b", "op-b"]
    assert all(r.rowid > first[-1].rowid for r in fresh)
    # the incremental union equals the full read
    assert first + fresh == ds.store.records_for(ds.space_id)


def test_records_since_pages_with_limit_and_filters_space():
    store = SampleStore(":memory:")
    for i in range(5):
        store.append_record("space-1", "op", f"d{i}", "measured")
    store.append_record("space-2", "op", "other", "measured")
    page1 = store.records_since("space-1", 0, limit=2)
    assert [r.config_digest for r in page1] == ["d0", "d1"]
    page2 = store.records_since("space-1", page1[-1].rowid)
    assert [r.config_digest for r in page2] == ["d2", "d3", "d4"]
    assert all(r.space_id == "space-1" for r in page1 + page2)
    store.close()


# ------------------------------------------- determinism (regression gate)


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_solo_campaign_reproduces_pipelined_serial_trajectory(name):
    """A one-member campaign == run_optimizer(max_inflight=1) draw-for-draw
    (same configurations, values, actions, sampling record) for every
    optimizer family — the PR-3-style regression gate: cooperative-sharing
    machinery must never perturb a solo trajectory."""
    def records(ds, op):
        return [(r.seq, r.config_digest, r.action) for r in ds.timeseries(op)]

    ds1, ds2 = make_ds(), make_ds()
    run = run_optimizer(OPTIMIZER_REGISTRY[name](seed=0), ds1, "loss", "min",
                        max_trials=6, patience=2,
                        rng=np.random.default_rng(3), max_inflight=1)
    camp = run_campaign(ds2, [OPTIMIZER_REGISTRY[name](seed=0)], "loss",
                        max_trials=6, patience=2,
                        rngs=[np.random.default_rng(3)])
    member = camp.members[0]
    assert trail(member.run.trials) == trail(run.trials)
    assert records(ds2, member.operation_id) == records(ds1, run.operation_id)
    assert member.foreign_trials == 0


# ------------------------------------------------------------- foreign tells


def test_members_fold_each_others_measurements():
    """Two members, shared history: each member folds the other operation's
    measurements as action='foreign' trials, digest-deduplicated, so its
    history size equals own + foreign with no double counting."""
    ds = make_ds()
    campaign = Campaign(
        ds, [OPTIMIZER_REGISTRY["random"](seed=0),
             OPTIMIZER_REGISTRY["tpe"](seed=1)],
        "loss", max_trials=8, patience=99,
        rngs=[np.random.default_rng(0), np.random.default_rng(1)])
    res = campaign.run()
    assert len(res.members) == 2
    histories = [m.adapter.trials for m in campaign.members]
    for result, history in zip(res.members, histories):
        assert result.foreign_trials > 0
        assert result.history_size \
            == result.run.num_trials + result.foreign_trials
        digests = [t.configuration.digest for t in history]
        assert len(set(digests)) == len(digests), "history must dedup digests"
        foreign = {t.configuration.digest for t in history
                   if t.action == FOREIGN_ACTION}
        own = {t.configuration.digest for t in history
               if t.action != FOREIGN_ACTION}
        assert foreign and not foreign & own
    # every foreign digest really came from the other member's operation
    own_sets = [{t.configuration.digest for t in m.run.trials}
                for m in res.members]
    for history, other_own in zip(histories, reversed(own_sets)):
        foreign = {t.configuration.digest for t in history
                   if t.action == FOREIGN_ACTION}
        assert foreign <= other_own


def test_foreign_history_reaches_model_and_digests_never_duplicate():
    """Drive the adapters directly: after a campaign, re-syncing a fresh
    adapter folds the full fleet history once, and folding again is a
    no-op (watermark + dedup)."""
    ds = make_ds()
    res = run_campaign(
        ds, [OPTIMIZER_REGISTRY["random"](seed=0),
             OPTIMIZER_REGISTRY["bo-gp"](seed=1)],
        "loss", max_trials=6, patience=99,
        rngs=[np.random.default_rng(0), np.random.default_rng(1)])
    adapter = SearchAdapter(ds, "loss", "min", optimizer_name="late-joiner")
    folded = adapter.sync_foreign()
    digests = [t.configuration.digest for t in adapter.trials]
    assert folded == len(digests) > 0
    assert len(set(digests)) == len(digests), "foreign fold must dedup digests"
    assert all(t.action == FOREIGN_ACTION for t in adapter.trials)
    assert adapter.sync_foreign() == 0  # watermark: nothing new
    # the union view: every fleet configuration exactly once
    fleet = {t.configuration.digest for m in res.members for t in m.run.trials}
    assert set(digests) == fleet


def test_foreign_failed_trials_fold_as_value_none():
    """A foreign 'failed' record folds as a value-None trial: the member
    learns the configuration is non-deployable and never re-proposes it."""
    def flaky(c):
        if c["x"] > 1.5:
            raise MeasurementError("quota")
        return quad_fn(c)

    ds = make_ds(fn=flaky)
    bad = Configuration.make({"x": 2.0, "y": 2.0})
    ds.sample_batch([bad], operation_id="other-op")  # records a failure
    adapter = SearchAdapter(ds, "loss", "min", optimizer_name="member")
    assert adapter.sync_foreign() == 1
    t = adapter.trials[0]
    assert t.action == FOREIGN_ACTION and t.value is None
    assert bad.digest in adapter.seen_digests()


def test_warm_start_folds_pre_campaign_history():
    """warm_start=True folds records that existed before the campaign began
    (cross-campaign reuse); the default shares only fleet-produced data."""
    store = SampleStore(":memory:")
    ds = make_ds(store)
    prior = list(ds.space.all_configurations())[:4]
    ds.sample_batch(prior, operation_id="previous-study")

    cold = Campaign(ds, [OPTIMIZER_REGISTRY["random"](seed=0)], "loss",
                    max_trials=2, rngs=[np.random.default_rng(0)])
    assert cold.members[0].adapter.record_watermark > 0  # tail, not zero
    warm = Campaign(ds, [OPTIMIZER_REGISTRY["random"](seed=0)], "loss",
                    max_trials=2, warm_start=True,
                    rngs=[np.random.default_rng(0)])
    assert warm.members[0].adapter.record_watermark == 0
    res = warm.run()
    member = res.members[0]
    assert member.foreign_trials == len(prior)
    assert member.history_size == member.run.num_trials + len(prior)


def test_shared_store_measures_once_across_members():
    """Two members proposing overlapping configurations: the store's claim
    arbitration measures each cell once; the second tell is 'reused'."""
    store = SampleStore(":memory:")
    ds = make_ds(store)
    # identical rng streams => the two random walkers propose identical draws
    res = run_campaign(
        ds, [OPTIMIZER_REGISTRY["random"](seed=0),
             OPTIMIZER_REGISTRY["random"](seed=0)],
        "loss", max_trials=5, patience=99, share_history=False,
        rngs=[np.random.default_rng(7), np.random.default_rng(7)])
    digests = {t.configuration.digest for _, t in res.events}
    assert store.count_measured(ds.space_id) == len(digests)
    assert res.num_measured == len(digests)
    assert res.num_trials > res.num_measured  # the overlap came back reused


def test_campaign_through_queue_backend_shares_one_worker_fleet(tmp_path):
    """Fleet routing: a two-member campaign over the store-rendezvous queue
    backend — one external worker loop serves BOTH members' work items, and
    every trial lands through the §III-D store-only coordination path."""
    from repro.core.execution.worker import run_worker

    path = str(tmp_path / "store.db")
    ds = make_ds(SampleStore(path))
    ds.claim_timeout_s = 10.0
    worker_ds = make_ds(SampleStore(path))
    worker = threading.Thread(
        target=run_worker, args=(worker_ds,),
        kwargs={"idle_timeout_s": 2.0, "claim_batch": 2})
    worker.start()
    try:
        res = run_campaign(
            ds, [OPTIMIZER_REGISTRY["random"](seed=0),
                 OPTIMIZER_REGISTRY["tpe"](seed=1)],
            "loss", max_trials=5, patience=99, max_inflight=2,
            backend="queue",
            rngs=[np.random.default_rng(0), np.random.default_rng(1)])
    finally:
        worker.join()
    assert all(m.run.num_trials == 5 for m in res.members)
    assert all(t.value is not None for _, t in res.events)
    # both members' items went through the one queue (one shared fleet)
    assert ds.store._rows(
        "SELECT COUNT(*) FROM work_items WHERE status='done'")[0][0] \
        == res.num_trials


def test_foreign_failure_recovered_when_later_measured():
    """A foreign 'failed' record folds provisionally: if another operation
    later measures the same configuration successfully, a recovery trial
    with the value is appended — a transient quota failure must not mask
    the real value forever (first-record-wins regression).  The failed
    trial itself is never mutated: trial objects are shared with event
    traces, and rewriting history would falsify time-to-best metrics."""
    calls = {"n": 0}

    def flaky_once(c):
        calls["n"] += 1
        if calls["n"] == 1:
            raise MeasurementError("transient quota")
        return quad_fn(c)

    ds = make_ds(fn=flaky_once)
    x = next(iter(ds.space.all_configurations()))
    ds.sample_batch([x], operation_id="op-a")   # records 'failed'
    adapter = SearchAdapter(ds, "loss", "min", optimizer_name="member")
    assert adapter.sync_foreign() == 1
    assert adapter.trials[0].value is None      # provisional non-deployable
    ds.sample_batch([x], operation_id="op-b")   # re-measure succeeds
    assert adapter.sync_foreign() == 1          # the recovery is a new fold
    assert len(adapter.trials) == 2             # failure kept, value appended
    assert adapter.trials[0].value is None      # history never rewritten
    assert adapter.trials[1].value == quad_fn(x)["loss"]
    assert adapter.trials[1].action == FOREIGN_ACTION
    # at most one recovery per digest: further syncs fold nothing
    ds.sample_batch([x], operation_id="op-c")
    assert adapter.sync_foreign() == 0


def test_own_failure_recovered_when_foreign_measurement_lands():
    """Symmetry: a member's OWN transient failure is provisional too — when
    another operation later measures the configuration successfully, the
    member gains a recovery trial instead of treating the configuration as
    non-deployable forever."""
    calls = {"n": 0}

    def flaky_once(c):
        calls["n"] += 1
        if calls["n"] == 1:
            raise MeasurementError("transient quota")
        return quad_fn(c)

    ds = make_ds(fn=flaky_once)
    x = next(iter(ds.space.all_configurations()))
    adapter = SearchAdapter(ds, "loss", "min", optimizer_name="member")
    adapter.evaluate_batch([x])                  # own trial: failed
    assert adapter.trials[0].action == "failed"
    assert adapter.trials[0].value is None
    ds.sample_batch([x], operation_id="op-b")    # outside op re-measures
    assert adapter.sync_foreign() == 1           # recovery appended
    assert len(adapter.trials) == 2
    assert adapter.trials[0].value is None       # own record stays honest
    assert adapter.trials[1].value == quad_fn(x)["loss"]
    assert adapter.trials[1].action == FOREIGN_ACTION


def test_crash_stops_fleet_submissions_immediately():
    """In-process crash contract: once a completion surfaces a crash, no
    further member may submit — exactly one experiment executes on a
    serial backend where every configuration crashes."""
    calls = {"n": 0}

    def bomb(c):
        calls["n"] += 1
        raise RuntimeError("experiment bug: wild pointer")

    ds = make_ds(fn=bomb)
    with pytest.raises(RuntimeError, match="wild pointer"):
        run_campaign(
            ds, [OPTIMIZER_REGISTRY["random"](seed=0),
                 OPTIMIZER_REGISTRY["random"](seed=1)],
            "loss", max_trials=5, patience=99, backend="serial",
            rngs=[np.random.default_rng(0), np.random.default_rng(1)])
    assert calls["n"] == 1, "submissions after an absorbed crash"


def test_min_trials_floor_counts_own_trials_not_foreign():
    """Regression: a member's min_trials floor must be satisfied by its OWN
    trials — foreign-folded fleet history (which quickly dwarfs own counts)
    must not let a stalled member stop early."""
    ds = make_ds(fn=lambda c: {"loss": 1.0})  # flat surface: every trial stalls
    res = run_campaign(
        ds, [OPTIMIZER_REGISTRY["random"](seed=0),
             OPTIMIZER_REGISTRY["random"](seed=1)],
        "loss", max_trials=30, patience=1, min_trials=8,
        rngs=[np.random.default_rng(0), np.random.default_rng(1)])
    for m in res.members:
        # stalls from trial one (flat surface), but the floor holds per member
        assert m.run.num_trials >= 8
        assert m.foreign_trials > 0  # the fold really was in play


# --------------------------------------------- sharing helps (smoke version)


def test_shared_campaign_reaches_best_no_later_than_isolated_member():
    """Sharing-efficiency smoke (the full §V comparison lives in
    benchmarks/campaign_bench.py): on a fixed seed set, the cooperative
    campaign's fleet finds the space optimum within its measurement budget
    and every model-based member trains on more history than it paid for."""
    space = quad_space(10)
    truth = min(quad_fn(c)["loss"] for c in space.all_configurations())

    ds = make_ds(space=space)
    opts = [OPTIMIZER_REGISTRY[n](seed=i)
            for i, n in enumerate(("random", "tpe", "bo-gp", "bohb"))]
    res = run_campaign(ds, opts, "loss", max_trials=12, patience=12,
                       rngs=[np.random.default_rng(100 + i) for i in range(4)])
    assert res.best is not None
    assert res.best.value <= truth + 0.35  # lands at/near the bowl bottom
    for m in res.members:
        assert m.history_size > m.run.num_trials  # model saw foreign data
    assert res.measurements_to_best() <= res.num_measured


# ----------------------------------------- bare-ask tolerance (normalization)


class BareRandom(Optimizer):
    """A legacy optimizer whose ask returns bare Configurations (no
    ScoredCandidate wrapper) — the tolerance documented on Optimizer.suggest
    must hold at every driver boundary."""

    name = "bare-random"

    def ask(self, adapter, rng, n=1):
        pool = [c for c in adapter.space.all_configurations()
                if c.digest not in adapter.seen_digests()]
        out = []
        for _ in range(min(n, len(pool))):
            out.append(pool.pop(int(rng.integers(len(pool)))))
        return out  # bare Configuration objects


def test_as_scored_normalizes_mixed_batches():
    c1 = Configuration.make({"x": 1})
    c2 = Configuration.make({"x": 2})
    batch = as_scored([c1, ScoredCandidate(c2, 3.5)])
    assert all(isinstance(b, ScoredCandidate) for b in batch)
    assert batch[0].configuration == c1 and batch[0].score is None
    assert batch[1].score == 3.5


@pytest.mark.parametrize("engine", ["batched", "pipelined", "campaign"])
def test_bare_returning_optimizer_runs_through_every_driver(engine):
    ds = make_ds()
    if engine == "campaign":
        res = run_campaign(ds, [BareRandom(seed=0)], "loss", max_trials=5,
                           patience=99, rngs=[np.random.default_rng(0)])
        trials = res.members[0].run.trials
    elif engine == "pipelined":
        run = run_optimizer(BareRandom(seed=0), ds, "loss", "min",
                            max_trials=5, patience=99,
                            rng=np.random.default_rng(0), max_inflight=2)
        trials = run.trials
    else:
        run = run_optimizer(BareRandom(seed=0), ds, "loss", "min",
                            max_trials=5, patience=99,
                            rng=np.random.default_rng(0), batch_size=2)
        trials = run.trials
    assert len(trials) == 5
    assert all(t.value is not None for t in trials)
    digests = [t.configuration.digest for t in trials]
    assert len(set(digests)) == 5


def test_bare_optimizer_joins_shared_campaign_with_model_member():
    """The campaign foreign-tell path tolerates bare-ask members alongside
    scored ones: both run, both fold each other's history."""
    ds = make_ds()
    res = run_campaign(
        ds, [BareRandom(seed=0), OPTIMIZER_REGISTRY["tpe"](seed=1)],
        "loss", max_trials=6, patience=99,
        rngs=[np.random.default_rng(0), np.random.default_rng(1)])
    assert all(m.run.num_trials == 6 for m in res.members)
    assert all(m.foreign_trials > 0 for m in res.members)


# --------------------------------------- _unseen_candidates dedup regression


def test_unseen_candidates_continuous_space_has_no_duplicates():
    """Bugfix regression: the continuous-space draw loop must dedup within
    itself — on a tiny effective space repeated draws used to return a pool
    with duplicate digests, letting ask() emit a non-distinct batch."""
    # continuous dimension, but the optimizer encoding snaps nothing — use
    # a 1-d continuous space with a coarse sampler via a tiny discrete dim
    # alongside: duplicates arise from the categorical collapsing draws
    space = ProbabilitySpace.make([
        Dimension.categorical("mode", ["a", "b", "c"]),
        Dimension.continuous("x", 0.0, 1.0),
    ])

    class SnappingSpace:
        """View whose sample_configuration rounds x to one decimal: a
        continuous space with only ~30 distinct digests, so raw draws
        collide constantly."""

        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, name):
            return getattr(self._inner, name)

        def sample_configuration(self, rng):
            c = self._inner.sample_configuration(rng)
            return Configuration.make(
                {"mode": c["mode"], "x": round(c["x"], 1)})

    ds = make_ds(space=space)
    adapter = SearchAdapter(ds, "loss", "min")
    ds.space = SnappingSpace(space)

    pool = Optimizer._unseen_candidates(adapter, np.random.default_rng(0),
                                        max_candidates=64)
    digests = [c.digest for c in pool]
    assert len(set(digests)) == len(digests), "pool contains duplicates"
    assert 0 < len(pool) <= 64
