"""Draw-for-draw parity of the accelerated ask backends, plus the ask-path
numerical-robustness bugfixes.

Parity contract: scoring is rng-free and both backends consume identical rng
streams in the candidate sampler, so for the same adapter history and the
same seeded rng, ``ask`` must propose the *same configurations in the same
order* whatever the backend — the accelerated paths are drop-in, not
approximately-similar.  Float32 vs float64 can only reorder exact score
ties, which the deterministic cases here avoid.

The three regression-pinned bugs:

* ``GPBayesOpt``: a Gram matrix that fails Cholesky twice, or an EI surface
  that is entirely NaN (posterior-std underflow on an all-equal history),
  used to crash or mis-rank — now both degrade to random proposals.
* ``Optimizer._unseen_candidates``: finite spaces larger than the old 4096
  enumeration cutoff went through rejection sampling, whose try cap
  reported a near-exhausted pool as empty — false exhaustion.
* ``TPE``: a degenerate good/bad split (``n_good == len(ok)``) aliased
  ``bad = good``, zeroing every score so proposals silently came out in
  pool order.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ActionSpace, Dimension, DiscoverySpace,
                        FunctionExperiment, ProbabilitySpace, SampleStore)
from repro.core.api.spec import OptimizerSpec
from repro.core.optimizers import BOHB, GPBayesOpt, TPE
from repro.core.optimizers import accel
from repro.core.optimizers.base import Optimizer, SearchAdapter, Trial
from repro.core.optimizers.tpe import tpe_score

jax_missing = not accel.jax_available()

FAMILIES = {"bo-gp": GPBayesOpt, "tpe": TPE, "bohb": BOHB}


def mixed_space():
    return ProbabilitySpace.make([
        Dimension.discrete("cpu", [1, 2, 4, 8, 16, 32]),
        Dimension.discrete("mem", [0.5, 1.0, 2.0, 4.0]),
        Dimension.categorical("tier", ["gp", "burst", "spot"]),
    ])


def continuous_space():
    return ProbabilitySpace.make([
        Dimension.continuous("lr", 1e-4, 1e-1),
        Dimension.continuous("momentum", 0.0, 0.99),
    ])


def adapter_with_history(space, n, seed=0, value_fn=None):
    """An adapter preloaded with n synthetic valued trials."""
    exp = FunctionExperiment(fn=lambda c: {"m": 0.0}, properties=("m",),
                             name="parity")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                        store=SampleStore(":memory:"))
    adapter = SearchAdapter(ds, "m", "min")
    rng = np.random.default_rng(seed)
    configs = [space.sample_configuration(rng) for _ in range(n)]
    values = rng.random(n)
    if value_fn is not None:
        values = np.array([value_fn(c, v) for c, v in zip(configs, values)])
    adapter.tell([Trial(c, float(v), "measured", i)
                  for i, (c, v) in enumerate(zip(configs, values))])
    return adapter


def accel_backends():
    out = []
    if accel.jax_available():
        out.append("jax")
        if accel.pallas_available():
            out.append("pallas")
    return out


# -- draw-for-draw proposal parity -------------------------------------------


@pytest.mark.skipif(jax_missing, reason="jax unavailable")
@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize("space_maker", [mixed_space, continuous_space],
                         ids=["mixed", "continuous"])
@pytest.mark.parametrize("history", [9, 17])
@pytest.mark.parametrize("seed", [0, 3])
def test_ask_proposals_match_numpy(family, space_maker, history, seed):
    adapter = adapter_with_history(space_maker(), history, seed=seed)
    batches = {}
    for backend in ["numpy"] + accel_backends():
        opt = FAMILIES[family](seed=0, backend=backend, max_candidates=32)
        batches[backend] = opt.ask(adapter, np.random.default_rng(seed), n=3)
    ref = [c.digest for c in batches["numpy"]]
    assert len(ref) == 3
    for backend, batch in batches.items():
        assert [c.digest for c in batch] == ref, (
            f"{family}/{backend} diverged from numpy proposals")
        # scores must agree too (None init-phase scores stay None)
        for a, b in zip(batches["numpy"], batch):
            if a.score is None:
                assert b.score is None
            else:
                assert b.score == pytest.approx(a.score, rel=1e-2, abs=1e-3)


@pytest.mark.skipif(jax_missing, reason="jax unavailable")
def test_gp_ei_surface_close_and_argmax_identical():
    """Direct acquisition-surface comparison on a bigger pool than the ask
    tests use: argmax must match exactly, values at float32 tolerance."""
    space = mixed_space()
    rng = np.random.default_rng(5)
    configs = [space.sample_configuration(rng) for _ in range(48)]
    y = rng.random(48)
    X = np.stack([space.encode(c) for c in configs])
    pool = [space.sample_configuration(rng) for _ in range(200)]
    Xc = np.stack([space.encode(c) for c in pool])
    ei_ref = GPBayesOpt(seed=0)._acquisition(X, y, Xc)
    for backend in accel_backends():
        opt = GPBayesOpt(seed=0, backend=backend)
        ei = opt._acquisition(X, y, Xc)
        assert int(np.argmax(ei)) == int(np.argmax(ei_ref))
        np.testing.assert_allclose(ei, ei_ref, atol=1e-3)
        # second call hits the fit cache and must be bit-identical
        assert np.array_equal(opt._acquisition(X, y, Xc), ei)


@pytest.mark.skipif(jax_missing, reason="jax unavailable")
def test_tpe_scores_close_to_reference():
    space = mixed_space()
    rng = np.random.default_rng(2)
    good = [space.sample_configuration(rng) for _ in range(5)]
    bad = [space.sample_configuration(rng) for _ in range(11)]
    pool = [space.sample_configuration(rng) for _ in range(100)]
    ref = tpe_score(space, good, bad, pool)
    got = accel.tpe_scores(space, good, bad, pool)
    np.testing.assert_allclose(got, ref, atol=1e-4)
    # empty observation sets degrade to the uniform prior on both paths
    np.testing.assert_allclose(accel.tpe_scores(space, good, [], pool),
                               tpe_score(space, good, [], pool), atol=1e-4)


@pytest.mark.skipif(jax_missing, reason="jax unavailable")
def test_pallas_rbf_matches_jnp_oracle():
    from repro.core.optimizers.accel import pallas_rbf
    if not pallas_rbf.pallas_available():
        pytest.skip("pallas unavailable")
    rng = np.random.default_rng(0)
    A = rng.random((24, 5)).astype(np.float32)
    B = rng.random((17, 5)).astype(np.float32)
    inv2ls2 = np.float32(0.5 / 0.35 ** 2)
    got = np.asarray(pallas_rbf.rbf_matrix_pallas(A, B, inv2ls2))
    want = np.asarray(pallas_rbf.rbf_matrix_jnp(A, B, inv2ls2))
    np.testing.assert_allclose(got, want, atol=1e-5)


# -- backend selection / spec threading --------------------------------------


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown ask backend"):
        GPBayesOpt(seed=0, backend="cuda")
    with pytest.raises(ValueError, match="unknown ask backend"):
        OptimizerSpec(name="tpe", backend="cuda")


def test_spec_threads_backend_and_roundtrips():
    spec = OptimizerSpec(name="bo-gp", seed=7, backend="jax")
    opt = spec.build()
    # resolve degrades to numpy only when jax is missing
    assert opt.backend == ("jax" if accel.jax_available() else "numpy")
    assert OptimizerSpec.from_json(spec.to_json()) == spec
    # default stays backend-free for byte-compatible old spec files
    assert OptimizerSpec(name="tpe").to_json()["backend"] is None
    assert OptimizerSpec(name="tpe").build().backend == "numpy"


# -- bugfix 1: GP ask-path robustness ----------------------------------------


def test_gp_double_cholesky_failure_degrades_to_random(monkeypatch):
    """Both cho_factor attempts raising used to escape ask and kill the
    worker; now the step degrades to (unscored) random proposals."""
    from repro.core.optimizers import bo_gp as bo_gp_mod

    def always_fail(*a, **k):
        raise np.linalg.LinAlgError("not positive definite")

    monkeypatch.setattr(bo_gp_mod, "cho_factor", always_fail)
    adapter = adapter_with_history(mixed_space(), 8, seed=0)
    batch = GPBayesOpt(seed=0).ask(adapter, np.random.default_rng(0), n=3)
    assert len(batch) == 3
    assert all(c.score is None for c in batch)
    assert len({c.digest for c in batch}) == 3


def test_gp_all_equal_history_no_nan_proposals():
    """All-equal y after foreign folding underflows the posterior std; the
    NaN EI surface must fall back to random instead of ranking on NaN."""
    for backend in ["numpy"] + accel_backends():
        adapter = adapter_with_history(mixed_space(), 12, seed=1,
                                       value_fn=lambda c, v: 0.75)
        opt = GPBayesOpt(seed=0, backend=backend)
        batch = opt.ask(adapter, np.random.default_rng(1), n=3)
        assert len(batch) == 3
        assert all(c.score is None or np.isfinite(c.score) for c in batch)


def test_gp_nan_surface_triggers_random_fallback(monkeypatch):
    adapter = adapter_with_history(mixed_space(), 8, seed=2)
    opt = GPBayesOpt(seed=0)
    monkeypatch.setattr(
        GPBayesOpt, "_acquisition",
        lambda self, X, y, Xc: np.full(Xc.shape[0], np.nan))
    batch = opt.ask(adapter, np.random.default_rng(2), n=2)
    assert len(batch) == 2
    assert all(c.score is None for c in batch)


def test_gp_isolated_nan_scores_zeroed(monkeypatch):
    """A partially-NaN surface keeps ranking the finite scores; NaN entries
    are zeroed so _top_n never sorts on NaN."""
    adapter = adapter_with_history(mixed_space(), 8, seed=3)
    opt = GPBayesOpt(seed=0)

    def spiky(self, X, y, Xc):
        ei = np.zeros(Xc.shape[0])
        ei[0] = np.nan
        ei[1] = 3.5
        return ei

    monkeypatch.setattr(GPBayesOpt, "_acquisition", spiky)
    batch = opt.ask(adapter, np.random.default_rng(3), n=1)
    assert batch[0].score == pytest.approx(3.5)


@given(scale=st.sampled_from([0.0, 1e-15, 1e-9, 1.0]),
       n=st.integers(min_value=4, max_value=12))
@settings(max_examples=20, deadline=None)
def test_gp_fit_predict_never_crashes_on_degenerate_history(scale, n):
    """Property: near-constant (down to exactly constant) histories produce
    either a clean posterior or None — never an exception, never NaN std."""
    rng = np.random.default_rng(n)
    X = rng.random((n, 3))
    y = 0.5 + scale * rng.standard_normal(n)
    Xc = rng.random((16, 3))
    fit = GPBayesOpt(seed=0)._fit_predict(X, y, Xc)
    if fit is not None:
        mean, std = fit
        assert np.all(np.isfinite(std))


# -- bugfix 2: false exhaustion of large finite spaces -----------------------


class _StubAdapter:
    """The minimal surface _unseen_candidates touches."""

    def __init__(self, space, seen):
        self.space = space
        self._seen = set(seen)

    def seen_digests(self):
        return set(self._seen)


def test_large_finite_space_near_exhaustion_returns_remainder():
    """5000-option space (beyond the old 4096 enumeration cutoff) with all
    but 7 configurations seen: rejection sampling used to return [] here;
    enumeration must return exactly the remaining 7."""
    space = ProbabilitySpace.make(
        [Dimension.discrete("x", list(range(5000)))])
    all_configs = list(space.all_configurations())
    remainder = {c.digest for c in all_configs[::717]}  # 7 survivors
    seen = {c.digest for c in all_configs} - remainder
    pool = Optimizer._unseen_candidates(_StubAdapter(space, seen),
                                        np.random.default_rng(0),
                                        max_candidates=64)
    assert {c.digest for c in pool} == remainder


def test_large_finite_space_pool_is_bounded_subsample():
    space = ProbabilitySpace.make(
        [Dimension.discrete("x", list(range(4500)))])
    pool = Optimizer._unseen_candidates(_StubAdapter(space, set()),
                                        np.random.default_rng(0),
                                        max_candidates=100)
    assert len(pool) == 100
    assert len({c.digest for c in pool}) == 100


_EXH_SPACE = ProbabilitySpace.make([Dimension.discrete("a", list(range(70))),
                                    Dimension.discrete("b", list(range(60)))])
_EXH_CONFIGS = list(_EXH_SPACE.all_configurations())  # 4200 > old cutoff


@given(keep=st.integers(min_value=0, max_value=40),
       seed=st.integers(min_value=0, max_value=10 ** 6))
@settings(max_examples=25, deadline=None)
def test_unseen_pool_is_exactly_the_remainder(keep, seed):
    """Property: for any survivor count at/below max_candidates, the pool is
    exactly the unseen remainder — never empty while configs remain."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(_EXH_CONFIGS), size=keep, replace=False)
    remainder = {_EXH_CONFIGS[i].digest for i in idx}
    seen = {c.digest for c in _EXH_CONFIGS} - remainder
    pool = Optimizer._unseen_candidates(_StubAdapter(_EXH_SPACE, seen),
                                        np.random.default_rng(seed),
                                        max_candidates=40)
    assert {c.digest for c in pool} == remainder


# -- bugfix 3: TPE degenerate good/bad split ---------------------------------


def _tpe_expected_pick(opt, adapter, seed):
    """Replicate ask's pool + degenerate-split scoring with the reference
    scorer: candidates from the identical rng stream, scored l(x) against
    the uniform prior (empty bad set)."""
    rng = np.random.default_rng(seed)
    candidates = opt._unseen_candidates(adapter, rng, opt.max_candidates)
    ok = [t for t in adapter.trials if t.value is not None]
    order = np.argsort([adapter.signed(t.value) for t in ok])
    good = [ok[i].configuration for i in order]  # gamma=1: everything good
    score = tpe_score(adapter.space, good, [], candidates, opt.bandwidth)
    return candidates, score


@pytest.mark.parametrize("backend", ["numpy"])
def test_tpe_degenerate_split_ranks_against_prior(backend):
    """gamma=1 makes n_good == len(ok).  The old bad=good alias zeroed all
    scores (every proposal = pool order); the fix scores l(x) against the
    uniform prior, so proposals track proximity to the good set."""
    adapter = adapter_with_history(mixed_space(), 8, seed=4)
    opt = TPE(seed=0, gamma=1.0, backend=backend)
    batch = opt.ask(adapter, np.random.default_rng(9), n=1)
    candidates, score = _tpe_expected_pick(opt, adapter, seed=9)
    assert np.any(score != 0.0), "degenerate split must not zero all scores"
    expected = candidates[int(np.argmax(score))]
    assert batch[0].digest == expected.digest
    assert batch[0].score == pytest.approx(float(score.max()), abs=1e-6)


@pytest.mark.skipif(jax_missing, reason="jax unavailable")
def test_tpe_degenerate_split_parity_across_backends():
    adapter = adapter_with_history(mixed_space(), 8, seed=4)
    ref = TPE(seed=0, gamma=1.0).ask(adapter, np.random.default_rng(9), n=3)
    for backend in accel_backends():
        got = TPE(seed=0, gamma=1.0, backend=backend).ask(
            adapter, np.random.default_rng(9), n=3)
        assert [c.digest for c in got] == [c.digest for c in ref]


def test_tpe_short_history_equal_to_n_good_not_pool_order():
    """Regression shape from the wild: len(ok) small enough that
    ceil(gamma * len) == len, with default gamma untouched."""
    adapter = adapter_with_history(mixed_space(), 4, seed=6)
    opt = TPE(seed=0, n_initial=4, gamma=1.0)
    batch = opt.ask(adapter, np.random.default_rng(11), n=2)
    assert len(batch) == 2
    assert all(c.score is not None and np.isfinite(c.score) for c in batch)


# -- constrained acquisition parity ------------------------------------------


def constrained_adapter(n=14, seed=0):
    """An adapter under an SLA-constrained objective with mixed feasibility
    labels (the label is a deterministic function of the encoding, so both
    backends see the same classifier training set)."""
    from repro.core.api.spec import ConstraintSpec, ObjectiveSpec

    space = mixed_space()
    exp = FunctionExperiment(fn=lambda c: {"m": 0.0, "lat": 0.0},
                             properties=("m", "lat"), name="parity-sla")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                        store=SampleStore(":memory:"))
    objective = ObjectiveSpec(constraints=(
        ConstraintSpec("lat", "<=", 1.0),))
    adapter = SearchAdapter(ds, "m", "min", objective=objective)
    rng = np.random.default_rng(seed)
    trials = []
    for i in range(n):
        c = space.sample_configuration(rng)
        feasible = bool(space.encode(c).sum() > 1.2)
        trials.append(Trial(c, float(rng.random()), "measured", i,
                            feasible=feasible))
    adapter.tell(trials)
    return adapter


@pytest.mark.skipif(jax_missing, reason="jax unavailable")
@pytest.mark.parametrize("seed", [0, 5])
def test_constrained_ask_parity_across_backends(seed):
    """Feasibility-weighted EI is backend-dispatched twice (value GP +
    classifier GP); the constrained ask must stay draw-for-draw identical
    to the numpy reference."""
    adapter = constrained_adapter(seed=seed)
    ref = GPBayesOpt(seed=0, max_candidates=32).ask(
        adapter, np.random.default_rng(seed), n=3)
    assert len(ref) == 3
    for backend in accel_backends():
        got = GPBayesOpt(seed=0, backend=backend, max_candidates=32).ask(
            adapter, np.random.default_rng(seed), n=3)
        assert [c.digest for c in got] == [c.digest for c in ref], (
            f"constrained bo-gp/{backend} diverged from numpy")
        for a, b in zip(ref, got):
            if a.score is None:
                assert b.score is None
            else:
                assert b.score == pytest.approx(a.score, rel=1e-2, abs=1e-3)


@pytest.mark.skipif(jax_missing, reason="jax unavailable")
def test_gp_pof_surface_close_to_numpy():
    """P(feasible) surfaces agree between the jitted classifier-GP path and
    the numpy reference at float32 tolerance, argmax identical, and the
    separate feasibility cache serves repeat calls bit-identically."""
    from scipy.stats import norm

    adapter = constrained_adapter(n=20, seed=3)
    space = adapter.space
    rng = np.random.default_rng(7)
    pool = [space.sample_configuration(rng) for _ in range(150)]
    Xc = np.stack([space.encode(c) for c in pool])
    ref_opt = GPBayesOpt(seed=0)
    pof_ref = ref_opt._feasibility_weight(adapter, Xc)
    assert pof_ref is not None
    assert np.all((pof_ref >= 0.0) & (pof_ref <= 1.0))
    # the numpy reference really is the classifier construction
    Xf, z = ref_opt._feasibility_arrays(adapter)
    mean, std = ref_opt._fit_predict(Xf, z, Xc)
    np.testing.assert_allclose(
        pof_ref, norm.cdf(mean / np.maximum(std, 1e-12)), atol=1e-12)
    for backend in accel_backends():
        opt = GPBayesOpt(seed=0, backend=backend)
        pof = opt._feasibility_weight(adapter, Xc)
        assert int(np.argmax(pof)) == int(np.argmax(pof_ref))
        np.testing.assert_allclose(pof, pof_ref, atol=1e-3)
        assert np.array_equal(opt._feasibility_weight(adapter, Xc), pof)
        # the classifier cache is separate from the value-GP fit cache
        assert opt._feas_cache and not opt._accel_cache
