"""Worker functions for the store-concurrency tests.

Kept in their own module (no hypothesis import, no fixtures) so spawn-based
``multiprocessing`` children can re-import them without pulling in test-only
dependencies or pytest configuration.
"""

import os
import sys

# Children must resolve `repro` even when launched without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:  # pragma: no cover - depends on launcher env
    sys.path.insert(0, _SRC)

from repro.core import Configuration, SampleStore
from repro.core.entities import PropertyValue

SPACE_ID = "conc-space"
OP_ID = "conc-op"


def hammer(store: SampleStore, worker: int, iterations: int) -> None:
    """One writer's workload: new configuration, values, record — repeatedly."""
    for i in range(iterations):
        config = Configuration.make({"worker": worker, "i": i})
        digest = store.put_configuration(config)
        store.put_values(digest, [
            PropertyValue(name="m", value=float(worker * 1000 + i),
                          experiment_id=f"exp-{worker}"),
        ])
        store.append_record(SPACE_ID, OP_ID, digest, "measured")


def hammer_process(path: str, worker: int, iterations: int) -> None:
    hammer(SampleStore(path), worker, iterations)


def append_mixed(store: SampleStore, worker: int, rounds: int,
                 batch: int) -> None:
    """One writer's record-append workload for the seq-invariant test: rounds
    alternate between single ``append_record`` calls and ``append_records``
    batches, all against ONE (space, operation)."""
    for i in range(rounds):
        if i % 2 == 0:
            store.append_record(SPACE_ID, OP_ID, f"w{worker}-r{i}", "measured")
        else:
            store.append_records(SPACE_ID, OP_ID, [
                (f"w{worker}-r{i}-b{j}", "measured") for j in range(batch)])


def append_mixed_process(path: str, worker: int, rounds: int,
                         batch: int) -> None:
    append_mixed(SampleStore(path), worker, rounds, batch)
