"""Integration: the multi-pod dry-run entry point runs end-to-end.

The dry-run needs 512 placeholder devices via XLA_FLAGS *before* jax
initializes, so it must run in a subprocess (this test process already owns
a 1-device jax).
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=420, env=env, cwd=REPO)


@pytest.mark.slow
def test_dryrun_single_pod_cell():
    p = _run_dryrun("--arch", "xlstm-125m", "--shape", "decode_32k",
                    "--mesh", "single")
    assert p.returncode == 0, p.stdout + p.stderr
    assert "OK" in p.stdout
    assert "memory_analysis" in p.stdout and "cost_analysis" in p.stdout


@pytest.mark.slow
def test_dryrun_multi_pod_cell_and_skip_reasons():
    p = _run_dryrun("--arch", "hubert-xlarge", "--shape", "all",
                    "--mesh", "multi")
    assert p.returncode == 0, p.stdout + p.stderr
    assert "SKIP — encoder-only" in p.stdout       # decode shapes skipped
    assert p.stdout.count("OK") == 2               # train_4k + prefill_32k
    # artifact written with roofline terms
    path = os.path.join(REPO, "experiments", "dryrun",
                        "hubert-xlarge__train_4k__2x16x16.json")
    assert os.path.exists(path)
    with open(path) as f:
        r = json.load(f)
    assert r["status"] == "ok"
    for key in ("compute_s", "memory_s", "collective_s", "dominant"):
        assert key in r["roofline"]
