"""Actuation-lifecycle tests (paper: experiments are *cloud actuations*).

Four guarantees matter:

* **lifecycle semantics** — provision/run/parse/teardown with per-phase
  retries on the injected clock, idempotent teardown on every exit path,
  and per-second provisioned billing that charges failed trials too;
* **failure provenance** — exhausted retries surface as ``MeasurementError``
  carrying a ``FailureRecord`` (phase, reason, attempts, cost) that the
  execution layer persists and ``failure_summary`` aggregates (legacy
  failed records backfill as phase ``"unknown"``; a reaped zombie's stale
  failure row is never double-counted);
* **trace replay fidelity** — a recorded trace replayed through the full
  ``sample → store`` path reproduces the live run byte-for-byte (records,
  property values including ``provisioned_cost``, failure rows) on both the
  sqlite and the served store, with zero real sleeps under ``FakeClock``;
* **backend conformance** — a flaky connector behaves identically through
  all four execution backends: same retry counts, same teardowns, same
  billed failures.
"""

import signal
import subprocess
import sys
import threading
import time

import pytest

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, MeasurementError, ProbabilitySpace,
                        SampleStore)
from repro.core.actions import ProvisioningError
from repro.core.api.spec import ConnectorSpec
from repro.core.clock import FakeClock
from repro.core.connector import (Deployment, DimensionPricing,
                                  ExperimentConnector, FlatPricing,
                                  LifecycleExperiment, RetryPolicy,
                                  TraceConnector, load_trace,
                                  pricing_from_json, record_trace)
from repro.core.execution.worker import run_worker
from repro.core.store.client import ClientStore

from _connector_workers import (_SRC, FLAKES, POISON_X, build_flaky_ds,
                                counter, state_dir_for)

RETRY = RetryPolicy(provision_attempts=3, backoff_s=2.0, backoff_factor=2.0,
                    jitter=0.0)
PRICING = FlatPricing(rate_per_s=0.01)


class VirtualCloud(ExperimentConnector):
    """Scripted cloud on a virtual clock: deterministic phase durations,
    ``x == 1`` flakes once at provisioning, ``x == 2`` never provisions."""

    name = "vcloud"
    version = "1"
    PROVISION_S = 5.0
    RUN_S = 10.0

    def __init__(self, clock):
        self.clock = clock
        self._attempts = {}

    @property
    def parameterization(self):
        return {"cloud": "virtual"}

    @property
    def observed_properties(self):
        return ("lat",)

    def provision(self, configuration):
        self.clock.sleep(self.PROVISION_S)
        d = configuration.digest
        n = self._attempts[d] = self._attempts.get(d, 0) + 1
        if configuration["x"] == 2:
            raise ProvisioningError(f"zone outage (attempt {n})")
        if configuration["x"] == 1 and n == 1:
            raise ProvisioningError("insufficient capacity")
        return Deployment(ident=f"v-{d[:8]}", configuration=configuration,
                          handle=d)

    def run(self, deployment):
        self.clock.sleep(self.RUN_S)
        return {"lat": self.RUN_S + deployment.configuration["x"]}


def _vclock_experiment():
    clock = FakeClock()
    return LifecycleExperiment(VirtualCloud(clock), retry=RETRY,
                               pricing=PRICING, clock=clock)


def _vspace():
    return ProbabilitySpace.make([Dimension.discrete("x", [0, 1, 2, 3])])


def _vconfigs():
    return [Configuration.make({"x": v}) for v in (0, 1, 2, 3)]


# ------------------------------------------------------ lifecycle semantics


def test_lifecycle_bills_every_provisioned_second():
    """Billing covers provision start through teardown across all attempts
    — backoff waits are not provisioned time and are free."""
    exp = _vclock_experiment()
    # clean trial: 5 s provision + 10 s run window, at $0.01/s
    out = exp.measure(Configuration.make({"x": 0}))
    assert out == {"lat": 10.0, "provisioned_cost": pytest.approx(0.15)}
    # one flake: two 5 s provision attempts billed, 2 s backoff free
    out = exp.measure(Configuration.make({"x": 1}))
    assert out == {"lat": 11.0, "provisioned_cost": pytest.approx(0.20)}


def test_retry_exhaustion_carries_failure_record():
    """Exhausted provisioning retries fail with phase/attempts/cost
    provenance — three billed 5 s attempts, backoffs free."""
    exp = _vclock_experiment()
    with pytest.raises(MeasurementError) as ei:
        exp.measure(Configuration.make({"x": 2}))
    rec = ei.value.failure
    assert rec is not None
    assert rec.phase == "provision"
    assert rec.attempts == 3
    assert rec.cost == pytest.approx(0.15)
    assert "zone outage" in rec.reason


class _TearCloud(ExperimentConnector):
    name = "tear"
    version = "1"

    def __init__(self, run_raises=None, parse_raises=None):
        self.run_raises = run_raises
        self.parse_raises = parse_raises
        self.torn = 0

    @property
    def parameterization(self):
        return {}

    @property
    def observed_properties(self):
        return ("m",)

    def provision(self, configuration):
        return Deployment(ident="t", configuration=configuration, handle="h")

    def run(self, deployment):
        if self.run_raises is not None:
            raise self.run_raises
        return {"m": 1.0}

    def parse(self, raw):
        if self.parse_raises is not None:
            raise self.parse_raises
        return dict(raw)

    def teardown(self, deployment):
        self.torn += 1


def test_teardown_exactly_once_on_every_exit_path():
    # success
    conn = _TearCloud()
    assert LifecycleExperiment(conn).measure(Configuration.make({"x": 0})) \
        == {"m": 1.0}
    assert conn.torn == 1
    # run fails terminally: torn down, phase provenance says "run"
    conn = _TearCloud(run_raises=MeasurementError("benchmark OOM"))
    with pytest.raises(MeasurementError) as ei:
        LifecycleExperiment(conn).measure(Configuration.make({"x": 0}))
    assert conn.torn == 1 and ei.value.failure.phase == "run"
    # parse fails: torn down, phase "parse"
    conn = _TearCloud(parse_raises=MeasurementError("garbled metrics"))
    with pytest.raises(MeasurementError) as ei:
        LifecycleExperiment(conn).measure(Configuration.make({"x": 0}))
    assert conn.torn == 1 and ei.value.failure.phase == "parse"
    # crash (experiment bug): infrastructure still released, crash propagates
    conn = _TearCloud(run_raises=RuntimeError("wild pointer"))
    with pytest.raises(RuntimeError):
        LifecycleExperiment(conn).measure(Configuration.make({"x": 0}))
    assert conn.torn == 1


def test_run_phase_retries_infrastructure_flakes_on_same_deployment():
    class FlakyRun(_TearCloud):
        calls = 0

        def run(self, deployment):
            FlakyRun.calls += 1
            if FlakyRun.calls < 3:
                raise ProvisioningError("ssh reset by peer")
            return {"m": 7.0}

    conn = FlakyRun()
    exp = LifecycleExperiment(
        conn, retry=RetryPolicy(run_attempts=3, backoff_s=0.0, jitter=0.0))
    assert exp.measure(Configuration.make({"x": 0})) == {"m": 7.0}
    assert FlakyRun.calls == 3
    assert conn.torn == 1  # retries reuse the deployment; one teardown


# -------------------------------------------------------- policies & pricing


def test_retry_policy_validation_and_deterministic_jitter():
    with pytest.raises(ValueError):
        RetryPolicy(provision_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_factor=0.5)
    plain = RetryPolicy(backoff_s=1.0, backoff_factor=2.0, jitter=0.0)
    assert [plain.delay(a) for a in (1, 2, 3)] == [1.0, 2.0, 4.0]
    capped = RetryPolicy(backoff_s=1.0, max_backoff_s=5.0, jitter=0.1)
    assert capped.delay(10, "k") <= 5.0 * 1.1
    # jitter is keyed content-hash, not a live RNG: replays are identical
    assert capped.delay(2, "digest-a") == capped.delay(2, "digest-a")
    assert capped.delay(2, "digest-a") != capped.delay(2, "digest-b")
    assert RetryPolicy.from_json(capped.to_json()) == capped


def test_pricing_models_and_round_trip():
    flat = FlatPricing(rate_per_s=0.5)
    c = Configuration.make({"inst": "a"})
    assert flat.cost(c, 10.0) == 5.0
    assert flat.cost(c, -1.0) == 0.0  # clock skew never refunds
    dim = DimensionPricing(dimension="inst",
                           rates=(("a", 1.0), ("b", 2.5)), default=9.0)
    assert dim.rate(Configuration.make({"inst": "b"})) == 2.5
    assert dim.rate(Configuration.make({"inst": "zz"})) == 9.0
    assert pricing_from_json(flat.to_json()) == flat
    assert pricing_from_json(dim.to_json()) == dim
    with pytest.raises(ValueError, match="unknown pricing kind"):
        pricing_from_json({"kind": "spot"})


def test_experiment_for_matches_linear_scan():
    """The cached property→experiment map must agree with a linear scan of
    the action space for every observed property."""
    e1 = FunctionExperiment(fn=lambda c: {"a": 1.0, "b": 2.0},
                            properties=("a", "b"), name="one")
    e2 = FunctionExperiment(fn=lambda c: {"c": 3.0}, properties=("c",),
                            name="two")
    actions = ActionSpace.make([e1, e2])
    for prop in ("a", "b", "c"):
        scan = next(e for e in actions.experiments
                    if prop in e.observed_properties)
        assert actions.experiment_for(prop) is scan
    with pytest.raises(KeyError):
        actions.experiment_for("nope")


def test_tuning_shim_identity_preserved():
    """The compatibility shims keep the monolithic experiments' identity:
    same identifier as the bare connector behind the adapter, unchanged by
    a retry policy (robustness, not surface) — while pricing, which adds
    the ``provisioned_cost`` property, is honestly a different surface."""
    from repro.tuning.experiments import WalltimeConnector, WalltimeExperiment

    shim = WalltimeExperiment("nano", repeats=2)
    bare = LifecycleExperiment(WalltimeConnector("nano", repeats=2))
    assert (shim.name, shim.version) == ("walltime", "1")
    assert shim.identifier == bare.identifier
    retried = WalltimeExperiment("nano", repeats=2,
                                 retry=RetryPolicy(provision_attempts=5))
    assert retried.identifier == shim.identifier
    priced = WalltimeExperiment("nano", repeats=2, pricing=FlatPricing(1.0))
    assert priced.identifier != shim.identifier
    assert "provisioned_cost" in priced.observed_properties
    assert "provisioned_cost" not in shim.observed_properties


# ------------------------------------------------------- failure provenance


def test_store_failure_primitives(tmp_path):
    store = SampleStore(str(tmp_path / "f.db"))
    store.record_failure("d1", "exp-a", "provision", "zone outage",
                         attempts=3, cost=0.5)
    store.record_failure("d1", "exp-b", "run", "OOM")
    rows = store.failures_for("d1")
    assert [(r["experiment_id"], r["phase"], r["attempts"], r["cost"])
            for r in rows] == [("exp-a", "provision", 3, 0.5),
                               ("exp-b", "run", 1, 0.0)]
    assert [r["phase"] for r in store.failures_for("d1", "exp-a")] \
        == ["provision"]
    assert store.failures_for("other") == []


def test_failure_summary_backfills_legacy_rows_as_unknown(tmp_path):
    store = SampleStore(str(tmp_path / "f.db"))
    sp = "space-1"
    # a pre-provenance failed record: no failures row at all
    store.append_record(sp, "op", "legacy-digest", "failed")
    # a modern one with structured provenance
    store.append_record(sp, "op", "modern-digest", "failed")
    store.record_failure("modern-digest", "exp-a", "provision", "outage",
                         attempts=2, cost=1.25)
    assert store.failure_summary(sp) == {
        "unknown": {"count": 1, "cost": 0.0},
        "provision": {"count": 1, "cost": 1.25},
    }


def test_zombie_failure_rows_never_double_charge(tmp_path):
    """A worker that died mid-trial leaves a failure row; after lease
    reaping the re-executing owner writes another.  ``failures_for`` keeps
    the full audit trail, but the summary counts each failed record once —
    against the LATEST row only."""
    store = SampleStore(str(tmp_path / "f.db"))
    sp = "space-1"
    store.append_record(sp, "op", "d1", "failed")
    store.record_failure("d1", "exp-a", "provision", "outage", 3, 5.0)
    store.record_failure("d1", "exp-a", "provision", "outage", 3, 7.0)
    assert len(store.failures_for("d1")) == 2  # audit trail intact
    assert store.failure_summary(sp) == {
        "provision": {"count": 1, "cost": 7.0}}


# ------------------------------------------------------------ spec plumbing


def test_connector_spec_round_trip_and_strict_parse(tmp_path):
    import json

    spec = ConnectorSpec(factory="trace-replay",
                         params={"path": "t.jsonl"},
                         retry=RetryPolicy(provision_attempts=4, jitter=0.0),
                         pricing=FlatPricing(rate_per_s=0.25),
                         virtual_clock=True)
    assert ConnectorSpec.from_json(
        json.loads(json.dumps(spec.to_json()))) == spec
    with pytest.raises(ValueError):
        ConnectorSpec.from_json({"params": {}})  # factory required
    with pytest.raises(ValueError, match="unknown"):
        ConnectorSpec.from_json({"factory": "f", "retry": {"attempts": 3}})
    with pytest.raises(ValueError, match="unknown"):
        ConnectorSpec.from_json(
            {"factory": "f", "pricing": {"kind": "flat", "rate": 1}})
    with pytest.raises(ValueError, match="unknown"):
        ConnectorSpec.from_json({"factory": "f", "clock": "fake"})


def test_connector_spec_rejects_ignored_knobs_on_ready_experiments(tmp_path):
    """``trace-replay`` returns a ready experiment that manages its own
    retry/pricing/clock; setting them on the spec too must fail loudly
    instead of being silently ignored."""
    path = str(tmp_path / "t.jsonl")
    exp = _vclock_experiment()
    record_trace(exp, _vconfigs()[:1], path=path, clock=exp.clock)
    ok = ConnectorSpec(factory="trace-replay", params={"path": path}).build()
    assert ok.name == "vcloud"
    bad = ConnectorSpec(factory="trace-replay", params={"path": path},
                        retry=RetryPolicy())
    with pytest.raises(ValueError, match="ignored"):
        bad.build()


# -------------------------------------------------- trace capture & replay


def _sampled_state(ds, op, digests):
    """Everything observable about a finished operation, minus wall-clock
    timestamps: the sampling record, the reconciled sample set (property
    values AND their experiment provenance), and the failure accounting."""
    recs = [(r.seq, r.config_digest, r.action) for r in ds.timeseries(op)]
    samples = sorted(
        (s.configuration.digest,
         sorted((v.name, v.value, v.experiment_id)
                for v in s.properties.values()))
        for s in ds.read())
    fails = {d: [{k: r[k] for k in ("phase", "reason", "attempts", "cost")}
                 for r in ds.store.failures_for(d)] for d in digests}
    return recs, samples, fails, ds.store.failure_summary(ds.space_id)


def test_trace_replay_byte_identical_through_store(tmp_path):
    """Acceptance gate: a recorded trace replayed through the full
    ``sample → store`` path reproduces the live run exactly — same records,
    same property values (``provisioned_cost`` included), same failure rows
    — while advancing only *virtual* time."""
    path = str(tmp_path / "trace.jsonl")
    rec_exp = _vclock_experiment()
    header, trials = record_trace(rec_exp, _vconfigs(), path=path,
                                  clock=rec_exp.clock)
    assert header["retry"] == RETRY.to_json()
    assert header["pricing"] == PRICING.to_json()
    assert [t["properties"] is None for t in trials] \
        == [False, False, True, False]
    # the flaky trial recorded its true retry sequence
    assert [a["ok"] for a in trials[1]["attempts"]
            if a["phase"] == "provision"] == [False, True]

    # live reference through the full path
    ds_live = DiscoverySpace(space=_vspace(),
                             actions=ActionSpace.make([_vclock_experiment()]),
                             store=SampleStore(str(tmp_path / "live.db")))
    res = ds_live.sample_batch(_vconfigs(), operation_id="op")
    assert [r.action for r in res] \
        == ["measured", "measured", "failed", "measured"]

    # replay from the recording: zero cloud calls, zero real sleeps
    clock = FakeClock()
    replay = LifecycleExperiment(
        TraceConnector(path, clock=clock),
        retry=RetryPolicy.from_json(header["retry"]),
        pricing=pricing_from_json(header["pricing"]), clock=clock)
    ds_replay = DiscoverySpace(space=_vspace(),
                               actions=ActionSpace.make([replay]),
                               store=SampleStore(str(tmp_path / "replay.db")))
    wall0, virt0 = time.perf_counter(), clock.time()
    res2 = ds_replay.sample_batch(_vconfigs(), operation_id="op")
    wall = time.perf_counter() - wall0
    assert [r.action for r in res2] == [r.action for r in res]
    digests = [c.digest for c in _vconfigs()]
    assert _sampled_state(ds_replay, "op", digests) \
        == _sampled_state(ds_live, "op", digests)
    # the ~73 recorded seconds passed virtually, not in wall-clock
    assert clock.time() - virt0 >= 40.0
    assert wall < 5.0


def test_trace_replay_is_idempotent_per_digest(tmp_path):
    """Re-measuring a digest replays the same recording again (the cursor
    resets after teardown), so reuse-vs-remeasure decisions upstream never
    desynchronize the replay."""
    path = str(tmp_path / "trace.jsonl")
    exp = _vclock_experiment()
    record_trace(exp, _vconfigs(), path=path, clock=exp.clock)
    clock = FakeClock()
    header, _ = load_trace(path)
    replay = LifecycleExperiment(TraceConnector(path, clock=clock),
                                 retry=RetryPolicy.from_json(header["retry"]),
                                 pricing=pricing_from_json(header["pricing"]),
                                 clock=clock)
    c = Configuration.make({"x": 1})
    first = replay.measure(c)
    second = replay.measure(c)
    assert first == second
    with pytest.raises(MeasurementError, match="not in the recorded trace"):
        replay.measure(Configuration.make({"x": 99}))


def _start_server(db, sock):
    env = dict(__import__("os").environ)
    env["PYTHONPATH"] = _SRC + ":" + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.core.store.server",
         "--db", db, "--unix", sock],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
    line = proc.stdout.readline()
    assert line.startswith("STORE_URL="), f"unexpected server output: {line!r}"
    return proc, line.strip().split("=", 1)[1]


def _stop_server(proc):
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait(timeout=10)
    proc.stdout.close()


def test_trace_replay_identical_through_served_store(tmp_path):
    """The same replay against a server-mediated store lands the same
    records, failure rows, and summary as against local sqlite — the
    failure-provenance protocol frames carry everything across the wire."""
    path = str(tmp_path / "trace.jsonl")
    exp = _vclock_experiment()
    header, _ = record_trace(exp, _vconfigs(), path=path, clock=exp.clock)

    def replay_into(store):
        clock = FakeClock()
        replay = LifecycleExperiment(
            TraceConnector(path, clock=clock),
            retry=RetryPolicy.from_json(header["retry"]),
            pricing=pricing_from_json(header["pricing"]), clock=clock)
        ds = DiscoverySpace(space=_vspace(),
                            actions=ActionSpace.make([replay]), store=store)
        ds.sample_batch(_vconfigs(), operation_id="op")
        return ds

    ds_local = replay_into(SampleStore(str(tmp_path / "local.db")))
    proc, url = _start_server(str(tmp_path / "served.db"),
                              str(tmp_path / "served.sock"))
    try:
        ds_served = replay_into(ClientStore(url, retries=8))
        digests = [c.digest for c in _vconfigs()]
        assert _sampled_state(ds_served, "op", digests) \
            == _sampled_state(ds_local, "op", digests)
    finally:
        _stop_server(proc)


# --------------------------------------------------- cross-backend conformance


@pytest.mark.parametrize("backend", ["serial", "thread", "process", "queue"])
def test_flaky_connector_conformance_across_backends(tmp_path, backend):
    """Satellite gate: the lifecycle behaves identically through every
    execution backend — healthy trials retried to success (exactly
    ``FLAKES`` flakes each, one teardown), the poison trial exhausts its
    attempts, is billed, and lands a provision-phase failure row."""
    path = str(tmp_path / "store.db")
    ds = build_flaky_ds(path)
    sd = state_dir_for(path)
    configs = [Configuration.make({"x": v}) for v in (0, 1, 2, 3)]
    workers = []
    if backend == "queue":
        workers = [threading.Thread(target=run_worker,
                                    args=(build_flaky_ds(path),),
                                    kwargs={"idle_timeout_s": 1.0,
                                            "owner": f"w{i}"})
                   for i in range(2)]
        for t in workers:
            t.start()
    kwargs = {"workers": 2} if backend in ("thread", "process") else {}
    results = ds.sample_batch(configs, operation_id="op", backend=backend,
                              **kwargs)
    for t in workers:
        t.join()
    assert [r.action for r in results] \
        == ["measured", "measured", "failed", "measured"]

    exp = ds.actions.experiments[0]
    for c in configs:
        assert counter(sd, "provision", c.digest) == FLAKES + 1
        expected_teardowns = 0 if c["x"] == POISON_X else 1
        assert counter(sd, "teardown", c.digest) == expected_teardowns

    poison = configs[POISON_X]
    rows = ds.store.failures_for(poison.digest)
    assert len(rows) == 1
    assert rows[0]["phase"] == "provision"
    assert rows[0]["attempts"] == FLAKES + 1
    assert "zone outage" in rows[0]["reason"]
    assert rows[0]["experiment_id"] == exp.identifier
    assert ds.store.failure_summary(ds.space_id) == {
        "provision": {"count": 1,
                      "cost": pytest.approx(rows[0]["cost"], abs=1e-12)}}
    # successful trials carry their billed cost as an ordinary property
    samples = list(ds.read())
    assert len(samples) == 3
    for s in samples:
        names = {v.name for v in s.properties.values()}
        assert "provisioned_cost" in names
