"""Tests for the LLM deployment-space family (:mod:`repro.workloads.llm`).

The family turns in-repo models into related Discovery Spaces: five shared
deployment dimensions, member knobs (seq_len, devices) in the connector
parameterization, a catalog ``family`` block marking siblings.  Pinned
here: member space construction, the dryrun tier's measurement and its
non-deployable paths, catalog relatedness across the family's member
shifts (exact seq-shift match, positionally inferred mesh/kernel renames,
disjoint-dimension and family-filter exclusion of non-siblings), the spec
round-trip with the new ``meta``/``predict_remaining`` fields, and the
end-to-end sibling transfer with the step-⑧ predict-remaining sweep.
"""

import math

import pytest

from repro.core import (ActionSpace, Configuration, DiscoverySpace,
                        Dimension, FunctionExperiment, Investigation,
                        MeasurementError, ProbabilitySpace, SampleStore,
                        SpaceCatalog)
from repro.core.api.spec import InvestigationSpec, TransferSpec
from repro.workloads.llm import (DeploymentSpaceFamily, FAMILY_NAME,
                                 LLMDryrunConnector, LLMWalltimeConnector)

ARCH = "nano-100m"


@pytest.fixture(scope="module")
def family():
    return DeploymentSpaceFamily(ARCH)


def a_config(mesh="2x2", sharding="fsdp", batch=2, kernel="xla",
             precision="bf16"):
    return Configuration.make({"mesh": mesh, "sharding": sharding,
                               "batch": batch, "kernel": kernel,
                               "precision": precision})


# ------------------------------------------------------------- construction


def test_member_space_has_the_five_deployment_dimensions(family):
    space = family.space(4)
    assert list(space.names) == ["mesh", "sharding", "batch", "kernel",
                                 "precision"]
    assert space.dimension("mesh").values == ("1x4", "2x2", "4x1")
    assert space.size == 3 * 2 * 4 * 3 * 2

    # topology-shift sibling: mesh labels move, cardinality and order stay
    assert family.space(8).dimension("mesh").values == ("1x8", "2x4", "8x1")
    assert family.space(8).size == space.size


def test_family_rejects_unknown_arch_kind_and_tier(family):
    with pytest.raises(ValueError):
        DeploymentSpaceFamily("no-such-model")
    with pytest.raises(ValueError):
        DeploymentSpaceFamily(ARCH, kind="finetune")
    with pytest.raises(ValueError):
        family.family_meta(512, 4, tier="quantum")
    with pytest.raises(ValueError):
        family.connector(512, 4, tier="quantum")


def test_members_share_the_family_block_and_differ_in_member_knobs(family):
    a = family.family_meta(512, 4, "dryrun")
    b = family.family_meta(1024, 8, "walltime")
    assert a["family"] == b["family"] == {
        "name": FAMILY_NAME, "arch": ARCH, "kind": "train"}
    assert a["member"] != b["member"]
    assert a["member"]["tier"] == "dryrun" and b["member"]["tier"] == "walltime"


def test_member_registers_family_meta_in_the_catalog(family):
    store = SampleStore(":memory:")
    ds = family.member(seq_len=512, devices=4, store=store)
    entry = SpaceCatalog(store).get(ds.space_id)
    assert entry.family == {"name": FAMILY_NAME, "arch": ARCH, "kind": "train"}
    assert entry.meta["member"] == {"seq_len": 512, "devices": 4,
                                    "tier": "dryrun", "hw": "tpu-v5e"}
    # the reserved registration keys are still the space's own
    assert entry.meta["size"] == ds.space.size


def test_same_member_knobs_different_seq_len_are_distinct_spaces(family):
    store = SampleStore(":memory:")
    a = family.member(seq_len=512, devices=4, store=store)
    b = family.member(seq_len=1024, devices=4, store=store)
    # identical Ω (same digest), distinct Discovery Spaces: the member knob
    # lives in the experiment parameterization (the FT-TRANS pattern)
    assert a.space.digest == b.space.digest
    assert a.space_id != b.space_id


# ---------------------------------------------------------------- measurement


def test_dryrun_member_measures_end_to_end(family):
    ds = family.member(seq_len=512, devices=4, store=SampleStore(":memory:"))
    results = ds.sample_batch(list(ds.remaining_configurations())[:6],
                              operation_id="op")
    assert all(r.ok for r in results)
    for r in results:
        s = r.sample
        assert s.value("step_time_s") > 0
        assert s.value("tokens_per_s") > 0
        assert s.value("cost_per_1m_tokens") > 0
        # max-of-terms roofline: the step is at least its compute term
        assert s.value("step_time_s") >= s.value("compute_s")


def test_dryrun_hbm_cap_is_a_non_deployable_point():
    conn = LLMDryrunConnector(ARCH, seq_len=512, devices=4,
                              hbm_fraction=1e-6)
    dep = conn.provision(a_config())
    raw = conn.run(dep)
    with pytest.raises(MeasurementError, match="over HBM"):
        conn.parse(raw)


def test_mesh_topology_mismatch_is_terminal_at_provision():
    conn = LLMDryrunConnector(ARCH, seq_len=512, devices=8)
    with pytest.raises(MeasurementError, match="non-deployable"):
        conn.provision(a_config(mesh="2x2"))  # 4 chips on an 8-chip member


def test_walltime_more_devices_than_host_is_non_deployable():
    conn = LLMWalltimeConnector(ARCH, seq_len=32, devices=4096)
    with pytest.raises(MeasurementError, match="non-deployable"):
        conn.provision(a_config(mesh="1x4096"))


def test_walltime_parse_survives_zero_elapsed_time():
    # a virtual clock can legitimately observe zero elapsed seconds; the
    # parse guard must keep tokens_per_s finite instead of dividing by zero
    conn = LLMWalltimeConnector(ARCH, seq_len=32)
    out = conn.parse((0.0, {"batch": 2, "seq": 32}))
    assert out["step_time_s"] > 0
    assert math.isfinite(out["tokens_per_s"])


# -------------------------------------------------------------- relatedness


def seeded_member(family, store, seq_len, devices, n=8):
    ds = family.member(seq_len=seq_len, devices=devices, store=store)
    ds.sample_batch(list(ds.remaining_configurations())[:n],
                    operation_id="op")
    return ds


def test_seq_shift_sibling_is_an_exact_dimension_match(family):
    store = SampleStore(":memory:")
    src = seeded_member(family, store, 512, 4)
    tgt = family.member(seq_len=1024, devices=4, store=store)
    rel = SpaceCatalog(store).find_related(tgt.space, exclude=[tgt.space_id],
                                           metric="step_time_s")
    assert [r.entry.space_id for r in rel] == [src.space_id]
    assert rel[0].exact and rel[0].mapping == {}


def test_topology_shift_bridged_by_positional_mesh_rename(family):
    store = SampleStore(":memory:")
    src = seeded_member(family, store, 512, 4)
    tgt_space = family.space(8)
    rel = SpaceCatalog(store).find_related(tgt_space, metric="step_time_s")
    assert [r.entry.space_id for r in rel] == [src.space_id]
    # the mesh labels changed but kept cardinality and semantic order, so
    # the catalog inferred the positional rename (§IV-1) and flagged it
    assert rel[0].mapping == {"mesh": {"1x4": "1x8", "2x2": "2x4",
                                       "4x1": "8x1"}}
    assert rel[0].inferred_dims == ("mesh",)
    assert not rel[0].exact


def test_kernel_variant_rename_is_positionally_inferred(family):
    store = SampleStore(":memory:")
    src = seeded_member(family, store, 512, 4)
    # the same member knobs with a renamed kernel dimension (e.g. a vendor
    # kernel suite): same cardinality, same semantic order
    variant = DeploymentSpaceFamily(
        ARCH, kernels=("vendor-ref", "vendor-xla", "vendor-flash"))
    rel = SpaceCatalog(store).find_related(variant.space(4),
                                           metric="step_time_s")
    assert [r.entry.space_id for r in rel] == [src.space_id]
    assert rel[0].mapping == {"kernel": {"ref": "vendor-ref",
                                         "xla": "vendor-xla",
                                         "flash": "vendor-flash"}}
    assert rel[0].inferred_dims == ("kernel",)


def test_non_sibling_model_spaces_with_disjoint_dimensions_never_match(family):
    store = SampleStore(":memory:")
    seeded_member(family, store, 512, 4)
    # a different workload's deployment space: no shared dimension names
    other = ProbabilitySpace.make([
        Dimension.categorical("instance", ["m5.large", "c5.xlarge"]),
        Dimension.discrete("workers", [1, 2, 4]),
    ])
    cat = SpaceCatalog(store)
    assert cat.find_related(other, metric="step_time_s") == []
    assert cat.find_related(other, min_overlap=0.0) == []


def test_family_filter_excludes_dimension_twins_outside_the_family(family):
    store = SampleStore(":memory:")
    src = seeded_member(family, store, 512, 4)
    # an impostor space with the SAME five dimensions but no family block
    # (a different model that happens to share knob names)
    exp = FunctionExperiment(fn=lambda c: {"step_time_s": 1.0},
                             properties=("step_time_s",), name="impostor")
    twin = DiscoverySpace(space=family.space(4),
                          actions=ActionSpace.make([exp]), store=store)
    twin.sample_batch(list(twin.remaining_configurations())[:4],
                      operation_id="op")
    cat = SpaceCatalog(store)
    unfiltered = cat.find_related(family.space(8), metric="step_time_s")
    assert {r.entry.space_id for r in unfiltered} == {src.space_id,
                                                      twin.space_id}
    filtered = cat.find_related(family.space(8), metric="step_time_s",
                                family=family.family_meta(512, 4,
                                                          "dryrun")["family"])
    assert [r.entry.space_id for r in filtered] == [src.space_id]


# --------------------------------------------------------------------- spec


def test_investigation_spec_roundtrips_with_meta_and_predict_remaining(family):
    spec = family.investigation_spec(
        seq_len=512, devices=4, optimizer="tpe", max_trials=5, patience=5,
        transfer=TransferSpec(enabled=True, predict_remaining=True))
    d = spec.to_json()
    spec2 = InvestigationSpec.from_json(d)
    assert spec2.to_json() == d
    assert spec2.meta == family.family_meta(512, 4, "dryrun")
    assert spec2.transfer.predict_remaining is True
    assert spec2.connectors[0].factory == "llm-dryrun"
    assert spec2.connectors[0].params["arch"] == ARCH
    # predict_remaining defaults off and survives an explicit false
    assert TransferSpec.from_json(
        TransferSpec(enabled=True).to_json()).predict_remaining is False


def test_spec_path_builds_the_same_experiment_identity(family):
    store = SampleStore(":memory:")
    programmatic = family.member(seq_len=512, devices=4, store=store)
    spec = family.investigation_spec(seq_len=512, devices=4, max_trials=2,
                                     patience=3)
    inv = Investigation(spec, store=store)
    assert inv.ds.space_id == programmatic.space_id


def test_e2e_sibling_transfer_with_predict_remaining_sweep(family):
    store = SampleStore(":memory:")
    # the prior study: the short-sequence member, measured exhaustively at
    # the fast tier
    src = family.member(seq_len=512, devices=4, store=store)
    src.sample_batch(list(src.remaining_configurations()),
                     operation_id="historical-study")
    spec = family.investigation_spec(
        seq_len=1024, devices=4, optimizer="random", seed=0,
        max_trials=6, patience=7,
        transfer=TransferSpec(enabled=True, selection="clustering",
                              max_representatives=8, predict_remaining=True))
    res = Investigation(spec, store=store).run()
    t = res.transfer
    assert t is not None and t.applied
    assert t.source_space_id == src.space_id
    # the step-⑧ sweep landed the predicted surface in its own A*_pred
    # space, distinct from the member being searched
    assert t.n_predicted > 0
    assert t.predicted_space_id is not None
    assert t.predicted_space_id != Investigation(spec, store=store).ds.space_id
    assert t.summary()["predicted"] == t.n_predicted
    assert t.summary()["predicted_space_id"] == t.predicted_space_id
