"""Tests for the deployment Discovery Space (the paper's technique applied
to the framework itself)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ActionSpace, Configuration, DiscoverySpace, SampleStore
from repro.tuning.deployment import (deployment_from_configuration,
                                     deployment_space)
from repro.tuning.experiments import WalltimeExperiment


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_deployment_space_dimensions_per_family(mesh):
    dense = deployment_space(get_config("stablelm-12b"), mesh, "train", 256)
    moe = deployment_space(get_config("granite-moe-3b-a800m"), mesh, "train", 256)
    ssm = deployment_space(get_config("xlstm-125m"), mesh, "train", 256)
    assert "moe_capacity_factor" in moe.names
    assert "moe_shard" in moe.names
    assert "moe_capacity_factor" not in dense.names
    assert "mlstm_chunk" in ssm.names
    assert "microbatches" in dense.names
    # decode shapes don't get microbatches
    dec = deployment_space(get_config("stablelm-12b"), mesh, "decode", 128)
    assert "microbatches" not in dec.names


def test_deployment_from_configuration_roundtrip(mesh):
    cfg = get_config("granite-moe-3b-a800m")
    space = deployment_space(cfg, mesh, "train", 256)
    c = Configuration.make({
        "remat": "full", "attn_q_chunk": 256, "attn_kv_chunk": 1024,
        "band_skip": False, "embed_rule": "none", "microbatches": 4,
        "moe_capacity_factor": 2.0, "moe_shard": "expert_parallel",
        "param_cast": "once",
    })
    assert space.contains(c)
    dep = deployment_from_configuration(c, cfg, mesh, "train", 256, 4096)
    assert dep.remat == "full"
    assert dep.cast_params_once is True
    assert dep.attn_q_chunk == 256 and dep.attn_kv_chunk == 1024
    assert dep.band_skip is False
    assert dep.microbatches == 4
    assert dep.moe_capacity_factor == 2.0
    assert dep.rule("embed") is None
    assert dep.rule("experts") == "model"
    assert dep.rule("moe_mlp") is None


def test_deployment_moe_shard_choices_respect_divisibility():
    mesh16 = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("granite-moe-3b-a800m")  # 40 experts
    space = deployment_space(cfg, mesh16, "train", 256)
    shard_dim = space.dimension("moe_shard")
    # on a 1-wide model axis everything divides
    assert "expert_parallel" in shard_dim.values


def test_walltime_experiment_measures(mesh):
    exp = WalltimeExperiment("xlstm-125m", repeats=1)
    c = Configuration.make({"batch": 1, "seq": 32, "attn_q_chunk": 16,
                            "remat": "none"})
    out = exp.measure(c)
    assert out["step_ms"] > 0
    assert out["tokens_per_s"] > 0
    # identity is stable and parameterized by arch
    exp2 = WalltimeExperiment("deepseek-67b", repeats=1)
    assert exp.identifier != exp2.identifier


def test_walltime_discovery_space_end_to_end(mesh):
    from repro.core.optimizers import RandomSearch, run_optimizer

    space_dims = [
        ("batch", [1, 2]),
        ("seq", [32, 64]),
        ("attn_q_chunk", [16, 32]),
    ]
    from repro.core import Dimension, ProbabilitySpace
    space = ProbabilitySpace.make(
        [Dimension.discrete(n, v) for n, v in space_dims]
        + [Dimension.categorical("remat", ["none"])])
    ds = DiscoverySpace(
        space=space,
        actions=ActionSpace.make([WalltimeExperiment("xlstm-125m", repeats=1)]),
        store=SampleStore(":memory:"))
    run = run_optimizer(RandomSearch(seed=0), ds, "step_ms", "min",
                        max_trials=4, patience=4)
    assert run.best is not None
    assert run.best.value > 0


# ----------------------------------------------------------- injectable clock


class TickingClock:
    """A clock whose monotonic() advances a fixed step per call, so every
    timed interval in a connector is exactly one step — deterministic."""

    def __init__(self, step=0.005):
        self.step = step
        self._now = 0.0

    def time(self):
        return self._now

    def monotonic(self):
        self._now += self.step
        return self._now

    def sleep(self, seconds):
        self._now += seconds


class _Ready:
    def block_until_ready(self):
        return self


def test_walltime_connector_times_on_the_injected_clock():
    from repro.core.connector import Deployment
    from repro.tuning.experiments import WalltimeConnector

    clock = TickingClock(step=0.005)
    conn = WalltimeConnector("xlstm-125m", repeats=3, clock=clock)
    dep = Deployment(ident="d", configuration=Configuration.make({}),
                     created_at=clock.time(),
                     handle=(lambda p, b: _Ready(), None, None),
                     meta={"batch": 2, "seq": 8})
    best, meta = conn.run(dep)
    # two monotonic() reads bracket each repeat: every duration is one tick
    assert best == pytest.approx(0.005)
    out = conn.parse((best, meta))
    assert out["step_ms"] == pytest.approx(5.0)
    assert out["tokens_per_s"] == pytest.approx(2 * 8 / 0.005)


def test_walltime_parse_survives_a_frozen_virtual_clock():
    from repro.core.clock import FakeClock
    from repro.core.connector import Deployment
    from repro.tuning.experiments import WalltimeConnector

    conn = WalltimeConnector("xlstm-125m", repeats=2, clock=FakeClock())
    dep = Deployment(ident="d", configuration=Configuration.make({}),
                     created_at=0.0,
                     handle=(lambda p, b: _Ready(), None, None),
                     meta={"batch": 1, "seq": 16})
    best, meta = conn.run(dep)
    assert best == 0.0  # a FakeClock legitimately observes zero elapsed time
    out = conn.parse((best, meta))
    assert out["step_ms"] > 0
    assert np.isfinite(out["tokens_per_s"])


def test_experiment_shims_plumb_the_clock_into_their_connector(mesh):
    from repro.tuning.experiments import DryrunRooflineExperiment

    clock = TickingClock()
    dry = DryrunRooflineExperiment("xlstm-125m", "train-256", mesh,
                                   clock=clock)
    wall = WalltimeExperiment("xlstm-125m", clock=clock)
    assert dry.connector.clock is clock and dry.clock is clock
    assert wall.connector.clock is clock and wall.clock is clock
