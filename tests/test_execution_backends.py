"""Tests for the pluggable execution-backend subsystem.

Three pillars:

* **equivalence** — ``SerialBackend``/``ThreadBackend`` re-host the classic
  engine byte-identically, and the pipelined engine at ``max_inflight=1``
  reproduces the serial trajectory draw-for-draw;
* **crash isolation** — a ``ProcessBackend`` worker that ``os._exit``-s (or
  raises an unexpected error) mid-measurement poisons only its own slot:
  its claims are released so nobody stalls, and the surviving slots'
  sampling records are serial-equivalent;
* **store rendezvous** — ``QueueBackend`` work items are executed by worker
  loops (threads here, ``python -m repro.core.execution.worker`` processes
  in the example) that coordinate exclusively through the shared store.
"""

import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import (AutoscalePolicy, Configuration, DiscoverySpace,
                        FakeClock, MeasurementError, SampleStore,
                        WorkerCrashError)
from repro.core.entities import canonical_json
from repro.core.execution import WorkItem, make_backend
from repro.core.execution.fleet import FleetSupervisor
from repro.core.execution.worker import run_worker
from repro.core.optimizers import (OPTIMIZER_REGISTRY, ScoredCandidate,
                                   SearchAdapter, run_optimizer)

from _execution_workers import (build_queue_ds, exit_fn, flaky_fn,
                                make_line_ds, raise_fn)


def reconciled(ds: DiscoverySpace) -> str:
    payload = sorted(
        (s.configuration.digest,
         sorted((v.name, v.value, v.experiment_id, v.predicted)
                for v in s.properties.values()))
        for s in ds.read()
    )
    return canonical_json(payload)


def records(ds: DiscoverySpace, op: str) -> list:
    return [(r.seq, r.config_digest, r.action) for r in ds.timeseries(op)]


def line_configs(n=4):
    return [Configuration.make({"x": x}) for x in range(n)]


# ----------------------------------------------------- backend equivalence


@pytest.mark.parametrize("backend,workers", [
    ("serial", 1), ("thread", 4), (None, 4),
])
def test_serial_thread_backends_byte_identical(tmp_path, backend, workers):
    """Every in-process backend spelling produces the same reconciled sample
    set and sampling record as the plain serial loop."""
    fn = lambda c: {"m": float(c["x"])}  # noqa: E731
    ref = make_line_ds(fn, SampleStore(":memory:"))
    for c in line_configs():
        ref.sample(c, operation_id="op")

    ds = make_line_ds(fn, SampleStore(":memory:"))
    results = ds.sample_batch(line_configs(), operation_id="op",
                              workers=workers, backend=backend)
    assert [r.action for r in results] == ["measured"] * 4
    assert reconciled(ds) == reconciled(ref)
    assert records(ds, "op") == records(ref, "op")


def test_backend_instance_is_reusable_and_caller_owned(tmp_path):
    ds = make_line_ds(lambda c: {"m": float(c["x"])}, SampleStore(":memory:"))
    with ds.execution_backend("thread", workers=2) as engine:
        ds.sample_batch(line_configs(2), operation_id="a", backend=engine)
        ds.sample_batch(line_configs(4), operation_id="b", backend=engine)
        assert len(records(ds, "a")) == 2 and len(records(ds, "b")) == 4


def test_unknown_backend_name_rejected():
    ds = make_line_ds(lambda c: {"m": 0.0}, SampleStore(":memory:"))
    with pytest.raises(ValueError, match="unknown execution backend"):
        ds.sample_batch(line_configs(1), backend="carrier-pigeon")


def test_process_backend_requires_file_store():
    ds = make_line_ds(lambda c: {"m": 0.0}, SampleStore(":memory:"))
    with pytest.raises(ValueError, match="reopenable store"):
        ds.sample_batch(line_configs(1), backend="process")


# ------------------------------------------------- process crash isolation


def _crash_isolation_check(tmp_path, hostile_fn, crashed_kind):
    """Shared body: one poison slot among four; the batch must survive."""
    ds = make_line_ds(hostile_fn, SampleStore(str(tmp_path / "store.db")))
    configs = line_configs()
    poison = configs[2].digest
    results = ds.sample_batch(configs, operation_id="op", workers=4,
                              backend="process")
    assert [r.action for r in results] == \
        ["measured", "measured", "failed", "measured"]
    bad = results[2]
    assert isinstance(bad.error, crashed_kind)
    assert isinstance(bad.error, MeasurementError)  # never kills the batch
    # the poison cell's claim is gone: waiters re-claim instead of stalling
    exp_id = ds.actions.experiments[0].identifier
    assert not ds.store.claim_exists(poison, exp_id)

    # surviving slots are serial-equivalent: same record events as a serial
    # run of the same surviving configurations
    ref = make_line_ds(lambda c: {"m": float(c["x"])}, SampleStore(":memory:"))
    for c in configs:
        if c.digest != poison:
            ref.sample(c, operation_id="op")
    survivors = [(d, a) for _, d, a in records(ds, "op") if d != poison]
    assert survivors == [(d, a) for _, d, a in records(ref, "op")]
    assert sorted(s.configuration.digest for s in ds.read()) == \
        sorted(s.configuration.digest for s in ref.read())


def test_process_worker_hard_exit_poisons_only_its_slot(tmp_path):
    _crash_isolation_check(tmp_path, exit_fn, WorkerCrashError)


def test_process_worker_unexpected_raise_poisons_only_its_slot(tmp_path):
    _crash_isolation_check(tmp_path, raise_fn, WorkerCrashError)


def test_process_worker_measurement_error_is_plain_failed(tmp_path):
    ds = make_line_ds(flaky_fn, SampleStore(str(tmp_path / "store.db")))
    results = ds.sample_batch(line_configs(), workers=4, backend="process")
    assert [r.ok for r in results] == [True, True, False, True]
    assert isinstance(results[2].error, MeasurementError)
    assert not isinstance(results[2].error, WorkerCrashError)


def test_pipelined_process_backend_survives_crashes(tmp_path):
    """The pipelined engine over ProcessBackend: poison trials come back as
    failed, the run continues to exhaustion."""
    ds = make_line_ds(exit_fn, SampleStore(str(tmp_path / "store.db")))
    run = run_optimizer(OPTIMIZER_REGISTRY["random"](seed=0), ds, "m", "min",
                        max_trials=4, patience=99,
                        rng=np.random.default_rng(0),
                        max_inflight=2, backend="process")
    assert run.num_trials == 4
    actions = sorted(t.action for t in run.trials)
    assert actions == ["failed", "measured", "measured", "measured"]


# ------------------------------------------------------- pipelined ask/tell


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_max_inflight_1_reproduces_serial_trajectory(name):
    """run_optimizer(max_inflight=1) == run_optimizer(batch_size=1): same
    configurations, values, actions, records — draw-for-draw.  Regression
    gate for the scored-candidate ask contract: attaching acquisition
    scores must never change rng consumption or the trajectory, for every
    optimizer family."""
    def one(max_inflight=None, batch_size=1):
        ds = make_line_ds(lambda c: {"m": (c["x"] - 1.3) ** 2},
                          SampleStore(":memory:"))
        run = run_optimizer(OPTIMIZER_REGISTRY[name](seed=0), ds, "m", "min",
                            max_trials=4, patience=2,
                            rng=np.random.default_rng(3),
                            batch_size=batch_size, max_inflight=max_inflight)
        return ([(t.configuration.digest, t.value, t.action, t.seq)
                 for t in run.trials], records(ds, run.operation_id))

    serial_trail, serial_recs = one()
    pipe_trail, pipe_recs = one(max_inflight=1)
    assert pipe_trail == serial_trail
    assert pipe_recs == serial_recs


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_ask_returns_scored_candidates(name):
    """Every optimizer's ask batch is ScoredCandidates; model-based families
    attach real (finite, orderable) acquisition scores once warmed up, and
    the batch comes out best-score-first."""
    ds = make_line_ds(lambda c: {"m": (c["x"] - 1.3) ** 2},
                      SampleStore(":memory:"))
    opt = OPTIMIZER_REGISTRY[name](seed=0)
    if hasattr(opt, "n_initial"):
        opt.n_initial = 2  # leave the random init phase within a tiny space
    rng = np.random.default_rng(0)
    adapter = SearchAdapter(ds, "m", "min", optimizer_name=opt.name)
    warm = opt.ask(adapter, rng, n=2)
    assert all(isinstance(c, ScoredCandidate) for c in warm)
    adapter.evaluate_batch(warm)
    batch = opt.ask(adapter, rng, n=1)
    assert all(isinstance(c, ScoredCandidate) for c in batch)
    if name in ("tpe", "bo-gp"):  # past n_initial: model scores attached
        scores = [c.score for c in batch]
        assert all(s is not None and np.isfinite(s) for s in scores)
        assert scores == sorted(scores, reverse=True)


def test_pipelined_keeps_max_inflight_and_exhausts_space():
    """With max_inflight=3 over a 4-point space the pipelined engine still
    visits every point exactly once (pending digests keep asks distinct)."""
    ds = make_line_ds(lambda c: {"m": float(c["x"])}, SampleStore(":memory:"))
    run = run_optimizer(OPTIMIZER_REGISTRY["random"](seed=0), ds, "m", "min",
                        max_trials=50, patience=99,
                        rng=np.random.default_rng(0), max_inflight=3)
    assert run.num_trials == 4
    assert len({t.configuration.digest for t in run.trials}) == 4
    assert run.max_inflight == 3
    seqs = [r.seq for r in ds.timeseries(run.operation_id)]
    assert sorted(seqs) == list(range(4))


def test_pipelined_tells_stragglers_after_stop():
    """Once the stopping rule fires, in-flight trials are drained and told —
    the history matches the number of sampling-record events."""
    ds = make_line_ds(lambda c: {"m": 1.0 + c["x"] * 0}, SampleStore(":memory:"))
    run = run_optimizer(OPTIMIZER_REGISTRY["random"](seed=0), ds, "m", "min",
                        max_trials=50, patience=2,
                        rng=np.random.default_rng(0), max_inflight=2)
    assert len(records(ds, run.operation_id)) == run.num_trials


def test_pipelined_crash_propagates_in_process():
    """In-process backends keep the pre-backend contract: an unexpected
    experiment error reaches the caller — after the surviving in-flight
    trials' records land (their values are already durable)."""
    ds = make_line_ds(raise_fn, SampleStore(":memory:"))
    with pytest.raises(RuntimeError, match="wild pointer"):
        run_optimizer(OPTIMIZER_REGISTRY["random"](seed=0), ds, "m", "min",
                      max_trials=8, patience=99,
                      rng=np.random.default_rng(0), max_inflight=2)
    # every healthy trial in flight alongside the poison point must be
    # recorded despite the raise (how many were asked before the crash
    # stopped submission depends on scheduling, but at least one of the
    # max_inflight=2 initial slots was healthy)
    op = ds.store.operations_for(ds.space_id)[0]["operation_id"]
    actions = [r.action for r in ds.timeseries(op)]
    assert actions and set(actions) == {"measured"}


# --------------------------------------------------------- queue rendezvous


def test_queue_backend_executes_through_worker_loops(tmp_path):
    """Investigator + two worker loops sharing one store: all work lands,
    every configuration measured exactly once."""
    path = str(tmp_path / "store.db")
    ds = build_queue_ds(path)
    workers = [threading.Thread(target=run_worker, args=(build_queue_ds(path),),
                                kwargs={"idle_timeout_s": 1.0,
                                        "owner": f"w{i}"})
               for i in range(2)]
    for t in workers:
        t.start()
    configs = list(ds.space.all_configurations())
    results = ds.sample_batch(configs, operation_id="op", backend="queue")
    for t in workers:
        t.join()
    assert all(r.ok for r in results)
    assert ds.store.count_measured(ds.space_id) == len(configs)
    assert len(records(ds, "op")) == len(configs)
    assert ds.store.pending_work(ds.space_id) == 0


def test_queue_backend_drain_timeout_without_workers(tmp_path):
    ds = make_line_ds(lambda c: {"m": 0.0}, SampleStore(str(tmp_path / "s.db")))
    engine = ds.execution_backend("queue")
    engine.submit(WorkItem(line_configs(1)[0], line_configs(1)[0].digest, 0))
    with pytest.raises(TimeoutError):
        engine.drain(timeout_s=0.3)


def test_worker_cli_subprocess(tmp_path):
    """The real thing: a ``python -m repro.core.execution.worker`` process
    serves the queue while the investigator samples through it."""
    import os
    path = str(tmp_path / "store.db")
    ds = build_queue_ds(path)
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    env = dict(os.environ, PYTHONPATH=os.pathsep.join([src, here]))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.core.execution.worker",
         "--store", path, "--factory", "_execution_workers:build_queue_ds",
         "--idle-timeout", "10", "--max-items", "6"],
        env=env, stdout=subprocess.PIPE, text=True)
    try:
        configs = list(ds.space.all_configurations())[:6]
        results = ds.sample_batch(configs, operation_id="op", backend="queue")
        assert all(r.ok for r in results)
        assert [r.action for r in results] == ["measured"] * 6
    finally:
        out, _ = proc.communicate(timeout=60)
    assert proc.returncode == 0
    assert "processed 6 work items" in out


def test_queue_worker_contains_experiment_bugs(tmp_path):
    """A worker hitting an experiment bug reports a failed item (with the
    crash marker) and keeps serving the queue."""
    path = str(tmp_path / "store.db")
    ds = make_line_ds(raise_fn, SampleStore(path))
    worker_ds = make_line_ds(raise_fn, SampleStore(path))
    t = threading.Thread(target=run_worker, args=(worker_ds,),
                         kwargs={"idle_timeout_s": 1.0})
    t.start()
    results = ds.sample_batch(line_configs(), operation_id="op", backend="queue")
    t.join()
    assert [r.ok for r in results] == [True, True, False, True]
    assert isinstance(results[2].error, WorkerCrashError)


# ------------------------------------------------- store GC / point queries


def test_sweep_stale_claims():
    """Staleness is lease expiry, nothing else: an expired lease is reaped,
    a live one survives — even when the live claim is *older* (a
    heartbeating owner mid-long-measurement must never be robbed)."""
    store = SampleStore(":memory:")
    store.claim_experiment("d1", "e", "dead")
    store.claim_experiment("d2", "e", "alive")
    store._write("UPDATE value_claims SET lease_expires_at=?,"
                 " created_at=? WHERE config_digest='d1'",
                 (time.time() - 1.0, time.time() - 30.0))
    store._write("UPDATE value_claims SET created_at=? WHERE config_digest='d2'",
                 (time.time() - 3600.0,))  # old but lease-fresh: kept
    assert store.sweep_stale_claims() == 1
    assert not store.claim_exists("d1", "e")
    assert store.claim_exists("d2", "e")
    store.close()


def test_release_claims_owned_by():
    store = SampleStore(":memory:")
    store.claim_experiment("d1", "e", "1234:567")
    store.claim_experiment("d2", "e", "1234")
    store.claim_experiment("d3", "e", "12345:9")
    assert store.release_claims_owned_by("1234") == 2
    assert store.claim_exists("d3", "e")
    store.close()


def test_requeue_stale_work(tmp_path):
    store = SampleStore(str(tmp_path / "s.db"))
    item = store.enqueue_work("space", "digest")
    claim = store.claim_work("w0", lease_s=60.0)
    assert claim["item_id"] == item
    assert store.claim_work("w1") is None  # nothing else queued
    store._write("UPDATE work_items SET lease_expires_at=? WHERE item_id=?",
                 (time.time() - 1.0, item))
    assert store.requeue_stale_work() == 1
    again = store.claim_work("w1")
    assert again["item_id"] == item  # the surviving fleet redoes the work
    store.finish_work(item, "measured")
    assert store.fetch_work_results([item]) == {item: ("measured", None)}
    assert store.pending_work("space") == 0
    store.close()


def test_has_record_point_query():
    ds = make_line_ds(flaky_fn, SampleStore(":memory:"))
    configs = line_configs()
    ds.sample_batch(configs, operation_id="op")
    assert ds.store.has_record(ds.space_id, configs[0].digest)
    assert ds.read_one(configs[0]) is not None
    # the poison configuration failed: excluded from {x} unless asked for
    assert not ds.store.has_record(ds.space_id, configs[2].digest)
    assert ds.store.has_record(ds.space_id, configs[2].digest,
                               include_failed=True)
    assert ds.read_one(configs[2]) is None
    # never sampled at all
    unseen = Configuration.make({"x": 99})
    assert not ds.store.has_record(ds.space_id, unseen.digest,
                                   include_failed=True)
    assert ds.read_one(unseen) is None


def test_stale_finish_cannot_overwrite_reexecution(tmp_path):
    """A worker that went silent long enough for its item to be re-queued
    must not land its late outcome over the re-executing worker's claim."""
    store = SampleStore(str(tmp_path / "s.db"))
    item = store.enqueue_work("space", "digest")
    store.claim_work("worker-A")
    store._write("UPDATE work_items SET lease_expires_at=? WHERE item_id=?",
                 (time.time() - 1.0, item))
    assert store.requeue_stale_work() == 1
    store.claim_work("worker-B")
    # A comes back from the dead with a failure: ignored, B still owns it
    assert store.finish_work(item, "failed", "crash: ...", owner="worker-A") is False
    assert store.fetch_work_results([item]) == {}
    assert store.finish_work(item, "measured", owner="worker-B") is True
    assert store.fetch_work_results([item]) == {item: ("measured", None)}
    store.close()


def test_backend_instance_rejected_on_foreign_space(tmp_path):
    """A backend instance is bound to its construction-time action space;
    using it on a different space must be a loud error, not a silent sweep
    with the wrong experiments."""
    ds_a = make_line_ds(flaky_fn, SampleStore(str(tmp_path / "s.db")))
    ds_b = build_queue_ds(str(tmp_path / "s.db"))
    engine = ds_a.execution_backend("thread", workers=2)
    with pytest.raises(ValueError, match="different Discovery Space"):
        ds_b.sample_batch(list(ds_b.space.all_configurations())[:1],
                          backend=engine)
    engine.close()


def test_make_backend_type_error():
    ds = make_line_ds(lambda c: {"m": 0.0}, SampleStore(":memory:"))
    with pytest.raises(TypeError):
        make_backend(42, ds.execution_context())


# ----------------------------------------------- autoscaling (fake clock)


def test_autoscale_policy_target_is_pure_and_clamped():
    policy = AutoscalePolicy(min_workers=2, max_workers=6,
                             backlog_per_worker=2.0)
    assert policy.target(0) == 2        # never below min
    assert policy.target(5) == 3        # ceil(5/2)
    assert policy.target(100) == 6      # never above max
    latency = AutoscalePolicy(min_workers=1, max_workers=8,
                              drain_horizon_s=10.0)
    # 20 items x 2 s each, drained in 10 s => 4 workers
    assert latency.target(20, ewma_latency_s=2.0) == 4
    with pytest.raises(ValueError):
        AutoscalePolicy(min_workers=3, max_workers=2)


def test_process_backend_grows_under_backlog_and_shrinks_when_drained(tmp_path):
    """Acceptance gate: an autoscaling ProcessBackend fleet grows under
    sustained queue depth and shrinks back to min_workers when drained —
    asserted deterministically off a fake clock (no sleeps, no flakes)."""
    clock = FakeClock()
    ds = make_line_ds(lambda c: {"m": float(c["x"])},
                      SampleStore(str(tmp_path / "store.db")))
    ds.clock = clock
    ds.autoscale = AutoscalePolicy(min_workers=1, max_workers=3,
                                   idle_retire_s=10.0)
    with ds.execution_backend("process") as engine:
        configs = line_configs(4)
        for i, config in enumerate(configs):
            ds.store.put_configuration(config)
            engine.submit(WorkItem(config, config.digest, i))
        # sustained backlog: the fleet grew to the policy target
        assert engine.num_workers == 3
        results = engine.drain()
        assert sorted(r.action for r in results) == ["measured"] * 4
        # drained but idle horizon not reached: fleet holds steady
        engine.poll()
        assert engine.num_workers == 3
        # past the idle horizon (virtual time only): shrink to min_workers
        clock.advance(10.5)
        engine.poll()
        assert engine.num_workers == 1
        # new backlog grows it right back
        more = line_configs(4)
        for i, config in enumerate(more):
            engine.submit(WorkItem(config, config.digest, 100 + i))
        assert engine.num_workers == 3
        engine.drain()


def test_fleet_supervisor_scales_queue_workers(tmp_path):
    """FleetSupervisor: backlog grows the fleet to the policy target, a
    drained queue (past the idle horizon on the fake clock) shrinks it back
    to min_workers, and every enqueued item is executed exactly once."""
    path = str(tmp_path / "store.db")
    clock = FakeClock()

    def factory():
        ds = build_queue_ds(path)
        ds.store.clock = clock
        ds.clock = clock
        return ds

    ds = factory()
    policy = AutoscalePolicy(min_workers=1, max_workers=3, idle_retire_s=5.0)
    supervisor = FleetSupervisor(factory, policy=policy, clock=clock)
    try:
        configs = list(ds.space.all_configurations())[:9]
        for config in configs:
            ds.store.enqueue_work(ds.space_id, ds.store.put_configuration(config))
        snap = supervisor.step()
        assert snap["workers"] == 3 and snap["target"] == 3
        deadline = time.monotonic() + 30.0
        while ds.store.pending_work(ds.space_id):
            assert time.monotonic() < deadline, "fleet never drained the queue"
            time.sleep(0.01)
        supervisor.step()           # observes the drained queue; idle starts
        clock.advance(6.0)
        snap = supervisor.step()
        assert snap["workers"] == 1  # shrunk back to min_workers
        assert supervisor.processed == len(configs)
        stats = ds.store.work_queue_stats(ds.space_id)
        assert stats["done"] == len(configs) and stats["queued"] == 0
    finally:
        supervisor.stop()
    assert supervisor.num_workers == 0


# ------------------------------------------------- priority scheduling e2e


def test_queue_workers_measure_best_priority_first(tmp_path):
    """End-to-end through QueueBackend + the real worker loop: a single
    worker drains a prioritized batch best-acquisition-first (FIFO within
    ties), observable in the store's claim order."""
    path = str(tmp_path / "store.db")
    ds = make_line_ds(lambda c: {"m": float(c["x"])}, SampleStore(path))
    configs = line_configs(4)
    priorities = [0.0, 3.0, -1.0, 7.0]  # best-first: x=3, x=1, x=0, x=2
    # submit the whole batch BEFORE the worker exists, so the pop order is
    # pure scheduling (a late-joining fleet is the §III-D normal case)
    engine = ds.execution_backend("queue")
    for i, (config, priority) in enumerate(zip(configs, priorities)):
        ds.store.put_configuration(config)
        engine.submit(WorkItem(config, config.digest, i, priority=priority))
    worker = threading.Thread(
        target=run_worker,
        args=(make_line_ds(lambda c: {"m": float(c["x"])}, SampleStore(path)),),
        kwargs={"idle_timeout_s": 1.0})  # claim_batch=1: one pop per trip,
    # so per-item claim timestamps make the execution order observable
    worker.start()
    results = engine.drain(timeout_s=30.0)
    worker.join()
    assert sorted(r.action for r in results) == ["measured"] * 4
    # the driver maps results back by tag regardless of completion order...
    assert sorted(r.item.tag for r in results) == [0, 1, 2, 3]
    # ...while execution happened in priority order
    rows = ds.store._rows(
        "SELECT config_digest FROM work_items ORDER BY claimed_at, rowid")
    executed = [ds.store.get_configuration(r[0])["x"] for r in rows]
    assert executed == [3, 1, 0, 2]


def test_pipelined_over_queue_carries_acquisition_priorities(tmp_path):
    """The pipelined engine forwards each ask's acquisition score into the
    work_items priority column (0.0 only for unscored random picks)."""
    path = str(tmp_path / "store.db")
    ds = make_line_ds(lambda c: {"m": (c["x"] - 1.3) ** 2}, SampleStore(path))
    worker = threading.Thread(
        target=run_worker,
        args=(make_line_ds(lambda c: {"m": (c["x"] - 1.3) ** 2},
                           SampleStore(path)),),
        kwargs={"idle_timeout_s": 1.0})
    worker.start()
    opt = OPTIMIZER_REGISTRY["tpe"](seed=0)
    opt.n_initial = 2
    run = run_optimizer(opt, ds, "m", "min", max_trials=4, patience=99,
                        rng=np.random.default_rng(0), max_inflight=2,
                        backend="queue")
    worker.join()
    assert run.num_trials == 4
    rows = ds.store._rows("SELECT priority FROM work_items")
    assert len(rows) == 4
    # past the init phase the TPE scores are real: not all-zero
    assert any(abs(r[0]) > 1e-12 for r in rows)
