"""Tests for the optimizer suite over Discovery Spaces."""

import numpy as np
import pytest

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, ProbabilitySpace, SampleStore)
from repro.core.optimizers import (BOHB, OPTIMIZER_REGISTRY, GPBayesOpt,
                                   RandomSearch, TPE, hypergeom_p_found,
                                   run_optimizer)


def quadratic_space(n_per_dim=8):
    vals = [round(v, 3) for v in np.linspace(-2, 2, n_per_dim)]
    return ProbabilitySpace.make([
        Dimension.discrete("x", vals),
        Dimension.discrete("y", vals),
        Dimension.categorical("mode", ["slow", "fast"]),
    ])


def quadratic_ds(store=None):
    def fn(c):
        penalty = 0.0 if c["mode"] == "fast" else 1.0
        return {"loss": (c["x"] - 0.5) ** 2 + (c["y"] + 0.5) ** 2 + penalty}
    exp = FunctionExperiment(fn=fn, properties=("loss",), name="quad")
    return DiscoverySpace(space=quadratic_space(),
                          actions=ActionSpace.make([exp]),
                          store=store or SampleStore(":memory:"))


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_optimizer_finds_good_region(name):
    ds = quadratic_ds()
    opt = OPTIMIZER_REGISTRY[name](seed=0)
    run = run_optimizer(opt, ds, metric="loss", mode="min", max_trials=60,
                        patience=8, rng=np.random.default_rng(0))
    assert run.best is not None
    # model-based optimizers should land well inside the bowl within 60
    # trials; the random baseline just needs to beat the bulk of the space
    threshold = 1.5 if name == "random" else 0.6
    assert run.best.value < threshold
    assert run.num_trials <= 60


def test_model_based_beats_random_on_average():
    """GP-BO should reach a better median best-value than random at equal
    trial counts on a smooth surface."""
    def best_after(opt_cls, seed, n=25):
        ds = quadratic_ds()
        run = run_optimizer(opt_cls(seed=seed), ds, "loss", "min",
                            max_trials=n, patience=n,  # no early stop
                            rng=np.random.default_rng(seed))
        return run.best.value

    bo = np.median([best_after(GPBayesOpt, s) for s in range(6)])
    rnd = np.median([best_after(RandomSearch, s) for s in range(6)])
    assert bo <= rnd + 1e-9


def test_early_stop_patience():
    ds = quadratic_ds()
    run = run_optimizer(RandomSearch(seed=0), ds, "loss", "min",
                        max_trials=500, patience=5,
                        rng=np.random.default_rng(3))
    # paper §V-B1 stopping rule: must stop well before exhausting the space
    assert run.num_trials < ds.space.size


def test_optimizers_share_store_and_reuse():
    """Two sequential optimizer runs on the same Discovery Space: the second
    transparently reuses overlapping samples (paper Fig. 7 mechanism)."""
    store = SampleStore(":memory:")
    ds = quadratic_ds(store)
    r1 = run_optimizer(RandomSearch(seed=0), ds, "loss", "min", max_trials=40,
                       patience=40, rng=np.random.default_rng(0))
    assert r1.num_measured == r1.num_trials  # cold store: everything measured
    r2 = run_optimizer(RandomSearch(seed=1), ds, "loss", "min", max_trials=40,
                       patience=40, rng=np.random.default_rng(0))
    # identical rng stream => same draws => full reuse
    assert r2.num_measured == 0
    assert r2.normalized_cost == 0.0
    r3 = run_optimizer(TPE(seed=2), ds, "loss", "min", max_trials=40,
                       patience=40, rng=np.random.default_rng(7))
    assert r3.num_reused > 0 or r3.num_measured < r3.num_trials


def test_optimizer_exhausts_finite_space():
    space = ProbabilitySpace.make([Dimension.discrete("x", [1, 2, 3])])
    exp = FunctionExperiment(fn=lambda c: {"m": float(c["x"])},
                             properties=("m",), name="tiny")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]))
    run = run_optimizer(RandomSearch(seed=0), ds, "m", "min",
                        max_trials=100, patience=100)
    assert run.num_trials == 3
    assert run.best.value == 1.0


def test_maximization_mode():
    ds = quadratic_ds()
    run = run_optimizer(GPBayesOpt(seed=0), ds, "loss", "max", max_trials=40,
                        patience=40, rng=np.random.default_rng(0))
    assert run.best.value > 5.0  # corners of the bowl + slow penalty


def test_hypergeometric_baseline():
    # drawing everything finds a target with certainty
    assert hypergeom_p_found(100, 5, 100) == pytest.approx(1.0)
    # analytic value for small case: N=10, K=2, n=3 -> 1 - C(8,3)/C(10,3)
    assert hypergeom_p_found(10, 2, 3) == pytest.approx(1 - (8 * 7 * 6) / (10 * 9 * 8))
    assert hypergeom_p_found(1000, 50, 0) == 0.0


def test_bohb_brackets_multifidelity():
    """BOHB successive halving: low-fidelity evals are noisy, full fidelity
    exact; the surviving config should be near-optimal."""
    space = quadratic_space(10)
    rng_noise = np.random.default_rng(0)

    def evaluate_at(config, budget):
        exact = (config["x"] - 0.5) ** 2 + (config["y"] + 0.5) ** 2 \
            + (0.0 if config["mode"] == "fast" else 1.0)
        noise = rng_noise.normal(0, 1.0 / budget)
        return exact + noise

    bohb = BOHB(seed=0, min_budget=1, max_budget=9, eta=3)
    pool_rng = np.random.default_rng(1)

    def suggest_pool(n):
        return [space.sample_configuration(pool_rng) for _ in range(n)]

    results = bohb.run_brackets(evaluate_at, suggest_pool, n_brackets=2)
    assert results
    best_cfg, best_val = min(results, key=lambda cv: cv[1])
    exact_best = (best_cfg["x"] - 0.5) ** 2 + (best_cfg["y"] + 0.5) ** 2 \
        + (0.0 if best_cfg["mode"] == "fast" else 1.0)
    assert exact_best < 2.0
