"""Tests for representative sub-space comparison (RSSC) knowledge transfer."""

import numpy as np
import pytest

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, ProbabilitySpace, SampleStore,
                        assess_transfer, prediction_quality, rssc_transfer,
                        select_linspace, select_representatives, select_top_k)
from repro.core.transfer import TransferCriteria


def make_pair(relation="linear", noise=0.0, seed=0):
    """Source space on gpu A100-PCIE, target on A100-SXM4; target metric is a
    function of the source metric controlled by `relation`."""
    rng = np.random.default_rng(seed)
    space_src = ProbabilitySpace.make([
        Dimension.categorical("gpu", ["A100-PCIE"]),
        Dimension.discrete("batch", [2, 4, 8, 16, 32, 64]),
        Dimension.discrete("cores", [1, 2, 4, 8]),
    ])
    mapping = {"gpu": {"A100-PCIE": "A100-SXM4"}}

    def src_fn(c):
        return {"latency": 100.0 / np.log2(c["batch"]) + 5.0 * c["cores"]}

    def tgt_fn(c):
        src = 100.0 / np.log2(c["batch"]) + 5.0 * c["cores"]
        if relation == "linear":
            val = 0.6 * src + 10.0
        elif relation == "negative":
            val = -0.8 * src + 200.0
        else:  # 'unrelated'
            val = float(rng.uniform(50, 150))
        return {"latency": val + (rng.normal(0, noise) if noise else 0.0)}

    store = SampleStore(":memory:")
    src_exp = FunctionExperiment(fn=src_fn, properties=("latency",), name="src-bench")
    tgt_exp = FunctionExperiment(fn=tgt_fn, properties=("latency",), name="tgt-bench")
    ds_src = DiscoverySpace(space=space_src, actions=ActionSpace.make([src_exp]),
                            store=store)
    ds_tgt = DiscoverySpace(space=space_src.map_values(mapping),
                            actions=ActionSpace.make([tgt_exp]), store=store)
    return ds_src, ds_tgt, mapping, tgt_fn


def exhaust(ds):
    for c in list(ds.remaining_configurations()):
        ds.sample(c)


# ---------------------------------------------------------------- point selection


def test_select_representatives_spans_value_range():
    rng = np.random.default_rng(0)
    values = np.concatenate([np.full(20, 1.0), np.full(20, 10.0), np.full(20, 100.0)])
    values = values + rng.normal(0, 0.05, size=60)
    reps = select_representatives(values, rng)
    picked = values[reps]
    assert len(reps) >= 2
    assert picked.min() < 5 and picked.max() > 50  # spans the clusters


def test_select_top_k_and_linspace():
    v = np.arange(20.0)
    assert select_top_k(v, 5, "min") == [0, 1, 2, 3, 4]
    assert select_top_k(v, 5, "max") == [19, 18, 17, 16, 15]
    ls = select_linspace(v, 5)
    assert 0 in ls and 19 in ls and len(ls) == 5


# ---------------------------------------------------------------- transfer criteria


def test_assess_transfer_criteria():
    x = np.linspace(1, 10, 12)
    ok = assess_transfer(x, 2 * x + 1)
    assert ok.transferable and ok.r > 0.99
    neg = assess_transfer(x, -2 * x + 100)
    assert neg.transferable and neg.r < -0.99  # |r| criterion
    rng = np.random.default_rng(0)
    bad = assess_transfer(x, rng.uniform(size=12))
    assert not bad.transferable
    few = assess_transfer(x[:2], x[:2])
    assert not few.transferable  # too few points


# ---------------------------------------------------------------- full RSSC flow


def test_rssc_transfers_linear_relationship():
    ds_src, ds_tgt, mapping, tgt_fn = make_pair("linear")
    exhaust(ds_src)
    res = rssc_transfer(ds_src, ds_tgt, "latency", mapping,
                        rng=np.random.default_rng(0))
    assert res.transferable
    assert res.assessment.r > 0.95
    assert res.predicted_space is not None
    # the predictor swept the remaining points -> target space fully covered
    preds = res.predicted_space.read()
    assert len(preds) == ds_tgt.space.size
    # predictions carry provenance: predicted flag set, distinct experiment
    predicted = [s for s in preds if s.properties["latency"].predicted]
    assert len(predicted) == ds_tgt.space.size - len(res.translated)
    # prediction quality against ground truth
    configs = [s.configuration for s in preds]
    pred_vals = np.array([s.value("latency") for s in preds])
    true_vals = np.array([tgt_fn(c)["latency"] for c in configs])
    q = prediction_quality(pred_vals, true_vals, n_measured=res.n_target_measured)
    assert q.best_pct > 0.95
    assert q.top5_pct >= 0.6
    assert q.savings_pct > 0.5


def test_rssc_parallel_workers_match_serial():
    """Step ④ (representative measurement) and step ⑧ (surrogate sweep)
    through 4 workers: same assessment, predictions, and measurement count
    as the serial run."""
    def run_with(workers):
        ds_src, ds_tgt, mapping, _ = make_pair("linear")
        exhaust(ds_src)
        res = rssc_transfer(ds_src, ds_tgt, "latency", mapping,
                            rng=np.random.default_rng(0), workers=workers)
        preds = {s.configuration.digest: s.value("latency")
                 for s in res.predicted_space.read()}
        return res, preds

    serial, preds_1 = run_with(1)
    parallel, preds_4 = run_with(4)
    assert parallel.transferable == serial.transferable
    assert parallel.assessment.r == pytest.approx(serial.assessment.r)
    assert parallel.n_target_measured == serial.n_target_measured
    assert preds_4 == preds_1


def test_rssc_rejects_unrelated_spaces():
    ds_src, ds_tgt, mapping, _ = make_pair("unrelated")
    exhaust(ds_src)
    res = rssc_transfer(ds_src, ds_tgt, "latency", mapping,
                        rng=np.random.default_rng(0))
    assert not res.transferable
    assert res.predicted_space is None
    # only the representative points were measured in the target
    assert ds_tgt.count_sampled() == len(res.translated)


def test_rssc_negative_correlation_transfers():
    ds_src, ds_tgt, mapping, tgt_fn = make_pair("negative")
    exhaust(ds_src)
    res = rssc_transfer(ds_src, ds_tgt, "latency", mapping,
                        rng=np.random.default_rng(0))
    assert res.transferable and res.assessment.r < -0.9
    preds = res.predicted_space.read()
    pred_vals = np.array([s.value("latency") for s in preds])
    true_vals = np.array([tgt_fn(s.configuration)["latency"] for s in preds])
    # surrogate carries the negative slope, so predictions still rank well
    q = prediction_quality(pred_vals, true_vals, res.n_target_measured)
    assert q.best_pct > 0.9


@pytest.mark.parametrize("method", ["clustering", "top5", "linspace"])
def test_rssc_point_selection_methods(method):
    ds_src, ds_tgt, mapping, _ = make_pair("linear")
    exhaust(ds_src)
    res = rssc_transfer(ds_src, ds_tgt, "latency", mapping, selection=method,
                        rng=np.random.default_rng(0))
    assert res.transferable
    assert len(res.representatives) >= 3


def test_rssc_identity_mapping():
    """No mapping: {e}_a == {e}_a* (paper §IV-1). The change is in the action
    space (new measurement infrastructure), not the configuration space."""
    ds_src, _, _, _ = make_pair("linear")
    exhaust(ds_src)
    # target over the SAME configuration space, different experiment
    tgt_exp = FunctionExperiment(
        fn=lambda c: {"latency": 0.5 * (100.0 / np.log2(c["batch"]) + 5.0 * c["cores"]) + 3.0},
        properties=("latency",), name="new-infra-bench")
    ds_tgt = DiscoverySpace(space=ds_src.space,
                            actions=ActionSpace.make([tgt_exp]),
                            store=ds_src.store)
    res = rssc_transfer(ds_src, ds_tgt, "latency", mapping=None,
                        rng=np.random.default_rng(0))
    # mapping None is allowed; configs translate to themselves
    assert [c.digest for c in res.representatives] == \
           [c.digest for c in res.translated]
    assert res.transferable
