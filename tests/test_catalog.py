"""Tests for the persistent SpaceCatalog (registration, stats, relatedness).

Every DiscoverySpace registers itself (Ω digest + entity metadata) in the
store's ``spaces`` table; the catalog joins that with per-space record
counts and answers ``find_related`` queries — the discovery step of the
paper's §IV cross-space reuse.  Edge cases pinned here: disjoint dimension
sets never match, value renames connect through explicit mappings or
positional inference (categorical only — numeric value sets are
quantities), and partial overlap is gated by ``min_overlap``.
"""

import numpy as np

from repro.core import (ActionSpace, Configuration, DiscoverySpace,
                        Dimension, FunctionExperiment, MeasurementError,
                        ProbabilitySpace, SampleStore, SpaceCatalog)
from repro.core.api.catalog import _match_dimension


def make_ds(store, dims, prop="m", name="exp", fn=None):
    fn = fn or (lambda c: {prop: 1.0})
    exp = FunctionExperiment(fn=fn, properties=(prop,), name=name)
    return DiscoverySpace(space=ProbabilitySpace.make(dims),
                          actions=ActionSpace.make([exp]), store=store)


def dims_xy(xvals=(1, 2, 3), yvals=("a", "b")):
    return [Dimension.discrete("x", list(xvals)),
            Dimension.categorical("y", list(yvals))]


# ------------------------------------------------------------- registration


def test_every_discovery_space_registers_a_catalog_entry():
    store = SampleStore(":memory:")
    ds = make_ds(store, dims_xy())
    cat = SpaceCatalog(store)
    entries = cat.entries()
    assert [e.space_id for e in entries] == [ds.space_id]
    e = entries[0]
    assert e.space_digest == ds.space.digest
    assert e.meta["dimensions"] == ["x", "y"]
    assert e.meta["size"] == 6
    assert e.properties == ("m",)
    assert e.n_records == e.n_measured == 0


def test_entry_counts_track_the_sampling_record():
    store = SampleStore(":memory:")

    def flaky(c):
        if c["x"] == 3:
            raise MeasurementError("cliff")
        return {"m": float(c["x"])}

    ds = make_ds(store, dims_xy(), fn=flaky)
    configs = list(ds.space.all_configurations())
    ds.sample_batch(configs, operation_id="op")
    ds.sample_batch(configs[:2], operation_id="op2")  # reused, not measured
    e = SpaceCatalog(store).get(ds.space_id)
    assert e.n_records == 8          # 6 + 2 reuse events
    assert e.n_measured == 4         # x==3 slots failed
    assert e.n_failed == 2
    assert e.n_distinct == 6


def test_same_dimensions_different_actions_are_two_entries_one_digest():
    store = SampleStore(":memory:")
    a = make_ds(store, dims_xy(), name="exp-a")
    b = make_ds(store, dims_xy(), name="exp-b")
    assert a.space_id != b.space_id
    entries = SpaceCatalog(store).entries()
    assert len(entries) == 2
    assert len({e.space_digest for e in entries}) == 1


# ------------------------------------------------------------- find_related


def seeded(store, dims, n=4, **kw):
    """A measured space: n configurations sampled so find_related sees data."""
    ds = make_ds(store, dims, **kw)
    ds.sample_batch(list(ds.space.all_configurations())[:n], operation_id="op")
    return ds


def test_find_related_exact_match_ranks_first():
    store = SampleStore(":memory:")
    src = seeded(store, dims_xy(), name="exp-src")
    tgt = make_ds(store, dims_xy(), name="exp-tgt")
    rel = SpaceCatalog(store).find_related(tgt.space,
                                           exclude=[tgt.space_id])
    assert [r.entry.space_id for r in rel] == [src.space_id]
    assert rel[0].exact and rel[0].overlap == 1.0
    assert rel[0].shared_dimensions == ("x", "y")
    assert rel[0].mapping == {}


def test_find_related_disjoint_dimensions_never_match():
    store = SampleStore(":memory:")
    seeded(store, dims_xy(), name="exp-src")
    other = ProbabilitySpace.make([Dimension.discrete("cores", [1, 2]),
                                   Dimension.discrete("mem", [4, 8])])
    assert SpaceCatalog(store).find_related(other) == []
    # even with min_overlap 0 a zero-dimension match is not 'related'
    assert SpaceCatalog(store).find_related(other, min_overlap=0.0) == []


def test_find_related_partial_overlap_gated_by_min_overlap():
    store = SampleStore(":memory:")
    src = seeded(store, dims_xy(), name="exp-src")
    superset = ProbabilitySpace.make(
        dims_xy() + [Dimension.discrete("z", [0, 1])])
    cat = SpaceCatalog(store)
    assert cat.find_related(superset) == []           # default needs 1.0
    rel = cat.find_related(superset, min_overlap=0.6)
    assert [r.entry.space_id for r in rel] == [src.space_id]
    assert rel[0].overlap == 2 / 3
    assert rel[0].shared_dimensions == ("x", "y")


def test_find_related_renamed_values_need_a_mapping_or_inference():
    store = SampleStore(":memory:")
    src = seeded(store, dims_xy(yvals=("gpu-old-1", "gpu-old-2")),
                 name="exp-src")
    tgt_space = ProbabilitySpace.make(
        dims_xy(yvals=("gpu-new-1", "gpu-new-2")))
    cat = SpaceCatalog(store)

    # positional inference: categorical, same cardinality => inferred rename
    rel = cat.find_related(tgt_space)
    assert len(rel) == 1 and rel[0].entry.space_id == src.space_id
    assert rel[0].mapping == {"y": {"gpu-old-1": "gpu-new-1",
                                    "gpu-old-2": "gpu-new-2"}}
    assert rel[0].inferred_dims == ("y",)
    assert not rel[0].exact

    # an explicit mapping overrides inference (here: crossed renames)
    rel = cat.find_related(tgt_space, mappings={
        "y": {"gpu-old-1": "gpu-new-2", "gpu-old-2": "gpu-new-1"}})
    assert rel[0].mapping == {"y": {"gpu-old-1": "gpu-new-2",
                                    "gpu-old-2": "gpu-new-1"}}
    assert rel[0].inferred_dims == ()

    # a mapping that misses the target's value set is not a match
    assert cat.find_related(tgt_space, mappings={
        "y": {"gpu-old-1": "gpu-other"}}) == []


def test_find_related_reordered_categorical_values_match_as_identity():
    """The same unordered value set declared in a different order is the
    same dimension: positional inference must NOT cross-rename it."""
    store = SampleStore(":memory:")
    src = seeded(store, dims_xy(yvals=("a", "b")), name="exp-src")
    reordered = ProbabilitySpace.make(dims_xy(yvals=("b", "a")))
    rel = SpaceCatalog(store).find_related(reordered)
    assert [r.entry.space_id for r in rel] == [src.space_id]
    assert rel[0].mapping == {} and rel[0].exact
    assert rel[0].inferred_dims == ()


def test_find_related_never_infers_numeric_value_renames():
    store = SampleStore(":memory:")
    seeded(store, [Dimension.discrete("mem_gb", [1, 2, 4])], name="exp-src")
    bigger = ProbabilitySpace.make([Dimension.discrete("mem_gb", [8, 16, 32])])
    cat = SpaceCatalog(store)
    assert cat.find_related(bigger) == []   # quantities, not labels
    # ...but an explicit mapping is allowed to assert the correspondence
    rel = cat.find_related(bigger, mappings={"mem_gb": {1: 8, 2: 16, 4: 32}})
    assert len(rel) == 1 and rel[0].mapping == {"mem_gb": {1: 8, 2: 16, 4: 32}}


def test_find_related_filters_metric_and_data_volume():
    store = SampleStore(":memory:")
    src = seeded(store, dims_xy(), n=4, prop="latency", name="exp-src")
    seeded(store, dims_xy(), n=2, prop="latency", name="exp-small")
    seeded(store, dims_xy(), n=4, prop="throughput", name="exp-other")
    tgt = ProbabilitySpace.make(dims_xy())
    rel = SpaceCatalog(store).find_related(tgt, metric="latency",
                                           min_measured=3)
    assert [r.entry.space_id for r in rel] == [src.space_id]


def test_match_dimension_kind_and_range_rules():
    cont = Dimension.continuous("t", 0.0, 1.0)
    assert _match_dimension(cont, Dimension.continuous("t", 0.0, 1.0),
                            None) == ({}, False)
    assert _match_dimension(cont, Dimension.continuous("t", 0.0, 2.0),
                            None) is None
    assert _match_dimension(cont, Dimension.discrete("t", [0, 1]),
                            None) is None


# ------------------------------------------------------------ measured_pairs


def test_measured_pairs_returns_only_real_measured_values():
    store = SampleStore(":memory:")

    def flaky(c):
        if c["x"] == 3:
            raise MeasurementError("cliff")
        return {"m": float(c["x"]) * 10}

    ds = make_ds(store, dims_xy(), fn=flaky)
    ds.sample_batch(list(ds.space.all_configurations()), operation_id="op")
    cat = SpaceCatalog(store)
    entry = cat.get(ds.space_id)
    pairs = cat.measured_pairs(entry, "m")
    assert len(pairs) == 4                      # the x==3 failures dropped
    assert all(isinstance(c, Configuration) and v == c["x"] * 10
               for c, v in pairs)
    assert cat.measured_pairs(entry, "no-such-metric") == []
