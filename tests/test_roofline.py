"""Tests for the while-aware HLO analyzer and roofline accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (_ring_factor, roofline_terms,
                                     xla_cost_analysis)
from repro.roofline.hlo_parse import analyze_hlo
from repro.roofline.hw import HW_V5E


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_xla_cost_analysis_counts_scan_once():
    """Documents the defect the parser exists to fix: XLA cost_analysis
    counts while bodies exactly once."""
    def scanned(x, ws):
        def body(c, w):
            return (c @ w).astype(c.dtype), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.zeros((64, 64))
    ws = jnp.zeros((8, 64, 64))
    compiled = _compile(scanned, x, ws)
    flops_xla = xla_cost_analysis(compiled).get("flops", 0.0)
    one_matmul = 2 * 64 * 64 * 64
    assert flops_xla == pytest.approx(one_matmul, rel=0.01)  # NOT ×8


@pytest.mark.parametrize("trips", [4, 8, 17])
def test_analyzer_scales_dot_flops_by_trip_count(trips):
    def scanned(x, ws):
        def body(c, w):
            return (c @ w).astype(c.dtype), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.zeros((32, 32))
    ws = jnp.zeros((trips, 32, 32))
    a = analyze_hlo(_compile(scanned, x, ws).as_text())
    assert a.flops == pytest.approx(2 * 32 ** 3 * trips, rel=0.01)
    assert a.trip_counts == [trips]


def test_analyzer_nested_scans_multiply():
    def nested(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return (c2 @ w).astype(c2.dtype), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jnp.zeros((32, 32))
    ws = jnp.zeros((5, 32, 32))
    a = analyze_hlo(_compile(nested, x, ws).as_text())
    assert a.flops == pytest.approx(2 * 32 ** 3 * 5 * 3, rel=0.01)
    assert sorted(a.trip_counts) == [3, 5]


def test_analyzer_counts_collectives_with_groups():
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    @jax.jit
    def f(x):
        y = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P("data", None)))
        return y.sum()

    # single-device: no collectives expected — exercise the zero path
    a = analyze_hlo(_compile(lambda x: x.sum(), jnp.zeros((8, 8))).as_text())
    assert a.collectives == {}


def test_analyzer_dus_counts_update_slice_only():
    def f(buf, x):
        def body(b, i):
            b = jax.lax.dynamic_update_index_in_dim(b, x, i, 0)
            return b, None
        b, _ = jax.lax.scan(body, buf, jnp.arange(16))
        return b

    buf = jnp.zeros((16, 1024))
    x = jnp.zeros((1024,))
    a = analyze_hlo(_compile(f, buf, x).as_text())
    # traffic should be ~16 updates of 4KB (64KB), far below 16 full-buffer
    # writes (1MB)
    assert a.traffic_bytes < 0.5 * 16 * buf.size * 4


def test_roofline_terms_math():
    terms = roofline_terms(
        hlo_flops=197e12,          # exactly one chip-second of compute
        hlo_bytes=819e9,           # one chip-second of HBM
        collectives={"all-reduce": 100e9},
        group_sizes={"all-reduce": 16},
        hw=HW_V5E)
    compute_s, memory_s, collective_s = terms
    assert compute_s == pytest.approx(1.0)
    assert memory_s == pytest.approx(1.0)
    # all-reduce ring factor 2·15/16 over 4×50GB/s links
    assert collective_s == pytest.approx(100e9 * 2 * 15 / 16 / 200e9)


def test_ring_factors():
    assert _ring_factor("all-gather", 16) == pytest.approx(15 / 16)
    assert _ring_factor("reduce-scatter", 16) == 15
    assert _ring_factor("all-reduce", 2) == pytest.approx(1.0)
    assert _ring_factor("all-reduce", 1) == 0.0
    assert _ring_factor("collective-permute", 8) == 1.0
