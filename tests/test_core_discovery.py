"""Unit + property tests for the Discovery Space data model (TRACE)."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, MeasurementError, ProbabilitySpace,
                        SampleStore)


def make_space():
    return ProbabilitySpace.make([
        Dimension.categorical("gpu_model", ["A100", "V100", "T4"]),
        Dimension.discrete("batch_size", [2, 4, 8]),
        Dimension.discrete("cores", [1, 2, 4, 8]),
    ])


CALLS = []


def make_experiment(name="gpu_flops", noise=0.0):
    def fn(config):
        CALLS.append(config.digest)
        base = {"A100": 3.0, "V100": 2.0, "T4": 1.0}[config["gpu_model"]]
        return {"tflops": base * math.log2(config["batch_size"]) + 0.1 * config["cores"]}
    return FunctionExperiment(fn=fn, properties=("tflops",), name=name)


def make_ds(store=None):
    return DiscoverySpace(
        space=make_space(),
        actions=ActionSpace.make([make_experiment()]),
        store=store or SampleStore(":memory:"),
    )


# ---------------------------------------------------------------- basic model


def test_space_size_and_enumeration():
    space = make_space()
    assert space.size == 3 * 3 * 4
    assert len(list(space.all_configurations())) == space.size


def test_configuration_identity_is_content_hash():
    a = Configuration.make({"x": 1, "y": "b"})
    b = Configuration.make({"y": "b", "x": 1})
    assert a.digest == b.digest
    c = Configuration.make({"x": 2, "y": "b"})
    assert a.digest != c.digest


def test_sample_and_read_roundtrip():
    ds = make_ds()
    config = Configuration.make({"gpu_model": "A100", "batch_size": 4, "cores": 2})
    s = ds.sample(config)
    assert s.has("tflops")
    assert s.value("tflops") == pytest.approx(3.0 * 2 + 0.2)
    read = ds.read()
    assert len(read) == 1
    assert read[0].configuration.digest == config.digest


# ----------------------------------------------------------------- Encapsulated


def test_encapsulated_rejects_foreign_configuration():
    ds = make_ds()
    bad = Configuration.make({"gpu_model": "H100", "batch_size": 4, "cores": 2})
    with pytest.raises(ValueError):
        ds.sample(bad)
    bad_dims = Configuration.make({"gpu_model": "A100", "batch_size": 4})
    with pytest.raises(ValueError):
        ds.sample(bad_dims)


def test_encapsulated_read_filters_by_action_space():
    """Values from experiments NOT in this space's action space are invisible."""
    store = SampleStore(":memory:")
    ds1 = make_ds(store)
    config = Configuration.make({"gpu_model": "A100", "batch_size": 4, "cores": 2})
    ds1.sample(config)

    other_exp = FunctionExperiment(
        fn=lambda c: {"watts": 400.0}, properties=("watts",), name="power")
    ds2 = DiscoverySpace(space=make_space(), actions=ActionSpace.make([other_exp]),
                         store=store)
    s2 = ds2.sample(config)
    assert s2.has("watts") and not s2.has("tflops")
    # and ds1 never sees watts
    s1 = ds1.read()[0]
    assert s1.has("tflops") and not s1.has("watts")


# ----------------------------------------------------------------- Reconcilable


def test_reconcilable_foreign_data_invisible_until_sampled():
    """Paper §III-C4: data written via space B is not readable via space A
    until A's sample() generates that configuration; then it is reused."""
    store = SampleStore(":memory:")
    ds_a = make_ds(store)
    ds_b = DiscoverySpace(space=make_space(),
                          actions=ActionSpace.make([make_experiment()]),
                          store=store, space_id="space-b")
    config = Configuration.make({"gpu_model": "V100", "batch_size": 8, "cores": 4})

    CALLS.clear()
    ds_b.sample(config)
    assert len(CALLS) == 1
    # A cannot read it yet
    assert ds_a.read() == []
    assert ds_a.read_one(config) is None
    # A samples it -> REUSED from the common context, not re-measured
    s = ds_a.sample(config)
    assert len(CALLS) == 1  # no second measurement
    assert s.value("tflops") == pytest.approx(2.0 * 3 + 0.4)
    assert ds_a.timeseries()[-1].action == "reused"


def test_reuse_within_same_space():
    ds = make_ds()
    config = Configuration.make({"gpu_model": "T4", "batch_size": 2, "cores": 1})
    CALLS.clear()
    ds.sample(config)
    ds.sample(config)
    assert len(CALLS) == 1
    actions = [r.action for r in ds.timeseries()]
    assert actions == ["measured", "reused"]


# ----------------------------------------------------------------- Time-Resolved


def test_time_resolved_record_sequence():
    ds = make_ds()
    op = ds.begin_operation("exploration")
    rng = np.random.default_rng(0)
    for _ in range(5):
        ds.sample(rng=rng, operation_id=op)
    records = ds.timeseries(op)
    assert [r.seq for r in records] == list(range(len(records)))
    times = [r.created_at for r in records]
    assert times == sorted(times)
    # distinct operations have independent sequences
    op2 = ds.begin_operation("exploration")
    ds.sample(rng=rng, operation_id=op2)
    assert ds.timeseries(op2)[0].seq == 0


# ----------------------------------------------------------------- Actionable


def test_actionable_remaining_configurations():
    ds = make_ds()
    total = ds.space.size
    rng = np.random.default_rng(1)
    for _ in range(7):
        ds.sample(rng=rng)
    sampled = ds.count_sampled()
    remaining = list(ds.remaining_configurations())
    assert sampled + len(remaining) == total
    digs = {c.digest for c in remaining} | {c.digest for c in ds.sampled_configurations()}
    assert len(digs) == total


def test_failed_measurements_recorded():
    def fn(config):
        if config["gpu_model"] == "T4":
            raise MeasurementError("OOM on T4")
        return {"tflops": 1.0}

    exp = FunctionExperiment(fn=fn, properties=("tflops",), name="flaky")
    ds = DiscoverySpace(space=make_space(), actions=ActionSpace.make([exp]))
    good = Configuration.make({"gpu_model": "A100", "batch_size": 2, "cores": 1})
    bad = Configuration.make({"gpu_model": "T4", "batch_size": 2, "cores": 1})
    ds.sample(good)
    with pytest.raises(MeasurementError):
        ds.sample(bad)
    assert ds.count_sampled() == 1  # failed points excluded from {x}
    assert [r.action for r in ds.timeseries()] == ["measured", "failed"]
    # failed points are not retried as 'remaining'
    assert bad.digest not in {c.digest for c in ds.remaining_configurations()}


# ----------------------------------------------------------------- Common Context


def test_common_context_shared_store_file(tmp_path):
    path = str(tmp_path / "store.db")
    store1 = SampleStore(path)
    ds1 = make_ds(store1)
    config = Configuration.make({"gpu_model": "A100", "batch_size": 8, "cores": 8})
    ds1.sample(config)
    store1.close()
    # a different process/session opens the same common context
    store2 = SampleStore(path)
    ds2 = make_ds(store2)  # same (Ω, A) => same space_id => same study
    assert ds2.count_sampled() == 1
    assert ds2.read()[0].value("tflops") == pytest.approx(3.0 * 3 + 0.8)
    store2.close()


# ----------------------------------------------------------------- property tests


finite_dims = st.lists(
    st.sampled_from([
        Dimension.categorical("a", ["x", "y", "z"]),
        Dimension.discrete("b", [1, 2, 3, 4]),
        Dimension.discrete("c", [10, 20]),
        Dimension.categorical("d", ["p", "q"]),
    ]),
    min_size=1, max_size=4, unique_by=lambda d: d.name,
)


@given(dims=finite_dims, seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_property_sampling_stays_in_space(dims, seed):
    space = ProbabilitySpace.make(dims)
    rng = np.random.default_rng(seed)
    for _ in range(5):
        c = space.sample_configuration(rng)
        assert space.contains(c)
        # encode/decode roundtrip is identity for finite dims
        assert space.decode(space.encode(c)).digest == c.digest


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 20))
@settings(max_examples=20, deadline=None)
def test_property_measure_count_equals_distinct_configs(seed, n):
    """Invariant: #measurements == #distinct configurations ever sampled,
    regardless of how many times or through which spaces they were drawn
    (transparent reuse never re-measures)."""
    store = SampleStore(":memory:")
    ds_a = make_ds(store)
    ds_b = DiscoverySpace(space=make_space(),
                          actions=ActionSpace.make([make_experiment()]),
                          store=store, space_id="b")
    CALLS.clear()
    rng = np.random.default_rng(seed)
    seen = set()
    for i in range(n):
        ds = ds_a if rng.uniform() < 0.5 else ds_b
        c = ds.space.sample_configuration(rng)
        seen.add(c.digest)
        ds.sample(c)
    assert len(CALLS) == len(seen)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_property_read_is_stateless_and_idempotent(seed):
    ds = make_ds()
    rng = np.random.default_rng(seed)
    for _ in range(6):
        ds.sample(rng=rng)
    r1 = {s.configuration.digest: s.value("tflops") for s in ds.read()}
    r2 = {s.configuration.digest: s.value("tflops") for s in ds.read()}
    assert r1 == r2
