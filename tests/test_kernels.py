"""Kernel validation: Pallas (interpret=True) and XLA paths vs jnp oracles.

Sweeps shapes/dtypes per kernel and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gmm import gmm_pallas, gmm_stacked_pallas
from repro.kernels.ref import (attention_ref, decode_attention_ref, gmm_ref,
                               rglru_ref)
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.xla_attn import attention_banded


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


# ---------------------------------------------------------------- attention


ATTN_CASES = [
    # (B, Sq, Sk, H, Hkv, D, causal, window, dtype)
    (1, 64, 64, 4, 4, 32, True, None, jnp.float32),
    (2, 128, 128, 8, 2, 64, True, None, jnp.float32),
    (2, 128, 128, 8, 2, 64, True, 32, jnp.float32),
    (1, 96, 96, 4, 1, 16, True, None, jnp.float32),   # odd length, GQA=4
    (2, 64, 64, 4, 4, 32, False, None, jnp.float32),  # encoder
    (2, 64, 64, 4, 2, 32, True, None, jnp.bfloat16),
    (1, 128, 128, 2, 2, 128, True, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_attention_vs_ref(case, impl):
    B, Sq, Sk, H, Hkv, D, causal, window, dtype = case
    rng = np.random.default_rng(42)
    q = rand(rng, (B, Sq, H, D), dtype)
    k = rand(rng, (B, Sk, Hkv, D), dtype)
    v = rand(rng, (B, Sk, Hkv, D), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    got = ops.attention(q, k, v, causal=causal, window=window, impl=impl,
                        q_chunk=32, kv_chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_attention_banded_gradients_match_ref():
    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 2, 128, 4, 2, 32
    q = rand(rng, (B, S, H, D), jnp.float32)
    k = rand(rng, (B, S, Hkv, D), jnp.float32)
    v = rand(rng, (B, S, Hkv, D), jnp.float32)

    def loss_ref(q, k, v):
        return (attention_ref(q, k, v, causal=True, window=48) ** 2).sum()

    def loss_band(q, k, v):
        return (ops.attention(q, k, v, causal=True, window=48, impl="xla",
                              q_chunk=32, kv_chunk=32) ** 2).sum()

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(loss_band, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gb):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


@given(
    sq=st.integers(1, 5), sk=st.integers(1, 5),
    hkv=st.sampled_from([1, 2]), g=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
    window=st.sampled_from([None, 8, 16]),
    seed=st.integers(0, 100),
)
@settings(max_examples=25, deadline=None)
@pytest.mark.slow
def test_property_banded_equals_ref(sq, sk, hkv, g, causal, window, seed):
    """Banded attention == oracle for arbitrary chunkings/shapes (queries at
    the causal suffix: q_offset = Sk - Sq >= 0; fully-masked rows are
    degenerate in the oracle and excluded by construction)."""
    Sq, Sk = sq * 16, sk * 16
    if Sq > Sk:
        Sq = Sk
    q_offset = Sk - Sq
    if not causal and window is not None and q_offset > 0:
        q_offset = 0
        Sq = Sk  # symmetric-window encoder: keep query/key sets aligned
    rng = np.random.default_rng(seed)
    q = rand(rng, (1, Sq, hkv * g, 16), jnp.float32)
    k = rand(rng, (1, Sk, hkv, 16), jnp.float32)
    v = rand(rng, (1, Sk, hkv, 16), jnp.float32)
    ref = attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
    got = attention_banded(q, k, v, causal, window, q_offset, 16, 16, True, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_decode_attention_ring_buffer():
    """Ring-buffer window cache == full cache with window mask."""
    rng = np.random.default_rng(1)
    B, H, Hkv, D, S, W = 2, 4, 2, 32, 64, 16
    q = rand(rng, (B, 1, H, D), jnp.float32)
    k_full = rand(rng, (B, S, Hkv, D), jnp.float32)
    v_full = rand(rng, (B, S, Hkv, D), jnp.float32)
    index = S - 1
    ref = decode_attention_ref(q, k_full, v_full, index=index, window=W)
    # ring layout: position p at slot p % W; valid positions index-W+1..index
    slots = np.array([(index - ((index - s) % W)) for s in range(W)])
    k_ring = k_full[:, slots]
    v_ring = v_full[:, slots]
    got = decode_attention_ref(q, k_ring, v_ring, index=index, window=W, ring=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- RG-LRU


RGLRU_CASES = [
    (1, 64, 32, jnp.float32, None),
    # the two big-sequence cases compile for ~5-7 s each on CPU under the
    # xla impl; the small cases already cover both h0 modes + block_d < D,
    # so the big shapes run in the slow tier
    pytest.param((2, 128, 64, jnp.float32, "h0"), marks=pytest.mark.slow),
    pytest.param((2, 256, 128, jnp.bfloat16, None), marks=pytest.mark.slow),
    (1, 128, 96, jnp.float32, "h0"),   # block_d smaller than D
]


@pytest.mark.parametrize("case", RGLRU_CASES)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_rglru_vs_ref(case, impl):
    B, S, D, dtype, h0_kind = case
    rng = np.random.default_rng(7)
    x = rand(rng, (B, S, D), dtype)
    ga = rand(rng, (B, S, D), dtype)
    gx = rand(rng, (B, S, D), dtype)
    log_a = jnp.asarray(np.log(-np.log(rng.uniform(0.9, 0.999, D))), jnp.float32)
    h0 = rand(rng, (B, D), jnp.float32) if h0_kind else None
    ref_h, ref_last = rglru_ref(x, log_a, ga, gx, h0)
    got_h, got_last = ops.rglru(x, log_a, ga, gx, h0, impl=impl,
                                block_d=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got_h, np.float32),
                               np.asarray(ref_h, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(got_last), np.asarray(ref_last),
                               rtol=1e-3, atol=1e-3)


def test_rglru_pallas_chunking_invariance():
    rng = np.random.default_rng(3)
    B, S, D = 2, 128, 64
    x = rand(rng, (B, S, D), jnp.float32)
    ga = rand(rng, (B, S, D), jnp.float32)
    gx = rand(rng, (B, S, D), jnp.float32)
    log_a = jnp.asarray(np.log(-np.log(rng.uniform(0.9, 0.999, D))), jnp.float32)
    h1, l1 = rglru_pallas(x, log_a, ga, gx, block_d=64, chunk_t=128)
    h2, l2 = rglru_pallas(x, log_a, ga, gx, block_d=16, chunk_t=32)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- grouped matmul


GMM_CASES = [
    (4, 32, 16, 24, jnp.float32),
    (3, 64, 32, 48, jnp.float32),
    (2, 128, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", GMM_CASES)
def test_gmm_stacked_vs_einsum(case):
    E, C, d, f, dtype = case
    rng = np.random.default_rng(11)
    xs = rand(rng, (E, C, d), dtype)
    w = rand(rng, (E, d, f), dtype)
    ref = jnp.einsum("ecd,edf->ecf", xs.astype(jnp.float32),
                     w.astype(jnp.float32))
    got = gmm_stacked_pallas(xs, w, block_m=16, block_n=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref),
                               **tol(dtype))


@given(e=st.integers(2, 5), t=st.integers(4, 24), seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
@pytest.mark.slow
def test_property_gmm_dynamic_groups(e, t, seed):
    rng = np.random.default_rng(seed)
    d, f = 8, 12
    sizes = rng.multinomial(t, np.ones(e) / e)
    x = rand(rng, (t, d), jnp.float32)
    w = rand(rng, (e, d, f), jnp.float32)
    gs = jnp.asarray(sizes)
    ref = gmm_ref(x, w, gs)
    got = gmm_pallas(x, w, gs, block_m=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_matches_dense_when_no_drops():
    """The capacity path equals the dense oracle when capacity is generous."""
    from repro.configs import get_config
    from repro.models import moe as moe_mod
    from repro.models.common import init_tree
    from repro.models.moe import MoEOptions

    cfg = get_config("granite-moe-3b-a800m", smoke=True)
    params = init_tree(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    x = rand(rng, (2, 16, cfg.d_model), jnp.float32)
    y_dense, aux1 = moe_mod.moe_apply(params, x, cfg,
                                      MoEOptions(impl="dense"))
    y_cap, aux2 = moe_mod.moe_apply(
        params, x, cfg, MoEOptions(impl="capacity", capacity_factor=50.0,
                                   min_capacity=64))
    y_gmm, aux3 = moe_mod.moe_apply(params, x, cfg, MoEOptions(impl="gmm"))
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_gmm), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)
    assert np.isclose(float(aux1), float(aux2))
