"""Backend-conformance suite: every StoreBackend honors the same contract.

Each test runs against BOTH backends via the ``backend`` fixture:

* ``sqlite`` — the reference :class:`~repro.core.store.sqlite.SampleStore`
  on a temp file;
* ``server`` — an in-process :class:`~repro.core.store.server.StoreServer`
  over the same SQLite store, reached through a
  :class:`~repro.core.store.client.ClientStore` socket connection.

The served pair shares one FakeClock with the test body, so lease/sweep
behavior is driven deterministically on both sides of the wire.  Covers the
contract the rest of the repo relies on: single-winner claims, lease-based
staleness, the priority work queue, watermark paging of ``records_since``,
measure-once under concurrency, and the batched write paths.
"""

from __future__ import annotations

import json
import threading

import pytest

from repro.core import Configuration, FakeClock
from repro.core.entities import PropertyValue
from repro.core.store import open_store
from repro.core.store.base import RECORD_PAGE_SIZE
from repro.core.store.client import ClientStore, StoreRemoteError
from repro.core.store.server import StoreServer
from repro.core.store.sqlite import SampleStore

SPACE = "conformance-space"
OP = "op-main"


def _config(i: int) -> Configuration:
    return Configuration(values=(("size", i), ("tier", f"t{i % 3}")))


@pytest.fixture(params=["sqlite", "server"])
def backend(request, tmp_path):
    """(store, clock): the backend under test + the clock driving it."""
    clock = FakeClock()
    base = SampleStore(str(tmp_path / "store.db"), clock=clock)
    if request.param == "sqlite":
        yield base, clock
        base.close()
        return
    server = StoreServer(base, unix_path=str(tmp_path / "store.sock")).start()
    client = ClientStore(server.url, clock=clock)
    yield client, clock
    client.close()
    server.shutdown()


# ----------------------------------------------------------------- identity


def test_configurations_roundtrip_and_batch(backend):
    store, _ = backend
    configs = [_config(i) for i in range(7)]
    digests = store.put_configurations(configs)
    assert digests == [c.digest for c in configs]
    # batch interning is idempotent and matches the per-item path
    assert store.put_configuration(configs[0]) == digests[0]
    for digest, config in zip(digests, configs):
        assert store.get_configuration(digest) == config
    # the decode survives a cold cache (forces the wire/SQL path)
    store.invalidate_config_cache()
    fetched = store.get_configurations(digests + ["missing-digest"])
    assert fetched == dict(zip(digests, configs))
    assert store.get_configuration("missing-digest") is None


def test_values_roundtrip_types(backend):
    store, clock = backend
    digest = store.put_configuration(_config(1))
    store.put_values(digest, [
        PropertyValue(name="p95_ms", value=12.5, experiment_id="exp-a",
                      predicted=False, timestamp=clock.time()),
        PropertyValue(name="p95_ms", value=11.0, experiment_id="exp-a",
                      predicted=True, timestamp=clock.time()),
    ])
    values = store.get_values(digest)
    assert [(v.name, v.value, v.experiment_id, v.predicted) for v in values] \
        == [("p95_ms", 12.5, "exp-a", False), ("p95_ms", 11.0, "exp-a", True)]
    assert store.get_values(digest, ["other"]) == []
    assert store.has_values(digest, "exp-a")
    assert not store.has_values(digest, "exp-b")


def test_spaces_and_operations(backend):
    store, _ = backend
    store.register_space(SPACE, {"dims": ["size"]}, ["exp-a"],
                         space_digest="omega-digest",
                         meta={"dimensions": ["size"]})
    store.register_operation(OP, SPACE, "optimizer", {"seed": 7})
    spaces = store.list_spaces()
    assert [s["space_id"] for s in spaces] == [SPACE]
    assert spaces[0]["space_digest"] == "omega-digest"
    assert spaces[0]["meta"] == {"dimensions": ["size"]}
    ops = store.operations_for(SPACE)
    assert [(o["operation_id"], o["kind"], o["meta"]) for o in ops] \
        == [(OP, "optimizer", {"seed": 7})]


# ------------------------------------------------------------------- claims


def test_claim_single_winner_across_threads(backend):
    store, _ = backend
    digest = store.put_configuration(_config(1))
    wins = []
    barrier = threading.Barrier(8)

    def racer(i):
        barrier.wait()
        if store.claim_experiment(digest, "exp-a", owner=f"w{i}"):
            wins.append(i)

    threads = [threading.Thread(target=racer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1
    assert store.claim_exists(digest, "exp-a")
    store.release_claim(digest, "exp-a")
    assert not store.claim_exists(digest, "exp-a")


def test_steal_only_after_lease_expiry(backend):
    store, clock = backend
    digest = store.put_configuration(_config(2))
    assert store.claim_experiment(digest, "exp-a", owner="alice", lease_s=10.0)
    # live lease: unstealable no matter how impatient the waiter
    assert not store.steal_claim(digest, "exp-a", "bob", older_than_s=0.0)
    clock.advance(11.0)
    assert store.steal_claim(digest, "exp-a", "bob", older_than_s=30.0)
    # the winner's refreshed lease shuts out the rest of the pack
    assert not store.steal_claim(digest, "exp-a", "carol", older_than_s=30.0)


def test_lease_renewal_and_sweep(backend):
    store, clock = backend
    d1 = store.put_configuration(_config(3))
    d2 = store.put_configuration(_config(4))
    assert store.claim_experiment(d1, "exp-a", owner="alive", lease_s=5.0)
    assert store.claim_experiment(d2, "exp-a", owner="dead", lease_s=5.0)
    clock.advance(4.0)
    assert store.renew_lease("alive", 5.0) == 1  # heartbeat
    clock.advance(2.0)  # dead's lease (t=5) expired; alive's (t=9) has not
    assert store.sweep_stale_claims() == 1
    assert store.claim_exists(d1, "exp-a")
    assert not store.claim_exists(d2, "exp-a")
    assert store.release_claims_owned_by("alive") == 1


def test_wait_for_values_outcomes_and_backoff(backend):
    store, clock = backend
    digest = store.put_configuration(_config(5))
    # no claim, no values -> immediate False (owner vanished)
    assert store.wait_for_values(digest, "exp-a", timeout_s=30.0) is False
    # values present -> immediate True
    store.put_values(digest, [PropertyValue(
        name="m", value=1.0, experiment_id="exp-a", predicted=False,
        timestamp=clock.time())])
    assert store.wait_for_values(digest, "exp-a", timeout_s=30.0) is True
    # a held claim with no values runs to timeout — and the exponential
    # backoff keeps the poll count logarithmic-then-capped instead of
    # hammering at a fixed interval (the satellite-1 fix): 60 s at the old
    # fixed 50 ms interval would be 1200 polls
    d2 = store.put_configuration(_config(6))
    assert store.claim_experiment(d2, "exp-a", owner="slow", lease_s=3600.0)
    polls = {"n": 0}
    original = store._poll_cell

    def counting(*args, **kwargs):
        polls["n"] += 1
        return original(*args, **kwargs)

    store._poll_cell = counting
    try:
        assert store.wait_for_values(d2, "exp-a", timeout_s=60.0) is False
    finally:
        del store._poll_cell
    assert 10 <= polls["n"] <= 300


# --------------------------------------------------------------- work queue


def test_work_queue_priority_order_and_batching(backend):
    store, _ = backend
    digests = store.put_configurations([_config(i) for i in range(5)])
    items = [store.enqueue_work(SPACE, d, priority=p)
             for d, p in zip(digests, [0.1, 2.0, 1.0, 2.0, 0.5])]
    first = store.claim_work_batch("w1", limit=3, space_id=SPACE)
    # best priority first, FIFO within the 2.0 tie
    assert [c["item_id"] for c in first] == [items[1], items[3], items[2]]
    assert store.pending_work(SPACE) == 5
    assert store.finish_work_batch(
        [(c["item_id"], "measured", None) for c in first], owner="w1") == 3
    rest = store.claim_work_batch("w2", limit=10, space_id=SPACE)
    assert [c["item_id"] for c in rest] == [items[4], items[0]]
    assert store.finish_work(rest[0]["item_id"], "failed", "boom",
                             owner="w2")
    results = store.fetch_work_results(items)
    assert results[items[1]] == ("measured", None)
    assert results[items[4]] == ("failed", "boom")
    stats = store.work_queue_stats(SPACE)
    assert (stats["queued"], stats["running"], stats["done"]) == (0, 1, 4)


def test_stale_work_requeue_and_owner_guard(backend):
    store, clock = backend
    digest = store.put_configuration(_config(9))
    item = store.enqueue_work(SPACE, digest, priority=1.5)
    claim = store.claim_work("ghost", space_id=SPACE, lease_s=5.0)
    assert claim["item_id"] == item
    clock.advance(6.0)  # ghost's heartbeats stopped
    assert store.requeue_stale_work() == 1
    reclaim = store.claim_work("survivor", space_id=SPACE, lease_s=5.0)
    assert reclaim["item_id"] == item
    assert reclaim["priority"] == 1.5  # priority survives the re-queue
    # the ghost coming back to life cannot overwrite the re-execution
    assert store.finish_work_batch([(item, "measured", None)],
                                   owner="ghost") == 0
    assert store.finish_work_batch([(item, "measured", None)],
                                   owner="survivor") == 1


def test_claim_work_batch_partitions_under_race(backend):
    store, _ = backend
    digests = store.put_configurations([_config(i) for i in range(20)])
    for d in digests:
        store.enqueue_work(SPACE, d)
    claimed: dict = {}
    lock = threading.Lock()
    barrier = threading.Barrier(4)

    def worker(name):
        barrier.wait()
        while True:
            batch = store.claim_work_batch(name, limit=3, space_id=SPACE)
            if not batch:
                return
            with lock:
                for c in batch:
                    assert c["item_id"] not in claimed, "double-claim!"
                    claimed[c["item_id"]] = name

    threads = [threading.Thread(target=worker, args=(f"w{i}",))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(claimed) == 20


# ------------------------------------------------- records & watermark paging


def test_append_records_batch_matches_per_row(backend):
    store, _ = backend
    digests = store.put_configurations([_config(i) for i in range(6)])
    one = store.append_record(SPACE, OP, digests[0], "measured")
    assert (one.seq, one.space_id, one.action) == (0, SPACE, "measured")
    batch = store.append_records(
        SPACE, OP, [(d, "measured") for d in digests[1:4]])
    assert [r.seq for r in batch] == [1, 2, 3]
    assert [r.config_digest for r in batch] == digests[1:4]
    assert batch[0].rowid > one.rowid
    assert store.next_seq(SPACE, OP) == 4
    assert store.append_records(SPACE, OP, []) == []
    # per-operation isolation: a second operation starts its own sequence
    other = store.append_record(SPACE, "op-other", digests[4], "reused")
    assert other.seq == 0
    assert store.count_measured(SPACE) == 4
    assert store.has_record(SPACE, digests[0])
    assert not store.has_record(SPACE, digests[5])
    assert store.sampled_digests(SPACE) == digests[:4] + [digests[4]]


def test_records_since_watermark_paging(backend):
    store, _ = backend
    digests = store.put_configurations([_config(i) for i in range(30)])
    store.append_records(SPACE, OP, [(d, "measured") for d in digests])
    tail = store.last_record_rowid(SPACE)
    assert tail > 0
    # paged iteration sees every record exactly once, in rowid order
    paged = list(store.iter_records_since(SPACE, 0, page_size=7))
    assert [r.config_digest for r in paged] == digests
    assert [r.rowid for r in paged] == sorted(r.rowid for r in paged)
    # consume returns the snapshot tail as the new watermark
    records, watermark = store.consume_records_since(SPACE, 0, page_size=7)
    assert watermark == tail
    assert len(records) == 30
    # resuming from the watermark is empty until new rows land
    assert store.consume_records_since(SPACE, watermark) == ([], watermark)
    store.append_record(SPACE, "op-other", digests[0], "reused")
    fresh, new_mark = store.consume_records_since(SPACE, watermark)
    assert [r.action for r in fresh] == ["reused"]
    assert new_mark == store.last_record_rowid(SPACE)
    # exclude_operation drops rows server-side but still advances the mark
    same, mark2 = store.consume_records_since(
        SPACE, watermark, exclude_operation="op-other")
    assert same == [] and mark2 == new_mark
    # upto_rowid bounds a page at a snapshot
    bounded = store.records_since(SPACE, 0, upto_rowid=paged[9].rowid)
    assert len(bounded) == 10


def test_records_since_page_boundary_exact_multiple(backend):
    store, _ = backend
    digests = store.put_configurations(
        [_config(i) for i in range(2 * RECORD_PAGE_SIZE // 128)])
    events = [(d, "measured") for d in digests]
    store.append_records(SPACE, OP, events)
    # page_size dividing the row count exactly must not loop or drop rows
    page_size = len(events) // 2
    got = list(store.iter_records_since(SPACE, 0, page_size=page_size))
    assert len(got) == len(events)


def test_measured_property_values_latest_wins(backend):
    store, clock = backend
    digests = store.put_configurations([_config(i) for i in range(3)])
    store.append_records(SPACE, OP, [(d, "measured") for d in digests[:2]]
                         + [(digests[2], "failed")])
    for i, d in enumerate(digests[:2]):
        store.put_values(d, [PropertyValue(
            name="cost", value=float(i), experiment_id="exp-a",
            predicted=False, timestamp=clock.time())])
    # re-measurement: the later value wins
    store.put_values(digests[0], [PropertyValue(
        name="cost", value=9.0, experiment_id="exp-a", predicted=False,
        timestamp=clock.time())])
    # predicted values never surface here
    store.put_values(digests[1], [PropertyValue(
        name="cost", value=99.0, experiment_id="exp-a", predicted=True,
        timestamp=clock.time())])
    pairs = store.measured_property_values(SPACE, "cost")
    assert [(dict(c.values)["size"], v) for c, v in pairs] \
        == [(0, 9.0), (1, 1.0)]  # failed config absent, order = appearance


# ------------------------------------------------------- frontier view


def _put_point(store, clock, i, cost, lat, action="measured", exp="exp-a",
               predicted=False):
    """One sampled configuration with (cost, lat) values recorded."""
    digest = store.put_configuration(_config(i))
    store.append_record(SPACE, OP, digest, action)
    store.put_values(digest, [
        PropertyValue(name="cost", value=cost, experiment_id=exp,
                      predicted=predicted, timestamp=clock.time()),
        PropertyValue(name="lat", value=lat, experiment_id=exp,
                      predicted=predicted, timestamp=clock.time()),
    ])
    return digest


def test_frontier_dominance_and_order(backend):
    store, clock = backend
    _put_point(store, clock, 0, 1.0, 9.0)   # frontier (cheap, slow)
    _put_point(store, clock, 1, 5.0, 5.0)   # dominated by config 3
    _put_point(store, clock, 2, 9.0, 1.0)   # frontier (dear, fast)
    _put_point(store, clock, 3, 4.0, 4.0)   # frontier (middle)
    front = store.frontier(SPACE, ["cost", "lat"])
    # non-dominated only, first-sampled order, values aligned to properties
    assert [(dict(c.values)["size"], v) for c, v in front] \
        == [(0, (1.0, 9.0)), (2, (9.0, 1.0)), (3, (4.0, 4.0))]
    # modes flip the dominance orientation per coordinate
    worst = store.frontier(SPACE, ["cost", "lat"], modes=["max", "max"])
    assert {dict(c.values)["size"] for c, _ in worst} == {1, 2, 0}
    # single property: the frontier degenerates to the argmin
    assert [v for _, v in store.frontier(SPACE, ["cost"])] == [(1.0,)]


def test_frontier_excludes_failed_predicted_incomplete(backend):
    store, clock = backend
    _put_point(store, clock, 0, 5.0, 5.0)
    # a strictly-better point whose only record is a failed deployment
    _put_point(store, clock, 1, 1.0, 1.0, action="failed")
    # a strictly-better point whose values are surrogate predictions
    _put_point(store, clock, 2, 0.5, 0.5, predicted=True)
    # a strictly-better point missing one of the requested properties
    d3 = store.put_configuration(_config(3))
    store.append_record(SPACE, OP, d3, "measured")
    store.put_values(d3, [PropertyValue(
        name="cost", value=0.1, experiment_id="exp-a", predicted=False,
        timestamp=clock.time())])
    front = store.frontier(SPACE, ["cost", "lat"])
    assert [(dict(c.values)["size"], v) for c, v in front] \
        == [(0, (5.0, 5.0))]
    # ...but a foreign experiment's measurements are excluded only when the
    # caller scopes the view to its own action space
    _put_point(store, clock, 4, 2.0, 2.0, exp="exp-other")
    assert {dict(c.values)["size"] for c, _ in
            store.frontier(SPACE, ["cost", "lat"])} == {4}
    assert {dict(c.values)["size"] for c, _ in
            store.frontier(SPACE, ["cost", "lat"],
                           experiment_ids=["exp-a"])} == {0}


def test_frontier_latest_measurement_wins(backend):
    store, clock = backend
    d0 = _put_point(store, clock, 0, 1.0, 1.0)
    _put_point(store, clock, 1, 3.0, 3.0)
    # config 0 is re-measured to a dominated position: the later write wins
    # and config 1 joins the frontier
    store.put_values(d0, [PropertyValue(
        name="cost", value=4.0, experiment_id="exp-a", predicted=False,
        timestamp=clock.time())])
    front = store.frontier(SPACE, ["cost", "lat"])
    assert [(dict(c.values)["size"], v) for c, v in front] \
        == [(0, (4.0, 1.0)), (1, (3.0, 3.0))]


def test_frontier_validates_and_empty(backend):
    store, _ = backend
    assert store.frontier(SPACE, ["cost", "lat"]) == []
    with pytest.raises((ValueError, StoreRemoteError)):
        store.frontier(SPACE, [])


def test_frontier_under_concurrent_appends(backend):
    """Writers racing on the record/value tables never corrupt the view:
    afterwards the frontier equals the pure-math frontier of everything
    written, on both backends."""
    from repro.core.pareto import pareto_front

    store, clock = backend
    # staircase points are all mutually non-dominated; interior points never
    # surface.  8 writers x 6 points each.
    def writer(w):
        for j in range(6):
            i = w * 6 + j
            if i % 3 == 0:
                _put_point(store, clock, i, 1.0 + i, 100.0 - i)  # staircase
            else:
                _put_point(store, clock, i, 200.0 + i, 200.0 + i)  # interior

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    front = store.frontier(SPACE, ["cost", "lat"])
    expected = {(1.0 + i, 100.0 - i) for i in range(48) if i % 3 == 0}
    assert {v for _, v in front} == expected
    # and the store agrees with the reference dominance filter
    pts = [v for _, v in front]
    assert pareto_front(pts) == list(range(len(pts)))


# ---------------------------------------------- measure-once, cross-backend


def test_measure_once_across_backend_boundary(tmp_path):
    """A served client and a direct SQLite handle racing on one database
    still measure each cell exactly once (the claim arbitration is the
    database transaction, whichever door the request came through)."""
    db = str(tmp_path / "shared.db")
    direct = SampleStore(db)
    server = StoreServer(SampleStore(db),
                         unix_path=str(tmp_path / "s.sock")).start()
    client = ClientStore(server.url)
    try:
        digest = direct.put_configuration(_config(0))
        wins = []
        barrier = threading.Barrier(2)

        def race(store, name):
            barrier.wait()
            if store.claim_experiment(digest, "exp-a", owner=name):
                wins.append(name)

        threads = [threading.Thread(target=race, args=(direct, "direct")),
                   threading.Thread(target=race, args=(client, "served"))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(wins) == 1
    finally:
        client.close()
        server.shutdown()
        direct.close()


# ------------------------------------------------------------ wire specifics


def test_server_rejects_unknown_method_and_survives_errors(backend):
    store, _ = backend
    if not isinstance(store, ClientStore):
        pytest.skip("wire-protocol specifics")
    with pytest.raises(StoreRemoteError):
        store._call("drop_all_tables")
    # a failing request poisons neither the connection nor the server
    with pytest.raises(StoreRemoteError):
        store._call("claim_experiment")  # missing args -> TypeError remotely
    assert store._call("ping") == "pong"


def test_client_pipelining_order(backend):
    store, _ = backend
    if not isinstance(store, ClientStore):
        pytest.skip("wire-protocol specifics")
    digests = store.put_configurations([_config(i) for i in range(4)])
    results = store._call_many(
        [("has_record", [SPACE, d, False]) for d in digests]
        + [("ping", [])])
    assert results == [False, False, False, False, "pong"]


def test_json_codec_fallback(tmp_path):
    base = SampleStore(str(tmp_path / "j.db"))
    server = StoreServer(base, unix_path=str(tmp_path / "j.sock")).start()
    client = ClientStore(server.url, codec=b"J")
    try:
        config = _config(3)
        digest = client.put_configuration(config)
        client.invalidate_config_cache()
        assert client.get_configuration(digest) == config
        rec = client.append_record(SPACE, OP, digest, "measured")
        assert rec.seq == 0 and rec.rowid > 0
    finally:
        client.close()
        server.shutdown()


def test_open_store_dispatch(tmp_path):
    db = str(tmp_path / "o.db")
    assert isinstance(open_store(db), SampleStore)
    server = StoreServer(SampleStore(db),
                         unix_path=str(tmp_path / "o.sock")).start()
    try:
        client = open_store(server.url)
        assert isinstance(client, ClientStore)
        assert client.path == server.url
        client.close()
    finally:
        server.shutdown()
    with pytest.raises(ValueError):
        open_store("tcp://no-port")


# ------------------------------------------------------------- index usage


def _plan(store: SampleStore, sql: str, params=()) -> str:
    return " ".join(str(row[3]) for row in
                    store._rows(f"EXPLAIN QUERY PLAN {sql}", params))


def test_sweeps_are_index_driven(tmp_path):
    """The satellite-3 guarantee: stale-claim/stale-work sweeps run off the
    covering indexes, not full-table scans — O(stale rows) per sweep at
    10⁶-row depth."""
    store = SampleStore(str(tmp_path / "idx.db"))
    plan = _plan(store,
                 "DELETE FROM value_claims WHERE lease_expires_at < ?",
                 (0.0,))
    assert "vc_lease" in plan, plan
    plan = _plan(store,
                 "UPDATE work_items SET status='queued'"
                 " WHERE status='running' AND lease_expires_at < ?", (0.0,))
    assert "wi_lease" in plan, plan
    # the space-scoped queue pop and the catalog stats scan are covered too
    plan = _plan(store,
                 "SELECT item_id FROM work_items"
                 " WHERE status='queued' AND space_id=?"
                 " ORDER BY priority DESC, created_at, rowid LIMIT 1",
                 ("s",))
    assert "wi_prio" in plan, plan
    plan = _plan(store,
                 "SELECT space_id, COUNT(*), SUM(action='measured'),"
                 " SUM(action='failed'), COUNT(DISTINCT config_digest)"
                 " FROM records GROUP BY space_id")
    assert "rec_stats" in plan, plan
    store.close()


# ----------------------------------------- measured_property_values decode


def test_measured_property_values_decodes_once_per_digest(tmp_path,
                                                          monkeypatch):
    """Satellite-2 regression: on a 10⁴-row space the read decodes each
    configuration once per DISTINCT digest, not once per value row (the old
    JOIN shipped + decoded the config JSON on every property row)."""
    store = SampleStore(str(tmp_path / "n1.db"))
    n_distinct, rows_per = 100, 100  # 10⁴ value rows over 100 configs
    configs = [_config(i) for i in range(n_distinct)]
    digests = store.put_configurations(configs)
    store.append_records(SPACE, OP, [(d, "measured") for d in digests])
    for digest in digests:
        store.put_values(digest, [
            PropertyValue(name="cost", value=float(k), experiment_id="e",
                          predicted=False, timestamp=0.0)
            for k in range(rows_per)])
    store.close()

    fresh = SampleStore(str(tmp_path / "n1.db"))  # cold cache
    from repro.core.store import sqlite as sqlite_mod
    decodes = {"n": 0}
    real_loads = json.loads

    def counting_loads(s, *a, **k):
        decodes["n"] += 1
        return real_loads(s, *a, **k)

    monkeypatch.setattr(sqlite_mod.json, "loads", counting_loads)
    pairs = fresh.measured_property_values(SPACE, "cost")
    assert len(pairs) == n_distinct
    # last row per digest wins
    assert all(v == float(rows_per - 1) for _, v in pairs)
    assert decodes["n"] <= n_distinct, \
        f"{decodes['n']} decodes for {n_distinct} digests (N+1 regression)"
    # warm cache: a second read decodes nothing
    decodes["n"] = 0
    fresh.measured_property_values(SPACE, "cost")
    assert decodes["n"] == 0
    fresh.close()
