"""Concurrency tests for the common-context SampleStore (paper §III-D).

The distributed-investigation claim rests on many writers sharing one store:
N threads in one process (the ``sample_batch`` worker pool) and N separate
processes (independent investigators) hammer the same space/operation and
must come out with gapless, non-duplicated per-operation ``seq`` numbers and
a reconciled ``read()`` identical to a serial run of the same work.
"""

import multiprocessing
import os
import threading

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, ProbabilitySpace, SampleStore)
from repro.core.entities import canonical_json, content_hash

from _store_workers import OP_ID, SPACE_ID, append_mixed as _append_mixed, \
    append_mixed_process as _append_mixed_process, hammer as _hammer, \
    hammer_process as _hammer_process


def _assert_record_invariants(store: SampleStore, n_events: int) -> None:
    records = store.records_for(SPACE_ID, OP_ID)
    assert len(records) == n_events
    seqs = sorted(r.seq for r in records)
    assert seqs == list(range(n_events)), "per-operation seq must be gapless/unique"


@pytest.mark.parametrize("n_workers,iterations", [(8, 25)])
def test_threads_hammering_one_store(tmp_path, n_workers, iterations):
    store = SampleStore(str(tmp_path / "store.db"))
    threads = [threading.Thread(target=_hammer, args=(store, w, iterations))
               for w in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    _assert_record_invariants(store, n_workers * iterations)
    # every write landed exactly once
    digests = store.sampled_digests(SPACE_ID)
    assert len(digests) == n_workers * iterations
    for w in range(n_workers):
        d = Configuration.make({"worker": w, "i": 0}).digest
        vals = store.get_values(d)
        assert [v.value for v in vals] == [float(w * 1000)]


def test_memory_store_threads():
    """The lock-serialized :memory: path upholds the same invariants."""
    store = SampleStore(":memory:")
    threads = [threading.Thread(target=_hammer, args=(store, w, 10))
               for w in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    _assert_record_invariants(store, 60)


def test_processes_hammering_one_store(tmp_path):
    path = str(tmp_path / "store.db")
    SampleStore(path).close()  # create schema before forking
    ctx = multiprocessing.get_context("spawn")
    procs = [ctx.Process(target=_hammer_process, args=(path, w, 15))
             for w in range(4)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
    assert all(p.exitcode == 0 for p in procs), [p.exitcode for p in procs]
    store = SampleStore(path)
    _assert_record_invariants(store, 60)
    assert store.count_measured(SPACE_ID) == 60


def _reconciled(ds: DiscoverySpace) -> str:
    """Canonical serialization of the reconciled sample set {x} — the
    byte-comparable artifact of a run (timestamps excluded)."""
    payload = sorted(
        (s.configuration.digest,
         sorted((v.name, v.value, v.experiment_id, v.predicted)
                for v in s.properties.values()))
        for s in ds.read()
    )
    return canonical_json(payload)


def _counter_ds(store):
    space = ProbabilitySpace.make([
        Dimension.discrete("x", list(range(8))),
        Dimension.discrete("y", list(range(4))),
    ])
    exp = FunctionExperiment(
        fn=lambda c: {"m": c["x"] * 10.0 + c["y"]}, properties=("m",), name="grid")
    return DiscoverySpace(space=space, actions=ActionSpace.make([exp]), store=store)


def test_concurrent_read_matches_serial_run():
    """Same configurations through 4 workers and serially: identical
    reconciled sample set and identical sampling record actions/seqs."""
    serial = _counter_ds(SampleStore(":memory:"))
    parallel = _counter_ds(SampleStore(":memory:"))
    configs = list(serial.space.all_configurations())

    for c in configs:
        serial.sample(c, operation_id="run")
    parallel.sample_batch(configs, operation_id="run", workers=4)

    assert _reconciled(serial) == _reconciled(parallel)
    rs, rp = serial.timeseries("run"), parallel.timeseries("run")
    assert [(r.seq, r.config_digest, r.action) for r in rs] \
        == [(r.seq, r.config_digest, r.action) for r in rp]


def test_claim_experiment_single_winner_and_takeover():
    """The measure-once arbitration: one winner per (configuration,
    experiment); waiters reuse landed values or take over released claims."""
    from repro.core.entities import PropertyValue

    store = SampleStore(":memory:")
    assert store.claim_experiment("d", "e", "alice")
    assert not store.claim_experiment("d", "e", "bob")
    # owner failed and released: waiter returns False (take over) quickly
    store.release_claim("d", "e")
    assert store.wait_for_values("d", "e", timeout_s=0.5) is False
    assert store.claim_experiment("d", "e", "bob")
    # once values land, waiters come back True (reuse)
    store.put_values("d", [PropertyValue(name="m", value=1.0, experiment_id="e")])
    assert store.wait_for_values("d", "e", timeout_s=0.5) is True
    store.close()


def test_steal_claim_stale_owner_single_winner():
    """A stale claim (presumed-dead owner) is stolen by exactly one waiter;
    fresh claims cannot be stolen."""
    import time as _time

    store = SampleStore(":memory:")
    assert store.claim_experiment("d", "e", "dead-owner")
    assert not store.steal_claim("d", "e", "w0", older_than_s=60.0)
    # expire the claim's lease (the owner stopped renewing), then race two
    # stealers
    store._write("UPDATE value_claims SET lease_expires_at=? WHERE config_digest=?",
                 (_time.time() - 1.0, "d"))
    wins = [store.steal_claim("d", "e", f"w{i}", older_than_s=60.0)
            for i in range(2)]
    assert wins == [True, False]
    store.close()


def test_sample_batch_cross_store_measures_once(tmp_path):
    """Two DiscoverySpace handles (same space, same on-disk store) sampling
    the same batch concurrently: every configuration measured exactly once."""
    path = str(tmp_path / "store.db")
    ds1 = _counter_ds(SampleStore(path))
    ds2 = _counter_ds(SampleStore(path))
    configs = list(ds1.space.all_configurations())

    out = []
    t1 = threading.Thread(
        target=lambda: out.append(ds1.sample_batch(configs, "op-a", workers=4)))
    t2 = threading.Thread(
        target=lambda: out.append(ds2.sample_batch(configs, "op-b", workers=4)))
    t1.start(); t2.start(); t1.join(); t2.join()

    assert ds1.store.count_measured(ds1.space_id) == len(configs)
    assert all(r.ok for results in out for r in results)
    assert _reconciled(ds1) == _reconciled(ds2)


# ------------------------------- seq allocation under concurrent appenders
#
# The invariant the campaign layer's `records_since` watermark sync depends
# on: per-operation seq numbers are gapless, strictly ordered (seq order ==
# rowid/commit order), and duplicate-free no matter how many processes
# append to ONE operation concurrently — mixed single appends and
# multi-event `append_records` transactions included.


def _assert_seq_invariants_and_watermark_sync(store: SampleStore,
                                              n_events: int) -> None:
    records = store.records_for(SPACE_ID, OP_ID)
    assert len(records) == n_events
    seqs = [r.seq for r in records]  # records_for orders by rowid
    assert sorted(seqs) == list(range(n_events)), "seq must be gapless/unique"
    assert seqs == list(range(n_events)), \
        "seq order must equal commit (rowid) order — no reordering window"
    rowids = [r.rowid for r in records]
    assert rowids == sorted(rowids) and len(set(rowids)) == len(rowids)
    # incremental watermark paging sees every record exactly once and in
    # order, regardless of page size
    paged, watermark = [], 0
    while True:
        page = store.records_since(SPACE_ID, watermark, limit=7)
        if not page:
            break
        watermark = page[-1].rowid
        paged.extend(page)
    assert paged == records


def test_concurrent_thread_appenders_keep_seq_gapless():
    store = SampleStore(":memory:")
    rounds, batch, workers = 10, 3, 6
    threads = [threading.Thread(target=_append_mixed,
                                args=(store, w, rounds, batch))
               for w in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    per_worker = (rounds // 2) + (rounds // 2) * batch
    _assert_seq_invariants_and_watermark_sync(store, workers * per_worker)
    store.close()


def test_concurrent_process_appenders_keep_seq_gapless(tmp_path):
    """Multi-process writers to one operation: the atomic in-insert seq
    allocation holds across process boundaries (separate connections, WAL),
    so a watermark reader in any process sees a gapless, strictly-ordered,
    duplicate-free record."""
    path = str(tmp_path / "store.db")
    SampleStore(path).close()  # create schema before forking
    rounds, batch, workers = 8, 3, 4
    ctx = multiprocessing.get_context("spawn")
    procs = [ctx.Process(target=_append_mixed_process,
                         args=(path, w, rounds, batch))
             for w in range(workers)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
    assert all(p.exitcode == 0 for p in procs), [p.exitcode for p in procs]
    per_worker = (rounds // 2) + (rounds // 2) * batch
    _assert_seq_invariants_and_watermark_sync(
        SampleStore(path), workers * per_worker)


# ----------------------------------------------------------- digest stability


config_values = st.dictionaries(
    st.text(min_size=1, max_size=8),
    st.one_of(st.integers(-2 ** 31, 2 ** 31), st.booleans(),
              st.text(max_size=12),
              st.floats(min_value=-1e6, max_value=1e6)),
    min_size=1, max_size=6,
)


@given(mapping=config_values)
@settings(max_examples=50, deadline=None)
def test_property_configuration_digest_roundtrip(mapping):
    """Store round-trip preserves identity: put → get returns a configuration
    with the same canonical_json and the same content-hash digest, and the
    digest is insertion-order independent."""
    store = SampleStore(":memory:")
    config = Configuration.make(mapping)
    reordered = Configuration.make(dict(reversed(list(mapping.items()))))
    assert config.digest == reordered.digest

    digest = store.put_configuration(config)
    restored = store.get_configuration(digest)
    assert restored is not None
    assert canonical_json(restored.values) == canonical_json(config.values)
    assert restored.digest == config.digest == content_hash(config.values)
    store.close()
