"""Module-level connector fixtures for the actuation-lifecycle tests.

Process backends may run under ``spawn`` and the queue worker is a separate
interpreter, so everything a child needs to import lives here (the
``_execution_workers`` pattern).  The flaky connector keeps its attempt
counters in *files* under a state directory derived from the store path, so
retry/teardown counts are observable across process boundaries.
"""

import os
import sys

# Children must resolve `repro` even when launched without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:  # pragma: no cover - depends on launcher env
    sys.path.insert(0, _SRC)

from repro.core import (ActionSpace, DiscoverySpace, Dimension,
                        ProbabilitySpace, SampleStore)
from repro.core.actions import ProvisioningError
from repro.core.connector import (Deployment, ExperimentConnector,
                                  FlatPricing, LifecycleExperiment,
                                  RetryPolicy)

POISON_X = 2   # this coordinate's zone is permanently out of capacity
FLAKES = 2     # healthy configurations fail provisioning this many times
RATE_PER_S = 1.0


def state_dir_for(store_path):
    return store_path + ".state"


def counter(state_dir, kind, digest):
    """Read a phase counter written by :class:`FlakyCloudConnector`."""
    path = os.path.join(state_dir, f"{kind}-{digest}")
    if not os.path.exists(path):
        return 0
    with open(path) as f:
        return int(f.read().strip() or 0)


class FlakyCloudConnector(ExperimentConnector):
    """A cloud that needs ``FLAKES + 1`` provisioning attempts per healthy
    configuration and never provisions the poison one.  Counters live on
    disk so the retry loop (which runs entirely inside one worker's
    ``measure()`` call) is auditable from the test process."""

    name = "flaky-cloud"
    version = "1"

    def __init__(self, state_dir):
        self.state_dir = state_dir

    @property
    def parameterization(self):
        return {"flakes": FLAKES}  # state_dir is host detail, not identity

    @property
    def observed_properties(self):
        return ("m",)

    def _bump(self, kind, digest):
        # one digest is claimed by exactly one worker at a time, so the
        # read-increment-write below never races
        path = os.path.join(self.state_dir, f"{kind}-{digest}")
        n = counter(self.state_dir, kind, digest) + 1
        with open(path, "w") as f:
            f.write(str(n))
        return n

    def provision(self, configuration):
        n = self._bump("provision", configuration.digest)
        if configuration["x"] == POISON_X:
            raise ProvisioningError(f"zone outage (attempt {n})")
        if n <= FLAKES:
            raise ProvisioningError(f"insufficient capacity (attempt {n})")
        return Deployment(ident=f"flaky-{configuration.digest[:12]}",
                          configuration=configuration,
                          handle=configuration.digest)

    def run(self, deployment):
        return {"m": float(deployment.configuration["x"]) * 10.0}

    def teardown(self, deployment):
        self._bump("teardown", deployment.handle)


def flaky_experiment(state_dir):
    return LifecycleExperiment(
        FlakyCloudConnector(state_dir),
        retry=RetryPolicy(provision_attempts=FLAKES + 1, backoff_s=0.0,
                          jitter=0.0),  # zero real sleeps on SYSTEM_CLOCK
        pricing=FlatPricing(rate_per_s=RATE_PER_S))


def build_flaky_ds(store_path):
    """Worker factory: rebuild the same (Ω, A) from the store path — same
    space_id, shared state directory derived from the path."""
    state_dir = state_dir_for(store_path)
    os.makedirs(state_dir, exist_ok=True)
    space = ProbabilitySpace.make([Dimension.discrete("x", [0, 1, 2, 3])])
    return DiscoverySpace(space=space,
                          actions=ActionSpace.make(
                              [flaky_experiment(state_dir)]),
                          store=SampleStore(store_path), claim_timeout_s=5.0)
