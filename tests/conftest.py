"""Shared pytest configuration for the tier-1 suite.

* Puts ``src/`` on ``sys.path`` so the suite runs with or without
  ``PYTHONPATH=src`` / an editable install.
* Registers the ``slow`` marker (long-running integration tests; CI
  deselects them with ``-m "not slow"``).
* Sets a CPU-safe hypothesis profile: bounded examples, no deadline —
  compiled-code tests easily blow hypothesis' default 200 ms deadline on
  CPU.  When the real ``hypothesis`` package is not installed, the
  API-compatible fallback in :mod:`repro._compat.hypothesis_stub` is
  registered in its place so the property tests still collect and run.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    from hypothesis import settings
except ModuleNotFoundError:
    from repro._compat import hypothesis_stub

    sys.modules["hypothesis"] = hypothesis_stub
    sys.modules["hypothesis.strategies"] = hypothesis_stub.strategies
    from hypothesis import settings  # now resolves to the stub

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running integration test (deselect with -m 'not slow')",
    )
