"""Fault-injection suite for the lease-based work queue (deterministic).

Every test drives a :class:`~repro.core.clock.FakeClock` by hand — no real
sleeps, no wall-clock races — and proves the three liveness/safety contracts
of heartbeat leasing:

* a LIVE owner renewing every tick is never reaped, no matter how long its
  measurement runs (``claim_timeout_s`` decoupled from death detection);
* a SILENTLY DEAD owner (heartbeats stopped) is reaped in at most two sweep
  periods — seconds, even when the claim timeout is minutes;
* a reaped owner coming back from the dead cannot overwrite the surviving
  fleet's re-execution (the ``finish_work`` owner guard).
"""

import numpy as np
import pytest

from repro.core import (Configuration, FakeClock, SampleStore)
from repro.core.execution import LeasePacer, WorkItem
from repro.core.execution.worker import run_worker

from _execution_workers import make_line_ds

LEASE_S = 5.0          # heartbeat lease: seconds
SWEEP_PERIOD_S = 3.0   # how often the GC sweeps
CLAIM_TIMEOUT_S = 600.0  # "minutes" — must never gate death detection


def fake_store():
    clock = FakeClock()
    return SampleStore(":memory:", clock=clock), clock


# ------------------------------------------------------- live owners survive


def test_live_owner_renewing_every_tick_is_never_reaped():
    """An owner heartbeating every tick survives arbitrarily many sweeps,
    even far past the original lease horizon (a long cloud measurement)."""
    store, clock = fake_store()
    pacer = LeasePacer(store, "owner-A", LEASE_S)  # beat() by hand: no thread
    assert store.claim_experiment("dig", "exp", "owner-A:123", lease_s=LEASE_S)
    item = store.enqueue_work("space", "dig")
    assert store.claim_work("owner-A", lease_s=LEASE_S)["item_id"] == item

    for _ in range(100):  # 100 ticks = 20x the lease, 50x a claim would allow
        clock.advance(1.0)
        assert pacer.beat() == 2  # value claim + running work item
        reaped = store.sweep_stale_claims()
        requeued = store.requeue_stale_work()
        assert reaped == 0 and requeued == 0
    assert store.claim_exists("dig", "exp")
    assert store.fetch_work_results([item]) == {}  # still running, not lost
    assert store.finish_work(item, "measured", owner="owner-A")


def test_dead_owner_reaped_within_two_sweep_periods():
    """Once heartbeats stop, the lease runs out and the next sweep (at most
    two periods after death) reaps the claim and re-queues the item."""
    store, clock = fake_store()
    assert store.claim_experiment("dig", "exp", "owner-A:123", lease_s=LEASE_S)
    item = store.enqueue_work("space", "dig")
    store.claim_work("owner-A", lease_s=LEASE_S)

    # alive for a while...
    for _ in range(3):
        clock.advance(SWEEP_PERIOD_S)
        store.renew_lease("owner-A", LEASE_S)
        assert store.sweep_stale_claims() == 0
        assert store.requeue_stale_work() == 0

    # ...then silence.  Sweeps keep running on their period; within two of
    # them the lease (5 s) has expired and everything the owner held is
    # recovered.
    reap_times, requeue_times = [], []
    for k in range(1, 4):
        clock.advance(SWEEP_PERIOD_S)
        if store.sweep_stale_claims():
            reap_times.append(k)
        if store.requeue_stale_work():
            requeue_times.append(k)
    assert reap_times and reap_times[0] <= 2
    assert requeue_times and requeue_times[0] <= 2
    assert not store.claim_exists("dig", "exp")
    # the re-queued item is claimable by the surviving fleet, priority intact
    again = store.claim_work("owner-B", lease_s=LEASE_S)
    assert again is not None and again["item_id"] == item


def test_death_detection_independent_of_claim_timeout():
    """The point of leases: reaping horizon ~lease_s, not ~claim_timeout_s."""
    store, clock = fake_store()
    store.claim_experiment("dig", "exp", "dead-owner", lease_s=LEASE_S)
    clock.advance(2 * LEASE_S)  # 10 s of silence; timeout would be 600 s
    assert store.sweep_stale_claims() == 1
    # a non-heartbeating owner still gets the full claim-timeout horizon
    store.claim_experiment("dig2", "exp", "slow-owner",
                           lease_s=CLAIM_TIMEOUT_S)
    clock.advance(CLAIM_TIMEOUT_S / 2)
    assert store.sweep_stale_claims() == 0
    clock.advance(CLAIM_TIMEOUT_S)
    assert store.sweep_stale_claims() == 1


# --------------------------------------------------- stale finishes rejected


def test_stale_finish_from_reaped_owner_is_rejected():
    """Owner-guard regression: a worker that went silent long enough to be
    reaped and re-queued must not land its late outcome over the
    re-execution's — in any interleaving of B's claim and A's late finish."""
    store, clock = fake_store()
    item = store.enqueue_work("space", "dig")
    store.claim_work("worker-A", lease_s=LEASE_S)
    clock.advance(LEASE_S + 1.0)  # A went silent; lease expired
    assert store.requeue_stale_work() == 1

    # interleaving 1: A's zombie finish arrives while the item is queued
    assert store.finish_work(item, "failed", "crash: ...", owner="worker-A") is False
    assert store.fetch_work_results([item]) == {}

    # interleaving 2: B re-claims, then A's zombie finish arrives
    assert store.claim_work("worker-B", lease_s=LEASE_S)["item_id"] == item
    assert store.finish_work(item, "failed", "crash: ...", owner="worker-A") is False
    assert store.fetch_work_results([item]) == {}

    # the re-execution's outcome is the one that lands
    assert store.finish_work(item, "measured", owner="worker-B") is True
    assert store.fetch_work_results([item]) == {item: ("measured", None)}
    # ...exactly once: B can't double-finish either
    assert store.finish_work(item, "failed", owner="worker-B") is False


def test_batched_finish_skips_stale_items_but_lands_live_ones():
    """finish_work_batch applies the owner guard per item: one stale item in
    a batch must not poison (or land alongside) the live outcomes."""
    store, clock = fake_store()
    items = [store.enqueue_work("space", f"d{i}") for i in range(3)]
    claims = store.claim_work_batch("worker-A", limit=3, lease_s=LEASE_S)
    assert [c["item_id"] for c in claims] == items
    # item 1 goes stale: re-queued and re-claimed by worker-B
    store._write("UPDATE work_items SET lease_expires_at=0 WHERE item_id=?",
                 (items[1],))
    assert store.requeue_stale_work() == 1
    store.claim_work("worker-B", lease_s=LEASE_S)
    landed = store.finish_work_batch(
        [(i, "measured", None) for i in items], owner="worker-A")
    assert landed == 2
    assert set(store.fetch_work_results(items)) == {items[0], items[2]}


# ------------------------------------------------------ steal + pacer wiring


def test_steal_claim_fires_on_expired_lease_and_winner_refreshes():
    store, clock = fake_store()
    store.claim_experiment("dig", "exp", "dead", lease_s=LEASE_S)
    # lease still live: nobody can steal, however impatient
    assert not store.steal_claim("dig", "exp", "thief-1", older_than_s=0.001)
    clock.advance(LEASE_S + 0.5)
    # expired: exactly one of the racing thieves wins, the winner's refresh
    # falsifies the WHERE clause for the rest
    wins = [store.steal_claim("dig", "exp", f"thief-{i}", older_than_s=60.0)
            for i in range(4)]
    assert wins.count(True) == 1
    assert store.claim_exists("dig", "exp")


def test_live_heartbeating_owner_cannot_be_robbed_by_claim_age():
    """Measure-once regression: a claim much older than the waiter's
    claim-timeout but with a freshly renewed lease must be steal-proof —
    the exact long-cloud-measurement case the leases exist for."""
    store, clock = fake_store()
    store.claim_experiment("dig", "exp", "long-runner:1", lease_s=LEASE_S)
    for _ in range(60):  # a 60 s measurement against a 5 s lease...
        clock.advance(1.0)
        store.renew_lease("long-runner", LEASE_S)
    # ...and a waiter whose claim_timeout (10 s) has long since elapsed
    assert not store.steal_claim("dig", "exp", "impatient", older_than_s=10.0)
    assert store.sweep_stale_claims() == 0


def test_owner_wildcards_do_not_leak_across_owners():
    """LIKE-injection regression: `_` / `%` in a (user-settable) owner name
    must not renew or release another owner's claims."""
    store, clock = fake_store()
    store.claim_experiment("d1", "e", "gpu_node_1:123", lease_s=LEASE_S)
    store.claim_experiment("d2", "e", "gpu-node-1:456", lease_s=LEASE_S)
    store.claim_experiment("d3", "e", "gpu%node%1:789", lease_s=LEASE_S)
    # renew as gpu_node_1: only its own claim is extended
    assert store.renew_lease("gpu_node_1", LEASE_S) == 1
    # release as gpu_node_1: the dash/percent owners' claims survive
    assert store.release_claims_owned_by("gpu_node_1") == 1
    assert not store.claim_exists("d1", "e")
    assert store.claim_exists("d2", "e") and store.claim_exists("d3", "e")
    assert store.release_claims_owned_by("gpu%node%1") == 1
    assert store.claim_exists("d2", "e") and not store.claim_exists("d3", "e")


def test_lease_pacer_thread_renews_until_stopped(tmp_path):
    """The real pacer thread (wall clock, fast interval): leases visibly
    extend while it runs and stop extending after stop()."""
    store = SampleStore(str(tmp_path / "s.db"))
    store.claim_experiment("dig", "exp", "owner-A:7", lease_s=0.5)
    with LeasePacer(store, "owner-A", lease_s=30.0, interval_s=0.01):
        import time as _t
        t0 = _t.monotonic()
        while _t.monotonic() - t0 < 5.0:
            rows = store._rows("SELECT lease_expires_at FROM value_claims")
            if rows and rows[0][0] > store.clock.time() + 10.0:
                break
            _t.sleep(0.01)
        else:
            pytest.fail("pacer never extended the lease")
    store.close()


def test_hung_measurement_thread_stops_being_renewed():
    """Watchdog: an owner whose process is alive (pacer beating) but whose
    measurement is stuck past the claim timeout stops renewing that item's
    leases, so the normal reaping path recovers the work — the pre-lease
    recovery guarantee."""
    store, clock = fake_store()
    pacer = LeasePacer(store, "stuck", LEASE_S, max_age_s=30.0)
    store.claim_experiment("dig", "exp", "stuck:1", lease_s=LEASE_S)
    item = store.enqueue_work("space", "dig")
    store.claim_work("stuck", lease_s=LEASE_S)
    for _ in range(29):  # within the age bound: fully alive
        clock.advance(1.0)
        assert pacer.beat() == 2
    assert store.sweep_stale_claims() == 0 and store.requeue_stale_work() == 0
    # past the bound the beats stop covering the stuck rows...
    clock.advance(2.0)
    for _ in range(3):
        clock.advance(1.0)
        assert pacer.beat() == 0
    # ...and once the last renewed lease runs out, everything is recovered
    clock.advance(LEASE_S)
    assert store.sweep_stale_claims() == 1
    assert store.requeue_stale_work() == 1
    assert store.claim_work("survivor", lease_s=LEASE_S)["item_id"] == item


def test_pre_migration_database_reopens_cleanly(tmp_path):
    """A database laid out by the pre-lease build (no priority /
    lease_expires_at columns) must open, migrate, and serve the new API."""
    import sqlite3
    path = str(tmp_path / "old.db")
    conn = sqlite3.connect(path)
    conn.executescript("""
    CREATE TABLE value_claims (
        config_digest TEXT NOT NULL, experiment_id TEXT NOT NULL,
        owner TEXT NOT NULL, created_at REAL NOT NULL,
        PRIMARY KEY (config_digest, experiment_id));
    CREATE TABLE work_items (
        item_id TEXT PRIMARY KEY, space_id TEXT NOT NULL,
        config_digest TEXT NOT NULL, status TEXT NOT NULL DEFAULT 'queued',
        owner TEXT, action TEXT, error TEXT, created_at REAL NOT NULL,
        claimed_at REAL, finished_at REAL);
    INSERT INTO work_items(item_id, space_id, config_digest, created_at)
        VALUES ('old-item', 's', 'd', 1.0);
    """)
    conn.close()
    store = SampleStore(path)  # must not raise (index-before-migration bug)
    # the legacy row is claimable through the new best-first path
    claim = store.claim_work("w", space_id="s")
    assert claim is not None and claim["item_id"] == "old-item"
    assert store.enqueue_work("s", "d2", priority=4.0)
    store.close()


# --------------------------------------- worker loop under injected failures


def test_silently_dead_worker_item_recovered_by_surviving_fleet(tmp_path):
    """End-to-end over the real worker loop: a no-heartbeat worker claims an
    item and vanishes; after its lease expires the GC re-queues the item and
    a live worker finishes it."""
    path = str(tmp_path / "s.db")
    clock = FakeClock()
    store = SampleStore(path, clock=clock)
    ds = make_line_ds(lambda c: {"m": float(c["x"])}, store)
    ds.lease_s = LEASE_S
    config = Configuration.make({"x": 1})
    digest = store.put_configuration(config)
    item = store.enqueue_work(ds.space_id, digest)

    # the doomed worker claims (heartbeat disabled => silence) and "dies"
    assert store.claim_work("doomed", space_id=ds.space_id,
                            lease_s=LEASE_S) is not None
    clock.advance(LEASE_S + 1.0)
    assert store.requeue_stale_work() == 1
    assert store.sweep_stale_claims() >= 0  # no claims yet; must not throw

    # a live worker (real loop, manual heartbeats not needed: it finishes
    # fast) picks the item up and lands the outcome
    processed = run_worker(ds, owner="survivor", idle_timeout_s=0.0,
                           heartbeat=False)
    assert processed == 1
    assert store.fetch_work_results([item]) == {item: ("measured", None)}
    store.close()
