"""SLA-constrained objectives: the DSL, feasibility plumbing, constrained
acquisition, Pareto utilities, and the incumbent/reporting bugfix sweep.

Covers, in order:

* :class:`~repro.core.api.spec.ConstraintSpec` /
  :class:`~repro.core.api.spec.ObjectiveSpec` validation + JSON round-trip;
* :mod:`repro.core.pareto` (dominance, frontier, hypervolume);
* adapter-level feasibility verdicts (missing property => infeasible,
  failed => infeasible under constraints, scalarized trial values);
* the incumbent bugfixes (warm predictions and infeasible trials are never
  ``best``; ``normalized_cost`` charges own trials only);
* the infeasible-aware stopping rule;
* constrained acquisition for BO-GP (feasibility-weighted EI) and TPE
  (constraint-filtered split);
* the per-adapter unseen-candidate cache (enumeration-count regression);
* the dry-run roofline ``bytes_per_device`` omission fix;
* an end-to-end SLA-constrained :class:`~repro.core.api.Investigation`.
"""

from __future__ import annotations

import math
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, Investigation, MeasurementError,
                        ProbabilitySpace, SampleStore)
from repro.core.api.spec import ConstraintSpec, InvestigationSpec, ObjectiveSpec
from repro.core.optimizers import GPBayesOpt, RandomSearch, TPE, run_optimizer
from repro.core.optimizers.base import (FOREIGN_ACTION, WARM_ACTION, Optimizer,
                                        OptimizerRun, SearchAdapter, Trial,
                                        _StoppingRule)
from repro.core.pareto import dominates, hypervolume, pareto_front


def _config(**values) -> Configuration:
    return Configuration.make(values)


def _eval(adapter: SearchAdapter, config: Configuration) -> Trial:
    """Evaluate one configuration and return the resulting Trial."""
    adapter.evaluate(config)
    return adapter.trials[-1]


# -------------------------------------------------------------- the DSL


def test_constraint_spec_semantics():
    c = ConstraintSpec("p95_ms", "<=", 250)
    assert c.bound == 250.0
    assert c.satisfied(250.0) and c.satisfied(1.0)
    assert not c.satisfied(250.1)
    # missing or NaN must NEVER silently pass an SLA
    assert not c.satisfied(None)
    assert not c.satisfied(float("nan"))
    assert c.describe() == "p95_ms <= 250"
    assert ConstraintSpec("x", ">", 0).satisfied(0.1)
    assert not ConstraintSpec("x", ">", 0).satisfied(0.0)
    assert ConstraintSpec("x", ">=", 0).satisfied(0.0)
    assert ConstraintSpec("x", "<", 1).satisfied(0.999)


def test_constraint_spec_validation():
    with pytest.raises(ValueError, match="unknown op"):
        ConstraintSpec("p95_ms", "==", 1.0)
    with pytest.raises(ValueError, match="required"):
        ConstraintSpec("", "<=", 1.0)


def test_constraint_json_roundtrip_strict():
    c = ConstraintSpec("p95_ms", "<=", 250.0)
    assert ConstraintSpec.from_json(c.to_json()) == c
    with pytest.raises(ValueError, match="unknown"):
        ConstraintSpec.from_json({"property": "p", "op": "<=", "bound": 1,
                                  "slo": True})
    with pytest.raises(ValueError, match="required"):
        ConstraintSpec.from_json({"property": "p", "op": "<="})


def test_objective_spec_validation():
    with pytest.raises(ValueError, match="at most one"):
        ObjectiveSpec(weights=(("a", 1.0),), ratio=("a", "b"))
    with pytest.raises(ValueError, match="ratio"):
        ObjectiveSpec(ratio=("a",))
    with pytest.raises(ValueError, match="ConstraintSpec"):
        ObjectiveSpec(constraints=({"property": "p"},))
    assert not ObjectiveSpec().scalarized
    assert ObjectiveSpec(weights=(("a", 1.0),)).scalarized
    assert ObjectiveSpec(ratio=("a", "b")).scalarized


def test_objective_scalarization_values():
    w = ObjectiveSpec(weights=(("cost", 1.0), ("lat", 0.5)))
    assert w.label == "1*cost+0.5*lat"
    assert w.objective_properties() == ("cost", "lat")
    assert w.value({"cost": 2.0, "lat": 4.0}.__getitem__) == 4.0
    r = ObjectiveSpec(ratio=("dollars", "requests"))
    assert r.label == "dollars/requests"
    assert r.value({"dollars": 6.0, "requests": 3.0}.__getitem__) == 2.0
    # a zero denominator is the worst possible efficiency, not a crash
    assert r.value({"dollars": 6.0, "requests": 0.0}.__getitem__) \
        == float("inf")
    assert r.value({"dollars": -6.0, "requests": 0.0}.__getitem__) \
        == float("-inf")
    with pytest.raises(ValueError):
        ObjectiveSpec().value({"x": 1.0}.__getitem__)


def test_objective_feasibility_and_json_roundtrip():
    o = ObjectiveSpec(ratio=("cost", "qps"),
                      constraints=(ConstraintSpec("p95_ms", "<=", 250.0),
                                   ConstraintSpec("qps", ">=", 100.0)))
    assert o.constraint_properties() == ("p95_ms", "qps")
    get = {"p95_ms": 200.0, "qps": 150.0}.get
    assert o.feasible(get)
    assert not o.feasible({"p95_ms": 300.0, "qps": 150.0}.get)
    assert not o.feasible({"qps": 150.0}.get)  # missing => infeasible
    assert ObjectiveSpec.from_json(o.to_json()) == o
    with pytest.raises(ValueError, match="unknown"):
        ObjectiveSpec.from_json({"target": "x"})


def test_spec_metric_xor_scalarized_objective():
    space = ProbabilitySpace.make([Dimension.discrete("x", [1, 2])])
    constrained = ObjectiveSpec(
        constraints=(ConstraintSpec("lat", "<=", 1.0),))
    spec = InvestigationSpec(name="s", space=space, metric="cost",
                             objective=constrained)
    assert spec.objective_label() == "cost"
    assert InvestigationSpec.from_json(spec.to_json()) == spec
    scalarized = ObjectiveSpec(ratio=("cost", "qps"))
    spec2 = InvestigationSpec(name="s", space=space, objective=scalarized)
    assert spec2.objective_label() == "cost/qps"
    assert InvestigationSpec.from_json(spec2.to_json()) == spec2
    with pytest.raises(ValueError, match="not both"):
        InvestigationSpec(name="s", space=space, metric="cost",
                          objective=scalarized)
    with pytest.raises(ValueError, match="metric"):
        InvestigationSpec(name="s", space=space)


# --------------------------------------------------------------- pareto


def test_dominates_and_front():
    assert dominates((1.0, 1.0), (2.0, 2.0))
    assert not dominates((1.0, 3.0), (2.0, 2.0))
    assert not dominates((1.0, 1.0), (1.0, 1.0))
    pts = [(1.0, 4.0), (2.0, 2.0), (3.0, 3.0), (4.0, 1.0), (2.0, 2.0)]
    # duplicates of a non-dominated point are both kept, input order
    assert pareto_front(pts) == [0, 1, 3, 4]
    assert pareto_front(pts, modes=("max", "max")) == [0, 2, 3]
    assert pareto_front([], None) == []


def test_hypervolume_exact_and_monotone():
    ref = (4.0, 4.0)
    assert hypervolume([(2.0, 2.0)], ref) == pytest.approx(4.0)
    # two staircase points: 2x2 + 1x1 extra slab
    assert hypervolume([(2.0, 2.0), (1.0, 3.0)], ref) == pytest.approx(5.0)
    # dominated and out-of-reference points add nothing
    assert hypervolume([(2.0, 2.0), (3.0, 3.0)], ref) == pytest.approx(4.0)
    assert hypervolume([(2.0, 2.0), (5.0, 0.0)], ref) == pytest.approx(4.0)
    assert hypervolume([], ref) == 0.0
    # max mode mirrors min mode
    assert hypervolume([(2.0, 2.0)], (0.0, 0.0), modes=("max", "max")) \
        == pytest.approx(4.0)


# ----------------------------------------------- adapter feasibility


def sla_ds(store=None):
    """cost rises with x while latency falls: the cheapest configurations
    violate any latency bound — the canonical SLA trade-off."""
    space = ProbabilitySpace.make([
        Dimension.discrete("x", list(range(8))),
        Dimension.categorical("tier", ["a", "b"]),
    ])

    def fn(c):
        bump = 0.25 if c["tier"] == "b" else 0.0
        return {"cost": 1.0 + c["x"] + bump, "lat": 10.0 - 2.0 * c["x"]}

    exp = FunctionExperiment(fn=fn, properties=("cost", "lat"), name="sla")
    return DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                          store=store or SampleStore(":memory:"))


SLA = ObjectiveSpec(constraints=(ConstraintSpec("lat", "<=", 6.0),))


def test_adapter_attaches_feasibility_verdicts():
    ds = sla_ds()
    adapter = SearchAdapter(ds, "cost", "min", objective=SLA)
    t_bad = _eval(adapter, _config(x=0, tier="a"))   # lat 10 > 6
    t_ok = _eval(adapter, _config(x=3, tier="a"))    # lat 4 <= 6
    assert t_bad.feasible is False and t_bad.value == 1.0
    assert t_ok.feasible is True and t_ok.value == 4.0
    # unconstrained adapters leave the verdict unknown
    plain = SearchAdapter(sla_ds(), "cost", "min")
    assert _eval(plain, _config(x=0, tier="a")).feasible is None


def test_adapter_scalarized_objective_value():
    ds = sla_ds()
    obj = ObjectiveSpec(weights=(("cost", 1.0), ("lat", 0.1)))
    adapter = SearchAdapter(ds, "", "min", objective=obj)
    t = _eval(adapter, _config(x=2, tier="a"))
    assert t.value == pytest.approx(3.0 + 0.6)
    ratio = SearchAdapter(sla_ds(), "", "min",
                          objective=ObjectiveSpec(ratio=("cost", "lat")))
    t2 = _eval(ratio, _config(x=2, tier="a"))
    assert t2.value == pytest.approx(3.0 / 6.0)


def test_adapter_missing_objective_property_raises():
    ds = sla_ds()
    obj = ObjectiveSpec(weights=(("cost", 1.0), ("watts", 1.0)))
    adapter = SearchAdapter(ds, "", "min", objective=obj)
    with pytest.raises(KeyError, match="watts"):
        adapter.evaluate(_config(x=2, tier="a"))


def test_missing_constraint_property_is_infeasible():
    """A constraint over a property the action space never measures can
    never be satisfied — no sentinel value sneaks an SLA pass through."""
    ds = sla_ds()
    obj = ObjectiveSpec(constraints=(ConstraintSpec("p99_ms", "<=", 1e9),))
    adapter = SearchAdapter(ds, "cost", "min", objective=obj)
    assert _eval(adapter, _config(x=3, tier="a")).feasible is False


def test_failed_trial_infeasible_only_under_constraints():
    def fn(c):
        if c["x"] >= 6:
            raise MeasurementError("OOM")
        return {"cost": float(c["x"]), "lat": 10.0 - c["x"]}

    def make(objective):
        space = ProbabilitySpace.make([Dimension.discrete("x", range(8))])
        exp = FunctionExperiment(fn=fn, properties=("cost", "lat"),
                                 name="cliff")
        ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                            store=SampleStore(":memory:"))
        return SearchAdapter(ds, "cost", "min", objective=objective)

    failed = _eval(make(SLA), _config(x=7))
    assert failed.value is None and failed.action == "failed"
    assert failed.feasible is False
    assert _eval(make(None), _config(x=7)).feasible is None


# ------------------------------------- incumbent/reporting bugfixes


def test_best_excludes_warm_predictions():
    """Reproduces the incumbent bug: a warm-folded surrogate *prediction*
    with the lowest value must never be reported as the best found."""
    ds = sla_ds()
    adapter = SearchAdapter(ds, "cost", "min")
    adapter.evaluate(_config(x=3, tier="a"))          # measured, cost 4.0
    adapter.warm_start([(_config(x=0, tier="a"), 0.01)])  # prediction!
    run = OptimizerRun(optimizer="o", metric="cost", mode="min",
                       trials=list(adapter.trials))
    assert run.best.value == 4.0
    assert run.best.action == "measured"
    # the by-step incumbent curve skips the warm step too
    curve = run.best_value_by_step()
    assert curve == [4.0, 4.0]
    # warm-only history: no incumbent at all
    warm_only = OptimizerRun(optimizer="o", metric="cost", mode="min",
                             trials=[t for t in adapter.trials
                                     if t.action == WARM_ACTION])
    assert warm_only.best is None
    assert warm_only.best_value_by_step() == [None]


def test_best_excludes_infeasible_trials():
    c = _config(x=1)
    run = OptimizerRun(optimizer="o", metric="cost", mode="min", trials=[
        Trial(c, 1.0, "measured", 0, feasible=False),
        Trial(c, 5.0, "measured", 1, feasible=True),
        Trial(c, 3.0, "measured", 2),  # unknown verdict stays eligible
    ])
    assert run.best.value == 3.0
    assert run.num_infeasible == 1
    assert run.best_value_by_step() == [None, 5.0, 3.0]
    all_bad = OptimizerRun(optimizer="o", metric="cost", mode="min", trials=[
        Trial(c, 1.0, "measured", 0, feasible=False)])
    assert all_bad.best is None


def test_normalized_cost_counts_own_trials_only():
    """Reproduces the reporting bug: foreign- and warm-folded history used
    to inflate the denominator, understating the member's own cost."""
    c = _config(x=1)
    run = OptimizerRun(optimizer="o", metric="m", mode="min", trials=[
        Trial(c, 1.0, "measured", 0),
        Trial(c, 2.0, "measured", 1),
        Trial(c, 3.0, "reused", 2),
        Trial(c, 4.0, FOREIGN_ACTION, 3),
        Trial(c, 5.0, FOREIGN_ACTION, 4),
        Trial(c, 6.0, WARM_ACTION, 5),
    ])
    # 2 measured / 3 own told trials — NOT 2/6
    assert run.normalized_cost == pytest.approx(2.0 / 3.0)
    foreign_only = OptimizerRun(optimizer="o", metric="m", mode="min",
                                trials=[Trial(c, 1.0, FOREIGN_ACTION, 0)])
    assert foreign_only.normalized_cost == 0.0


def test_stopping_rule_infeasible_trials_stall():
    adapter = SimpleNamespace(trials=[1] * 10, signed=lambda v: v)
    rule = _StoppingRule(adapter, patience=3, min_trials=1)
    rule.observe(5.0, True)
    assert rule.best == 5.0 and rule.stall == 0
    # a streak of ever-cheaper SLA violators is STALLING, not improving
    for v in (4.0, 3.0, 2.0):
        rule.observe(v, False)
    assert rule.best == 5.0
    assert rule.stop
    # ...while a feasible improvement resets the streak
    rule2 = _StoppingRule(adapter, patience=3, min_trials=1)
    rule2.observe(5.0, True)
    rule2.observe(4.0, False)
    rule2.observe(3.0, True)
    assert rule2.best == 3.0 and rule2.stall == 0


# ------------------------------------------- constrained acquisition


def test_bo_gp_feasibility_weight_signal():
    ds = sla_ds()
    adapter = SearchAdapter(ds, "cost", "min", objective=SLA)
    for x in range(8):
        adapter.evaluate(_config(x=x, tier="a"))
    opt = GPBayesOpt(seed=0)
    cand = [_config(x=x, tier="b") for x in range(8)]
    Xc = np.stack([ds.space.encode(c) for c in cand])
    pof = opt._feasibility_weight(adapter, Xc)
    assert pof is not None and pof.shape == (8,)
    assert np.all((pof >= 0.0) & (pof <= 1.0))
    # feasibility rises with x in this surface; the classifier must agree
    assert pof[7] > pof[0]
    # all-feasible history carries no signal: weighting is skipped entirely
    feas_only = SearchAdapter(sla_ds(), "cost", "min", objective=SLA)
    for x in (3, 4, 5):
        feas_only.evaluate(_config(x=x, tier="a"))
    assert opt._feasibility_weight(feas_only, Xc) is None


def test_bo_gp_all_infeasible_history_explores_randomly():
    """An all-infeasible history is a one-class label set: the standardized
    classifier fit degenerates (PoF = 0 everywhere), and ranking on that
    flat surface would crawl the candidate pool in enumeration order.  The
    weight must be None so the ask falls back to random exploration."""
    ds = sla_ds()
    adapter = SearchAdapter(ds, "cost", "min", objective=SLA)
    for x in (0, 1):  # lat 10, 8 > bound 6 — every observation infeasible
        adapter.evaluate(_config(x=x, tier="a"))
    assert all(t.feasible is False for t in adapter.trials)
    opt = GPBayesOpt(seed=0, n_initial=1)
    cand = [_config(x=x, tier="b") for x in range(8)]
    Xc = np.stack([ds.space.encode(c) for c in cand])
    assert opt._feasibility_weight(adapter, Xc) is None
    # and the full ask explores: different rng streams pick different
    # configurations instead of deterministically walking enumeration order
    picks = {opt.ask(adapter, np.random.default_rng(s), 1)[0]
             .configuration.digest for s in range(8)}
    assert len(picks) > 1


@pytest.mark.parametrize("opt_cls", [GPBayesOpt, TPE])
def test_constrained_search_lands_feasible(opt_cls):
    """On a surface where cheap == SLA-violating, the constrained search
    must report a feasible incumbent at the cheapest feasible cost, while
    the unconstrained run happily reports a violator."""
    def run(objective):
        ds = sla_ds()
        inv = Investigation.from_components(
            ds, [opt_cls(seed=0)], "cost", mode="min", max_trials=16,
            patience=17, backend="serial", objective=objective)
        return inv.run()

    res = run(SLA)
    assert res.best is not None and res.best.feasible is True
    # cheapest feasible: x=2 (lat 6.0), tier a => cost 3.0
    assert res.best.value == pytest.approx(3.0)
    assert res.num_infeasible > 0
    assert res.summary()["infeasible"] == res.num_infeasible
    plain = run(None)
    assert plain.best.value < 3.0  # the violator the SLA exists to reject


def boundary_adapter(objective):
    """16-point 1-d surface, even x measured: cost rises with x, latency
    falls, ``lat <= 8`` means x >= 6 — the odd-x pool spans deep violators
    (x=1,3), the boundary (x=5), and the feasible shelf (x>=7)."""
    space = ProbabilitySpace.make([Dimension.discrete("x", list(range(16)))])

    def fn(c):
        return {"cost": 1.0 + c["x"], "lat": 20.0 - 2.0 * c["x"]}

    exp = FunctionExperiment(fn=fn, properties=("cost", "lat"), name="bnd")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                        store=SampleStore(":memory:"))
    adapter = SearchAdapter(ds, "cost", "min", objective=objective)
    for x in range(0, 16, 2):
        adapter.evaluate(_config(x=x))
    return adapter


BOUNDARY_SLA = ObjectiveSpec(constraints=(ConstraintSpec("lat", "<=", 8.0),))


def test_constrained_bo_gp_prefers_feasible_region():
    """Feasibility-weighted EI steers proposals to the constraint boundary;
    unweighted EI on the same history chases the deep violators."""
    opt = GPBayesOpt(seed=0)
    con = opt.ask(boundary_adapter(BOUNDARY_SLA),
                  np.random.default_rng(0), n=4)
    unc = GPBayesOpt(seed=0).ask(boundary_adapter(None),
                                 np.random.default_rng(0), n=4)
    # cost-only EI proposes the cheapest unseen point — an SLA violator
    assert unc[0].configuration["x"] == 1
    # P(feasible) weighting moves the top proposal to the boundary/feasible
    # region and zeroes the deep violators' scores
    assert con[0].configuration["x"] >= 5
    assert con[0].score > 0.0
    deep = [c.score for c in con if c.configuration["x"] <= 3]
    assert all(s == 0.0 for s in deep)


def test_tpe_constrained_split_uses_feasible_good():
    con = TPE(seed=0).ask(boundary_adapter(BOUNDARY_SLA),
                          np.random.default_rng(0), n=1)
    unc = TPE(seed=0).ask(boundary_adapter(None),
                          np.random.default_rng(0), n=1)
    assert unc[0].configuration["x"] == 1   # the violator again
    assert con[0].configuration["x"] >= 6   # inside the feasible shelf


def test_unconstrained_rng_stream_untouched():
    """The constrained machinery must not change unconstrained draws: same
    seed, same history => same proposals as before the feature existed."""
    def proposals(objective):
        ds = sla_ds()
        adapter = SearchAdapter(ds, "cost", "min", objective=objective)
        for x in (0, 3, 5):
            adapter.evaluate(_config(x=x, tier="a"))
        rng = np.random.default_rng(42)
        return [c.configuration.digest
                for c in GPBayesOpt(seed=0).ask(adapter, rng, n=3)]

    # None and a constraint-free objective are both the unconstrained path
    assert proposals(None) == proposals(ObjectiveSpec())


# ------------------------------------------------- unseen-pool cache


def test_unseen_pool_matches_fresh_enumeration():
    ds = sla_ds()
    adapter = SearchAdapter(ds, "cost", "min")
    for x in (0, 2, 4):
        adapter.evaluate(_config(x=x, tier="a"))
    pool = adapter.unseen_pool()
    fresh = [c for c in ds.space.all_configurations()
             if c.digest not in {t.configuration.digest
                                 for t in adapter.trials}]
    # same configurations, same enumeration order
    assert list(pool.values()) == fresh
    # tell() evicts in place
    nxt = fresh[0]
    adapter.evaluate(nxt)
    assert nxt.digest not in adapter.unseen_pool()
    # pending digests are filtered per-ask but stay in the cache
    adapter.pending.add(fresh[1].digest)
    got = Optimizer._unseen_candidates(adapter, np.random.default_rng(0),
                                       max_candidates=512)
    assert fresh[1] not in got
    assert fresh[1].digest in adapter.unseen_pool()


def test_ask_enumerates_space_once_per_adapter(monkeypatch):
    """The O(|Ω|)-per-ask regression gate: a full run's ask loop walks the
    finite space ONCE (the cache build), not once per trial."""
    calls = {"n": 0}
    orig = ProbabilitySpace.all_configurations

    def counting(self, *a, **k):
        calls["n"] += 1
        return orig(self, *a, **k)

    monkeypatch.setattr(ProbabilitySpace, "all_configurations", counting)
    ds = sla_ds()
    baseline = calls["n"]  # space registration etc.
    run = run_optimizer(RandomSearch(seed=0), ds, "cost", "min",
                        max_trials=12, patience=13,
                        rng=np.random.default_rng(0))
    assert run.num_trials == 12
    assert calls["n"] - baseline <= 1


# ------------------------------------------- dry-run report properties


def test_dryrun_report_omits_unknown_byte_count():
    from repro.tuning.experiments import DryrunRooflineExperiment

    report = SimpleNamespace(compute_s=1.0, memory_s=2.0, collective_s=0.5,
                             step_time_s=3.5, roofline_fraction=0.9,
                             hlo_flops=1e12, bytes_per_device=None)
    out = DryrunRooflineExperiment._report_properties(report, 7.0)
    # no zero sentinel: a memory SLA must not silently pass
    assert "bytes_per_device" not in out
    assert out["compile_s"] == 7.0
    report.bytes_per_device = 2.5e9
    out2 = DryrunRooflineExperiment._report_properties(report, 7.0)
    assert out2["bytes_per_device"] == 2.5e9
    # and the constraint layer treats the omission as an SLA failure
    hbm = ConstraintSpec("bytes_per_device", "<=", 16e9)
    assert not hbm.satisfied(out.get("bytes_per_device"))
    assert hbm.satisfied(out2["bytes_per_device"])


# ------------------------------------------------------- end to end


def test_investigation_sla_end_to_end():
    store = SampleStore(":memory:")
    ds = sla_ds(store)
    inv = Investigation.from_components(
        ds, [TPE(seed=1)], "cost", mode="min", max_trials=14, patience=15,
        backend="serial", objective=SLA)
    plan = inv.plan()
    assert "s.t. lat <= 6" in plan.describe()
    res = inv.run()
    assert res.best is not None and res.best.feasible is True
    summary = res.summary()
    assert summary["infeasible"] == res.num_infeasible
    assert summary["best"]["value"] >= 3.0  # never a violator's cost
    # the store's frontier view over (cost, lat) is non-empty, mutually
    # non-dominating, and contains the reported best
    front = inv.frontier(["cost", "lat"])
    assert front
    pts = [v for _, v in front]
    assert pareto_front(pts) == list(range(len(pts)))
    assert any(v[0] == pytest.approx(res.best.value) for v in pts)


def test_measurements_to_best_skips_infeasible_match():
    """An infeasible trial sharing the best's value must not shortcut the
    measurements-to-best count."""
    ds = sla_ds()
    inv = Investigation.from_components(
        ds, [TPE(seed=3)], "cost", mode="min", max_trials=12, patience=13,
        backend="serial", objective=SLA)
    res = inv.run()
    n = res.measurements_to_best()
    paid = 0
    for _, t in res.events:
        if t.action in ("measured", "failed"):
            paid += 1
        if t.feasible is not False and t.value is not None \
                and t.value == res.best.value:
            break
    assert n == paid
