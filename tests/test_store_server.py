"""Served-store process tests: crash recovery, reconnects, spec e2e.

The conformance suite (``test_store_backends.py``) pins the contract with an
in-process server; these tests run ``python -m repro.core.store.server`` as
a real subprocess and exercise what only a separate process can show:

* **crash mid-claim** — SIGKILL the server while a worker holds a work-item
  claim and a measurement claim; restart it on the same URL; the client
  reconnects transparently and the *existing lease machinery* recovers both
  (the server holds no volatile coordination state — everything lives in
  the database).
* **zombie fencing across the crash** — the pre-crash owner's finish is
  rejected by the owner guard after its item was re-queued and re-claimed.
* **spec-driven e2e** — ``InvestigationSpec.store = <url>`` runs a whole
  investigation through the served store, draw-for-draw identical to the
  same spec on the in-process reference backend.
"""

from __future__ import annotations

import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import (Configuration, Dimension, Investigation,
                        InvestigationSpec, ProbabilitySpace, SampleStore)
from repro.core.api.spec import BudgetSpec, ExperimentSpec, OptimizerSpec
from repro.core.store.client import ClientStore

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
SPACE = "served-space"


def start_server(db: str, sock: str) -> tuple:
    """Launch a store-server subprocess; returns (proc, url) once it's up."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.core.store.server",
         "--db", db, "--unix", sock],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
    line = proc.stdout.readline()  # blocks until the server binds
    assert line.startswith("STORE_URL="), f"unexpected server output: {line!r}"
    return proc, line.strip().split("=", 1)[1]


def stop(proc) -> None:
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait(timeout=10)
    proc.stdout.close()


def test_server_crash_mid_claim_lease_recovery(tmp_path):
    db, sock = str(tmp_path / "crash.db"), str(tmp_path / "crash.sock")
    proc, url = start_server(db, sock)
    client = ClientStore(url, retries=8)
    try:
        digest = client.put_configuration(
            Configuration(values=(("size", 1),)))
        item = client.enqueue_work(SPACE, digest, priority=1.0)
        lease_s = 1.0
        claim = client.claim_work("doomed", space_id=SPACE, lease_s=lease_s)
        assert claim["item_id"] == item
        assert client.claim_experiment(digest, "exp-a", owner="doomed",
                                       lease_s=lease_s)
        claimed_at = time.time()

        proc.kill()  # SIGKILL: no shutdown path runs
        proc.wait(timeout=10)

        # same db, same socket path -> same URL; the durable state (queue,
        # claims, leases) is all in the database
        proc, url2 = start_server(db, sock)
        assert url2 == url

        # the doomed worker's heartbeats died with the old connection; wait
        # out its lease, then the standard sweeps recover everything
        time.sleep(max(0.0, claimed_at + lease_s + 0.3 - time.time()))
        assert client.requeue_stale_work() == 1  # transparent reconnect too
        assert client.sweep_stale_claims() >= 1
        assert not client.claim_exists(digest, "exp-a")

        survivor = client.claim_work("survivor", space_id=SPACE, lease_s=30.0)
        assert survivor["item_id"] == item
        assert survivor["priority"] == 1.0
        # the pre-crash owner coming back cannot overwrite the re-execution
        assert client.finish_work_batch([(item, "measured", None)],
                                        owner="doomed") == 0
        assert client.finish_work(item, "measured", owner="survivor")
        assert client.fetch_work_results([item]) == {
            item: ("measured", None)}
    finally:
        client.close()
        stop(proc)


def test_client_survives_clean_server_restart(tmp_path):
    db, sock = str(tmp_path / "re.db"), str(tmp_path / "re.sock")
    proc, url = start_server(db, sock)
    client = ClientStore(url, retries=8)
    try:
        digest = client.put_configuration(
            Configuration(values=(("size", 2),)))
        stop(proc)
        proc, _ = start_server(db, sock)
        # the dead socket is detected and redialed inside one call
        client.invalidate_config_cache()
        assert client.get_configuration(digest) is not None
        assert client.count_measured() == 0
    finally:
        client.close()
        stop(proc)


def test_dead_server_raises_connection_error(tmp_path):
    db, sock = str(tmp_path / "dead.db"), str(tmp_path / "dead.sock")
    proc, url = start_server(db, sock)
    client = ClientStore(url, retries=2)
    stop(proc)
    with pytest.raises(ConnectionError):
        client.count_measured()
    client.close()


def _quad_spec(store_url, seed=5):
    vals = [round(v, 3) for v in np.linspace(-2, 2, 6)]
    space = ProbabilitySpace.make([Dimension.discrete("x", vals),
                                   Dimension.discrete("y", vals)])
    return InvestigationSpec(
        name="served-e2e", space=space, metric="loss",
        experiments=(ExperimentSpec("quad"),),
        optimizers=(OptimizerSpec("tpe", seed=seed),),
        budget=BudgetSpec(max_trials=8, patience=8),
        store=store_url)


def trail(result):
    return [(t.configuration.digest, t.value, t.action)
            for t in result.members[0].run.trials]


def test_investigation_runs_draw_for_draw_over_served_store(tmp_path):
    """The acceptance gate in miniature: the same spec produces the
    byte-identical trajectory whether the rendezvous is the in-process
    reference backend or the served one."""
    proc, url = start_server(str(tmp_path / "e2e.db"),
                             str(tmp_path / "e2e.sock"))
    try:
        served = Investigation(_quad_spec(url)).run()
        reference = Investigation(_quad_spec(None)).run()
        assert trail(served) == trail(reference)
        assert served.summary()["paid_measurements"] \
            == reference.summary()["paid_measurements"]
    finally:
        stop(proc)
    # the measurements are durable in the server's database
    store = SampleStore(str(tmp_path / "e2e.db"))
    assert store.count_measured() == 8
    store.close()
