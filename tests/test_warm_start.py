"""Tests for cross-space transfer warm-starting (SearchAdapter.warm_start +
the Investigation transfer stage).

Contracts:

* **warm_start** — rng-free, deterministic-order folding into the
  model-visible history; warm digests stay proposable (a prediction never
  vetoes a real measurement); budgets/stopping rules never charge for warm
  trials;
* **determinism** — per optimizer family, two identical warm-started
  investigations over identical stores produce identical own trajectories;
* **end-to-end** — the transfer stage discovers the related space, applies
  the criteria, warm-starts, and beats the cold search on paid
  measurements; failed criteria fall back to a cold search (reported).
"""

import numpy as np
import pytest

from repro.core import (ActionSpace, DiscoverySpace, Dimension,
                        FunctionExperiment, Investigation, InvestigationSpec,
                        ProbabilitySpace, SampleStore)
from repro.core.api.spec import (BudgetSpec, ExperimentSpec, OptimizerSpec,
                                 TransferSpec)
from repro.core.optimizers import OPTIMIZER_REGISTRY
from repro.core.optimizers.base import WARM_ACTION, SearchAdapter


def quad_space(n=8):
    vals = [round(v, 3) for v in np.linspace(-2, 2, n)]
    return ProbabilitySpace.make([
        Dimension.discrete("x", vals),
        Dimension.discrete("y", vals),
    ])


def make_ds(store=None):
    exp = FunctionExperiment(
        fn=lambda c: {"loss": (c["x"] - 0.5) ** 2 + (c["y"] + 0.5) ** 2},
        properties=("loss",), name="quad")
    return DiscoverySpace(space=quad_space(),
                          actions=ActionSpace.make([exp]),
                          store=store or SampleStore(":memory:"))


def trail(trials):
    return [(t.configuration.digest, t.value, t.action) for t in trials]


def seed_source(store):
    """Exhaustively measure a quad source space into the store; returns the
    source investigation spec's space_id."""
    src = make_ds(store)
    src.sample_batch(list(src.remaining_configurations()),
                     operation_id="historical")
    return src.space_id


def target_spec(optimizer="tpe", seed=0, enabled=True, max_trials=8,
                **transfer_kw):
    return InvestigationSpec(
        name="warm-target", space=quad_space(), metric="loss",
        experiments=(ExperimentSpec(
            "linear-shift", {"base": "quad", "scale": 1.3, "offset": 5.0,
                             "noise": 0.02}),),
        optimizers=(OptimizerSpec(optimizer, seed=seed),),
        budget=BudgetSpec(max_trials=max_trials, patience=99),
        transfer=TransferSpec(enabled=enabled, **transfer_kw))


# ------------------------------------------------------- adapter.warm_start


def test_warm_start_folds_without_marking_seen_or_touching_rng():
    ds = make_ds()
    adapter = SearchAdapter(ds, "loss", "min")
    configs = list(ds.space.all_configurations())[:3]
    rng = np.random.default_rng(0)
    state_before = rng.bit_generator.state
    folded = adapter.warm_start([(c, float(i)) for i, c in enumerate(configs)])
    assert rng.bit_generator.state == state_before  # rng-free by construction
    assert folded == adapter.warm_told == 3
    assert [t.action for t in adapter.trials] == [WARM_ACTION] * 3
    assert [t.value for t in adapter.trials] == [0.0, 1.0, 2.0]
    # warm digests are NOT seen: predictions never veto a real measurement
    assert adapter.seen_digests() == set()


def test_warm_trials_never_charge_budgets_or_stopping_rules():
    """A member warm-started with a big history must still spend its full
    own-trial budget: warm trials are model food, not paid work."""
    store = SampleStore(":memory:")
    seed_source(store)
    res = Investigation(target_spec(max_trials=5), store=store).run()
    m = res.members[0]
    assert m.warm_trials >= 60              # the whole source folded
    assert m.run.num_trials == 5            # budget counted own trials only
    assert m.history_size >= m.warm_trials + m.run.num_trials


def test_warm_digest_can_be_proposed_and_measured_for_real():
    """The optimizer may re-propose a warm-predicted configuration; the
    measurement lands normally and the history then holds both the
    prediction and the measured correction."""
    store = SampleStore(":memory:")
    seed_source(store)
    res = Investigation(target_spec(max_trials=6), store=store).run()
    m = res.members[0]
    # the whole space is warm-covered, so every own trial re-measures (or
    # reuses) a warm digest — proposals were not vetoed by the predictions
    assert m.run.num_trials == 6
    assert all(t.action in ("measured", "reused") for t in m.run.trials)
    warm_digests = {t.configuration.digest for t in m.run.trials} & {
        d for d in res.transfer.warm_predictions}
    assert warm_digests or res.transfer.n_warm_trials == 64


@pytest.mark.parametrize("name", list(OPTIMIZER_REGISTRY))
def test_warm_started_trajectories_are_deterministic_per_family(name):
    """Two identical warm-started investigations over identically-seeded
    stores produce identical own trajectories — warm_start folds in a
    deterministic order and consumes no randomness."""
    def run_once():
        store = SampleStore(":memory:")
        seed_source(store)
        spec = target_spec(optimizer=name, seed=4, max_trials=6)
        return Investigation(spec, store=store).run()

    a, b = run_once(), run_once()
    assert a.transfer.applied and b.transfer.applied
    assert trail(a.members[0].run.trials) == trail(b.members[0].run.trials)
    # the warm fold itself is identical too
    assert a.members[0].warm_trials == b.members[0].warm_trials


# ----------------------------------------------------- transfer stage (e2e)


def test_transfer_stage_discovers_assesses_and_warm_starts():
    store = SampleStore(":memory:")
    src_id = seed_source(store)
    res = Investigation(target_spec(), store=store).run()
    t = res.transfer
    assert t is not None and t.applied
    assert t.source_space_id == src_id
    assert t.assessment.transferable and abs(t.assessment.r) > 0.95
    assert t.n_rep_measured == t.n_representatives > 0
    assert t.n_warm_trials == t.n_source_samples == 64
    assert res.members[0].warm_trials == t.n_warm_trials
    # paid = search measurements + the representative pass
    assert res.paid_measurements >= t.paid + res.num_measured


def test_transfer_disabled_or_empty_catalog_searches_cold():
    store = SampleStore(":memory:")
    res = Investigation(target_spec(enabled=False), store=store).run()
    assert res.transfer is None
    res2 = Investigation(target_spec(), store=SampleStore(":memory:")).run()
    assert res2.transfer is not None and not res2.transfer.applied
    assert res2.members[0].warm_trials == 0


def test_failed_criteria_fall_back_to_cold_with_attempt_recorded():
    """An uncorrelated source (pure per-configuration noise) must fail the
    r/p criteria: no warm trials, the attempt is reported, and the search
    still runs to budget."""
    store = SampleStore(":memory:")
    rng = np.random.default_rng(0)
    noise = {}
    exp = FunctionExperiment(
        fn=lambda c: {"loss": noise.setdefault(c.digest,
                                               float(rng.normal()))},
        properties=("loss",), name="quad")  # same identity as the source exp?
    src = DiscoverySpace(space=quad_space(),
                         actions=ActionSpace.make([exp]),
                         store=store)
    src.sample_batch(list(src.remaining_configurations()),
                     operation_id="noise-study")
    res = Investigation(target_spec(max_trials=4), store=store).run()
    assert not res.transfer.applied
    assert res.transfer.attempts
    assert res.transfer.attempts[0]["outcome"] == "criteria not met"
    assert res.members[0].warm_trials == 0
    assert res.members[0].run.num_trials == 4
    assert res.prediction_quality() is None


def test_failed_attempt_rep_measurements_still_count_as_paid():
    """A candidate that pays a representative pass and THEN fails the
    criteria still deployed real experiments: its paid count must survive
    into the report even when a later candidate transfers."""
    store = SampleStore(":memory:")
    # decoy source: same dimensions, MORE measured data (ranked first),
    # pure noise => criteria must reject it after a paid rep pass
    rng = np.random.default_rng(0)
    noise = {}
    decoy_exp = FunctionExperiment(
        fn=lambda c: {"loss": noise.setdefault(c.digest,
                                               float(rng.normal()))},
        properties=("loss",), name="decoy")
    decoy = DiscoverySpace(space=quad_space(), store=store,
                           actions=ActionSpace.make([decoy_exp]))
    decoy.sample_batch(list(decoy.space.all_configurations()),
                       operation_id="decoy-study")
    # real source: fewer samples (ranked second), strongly transferable
    src = make_ds(store)
    src.sample_batch(list(src.space.all_configurations())[:40],
                     operation_id="historical")
    res = Investigation(target_spec(max_trials=3), store=store).run()
    t = res.transfer
    assert t.applied and t.source_space_id == src.space_id
    assert [a["outcome"] for a in t.attempts] == ["criteria not met",
                                                  "transfer"]
    # paid = BOTH rep passes, not just the winning candidate's
    assert t.paid == sum(a["rep_paid"] for a in t.attempts)
    assert t.paid > t.attempts[1]["rep_paid"] > 0


def test_failed_representatives_are_not_warm_folded():
    """A representative the rep pass just observed to FAIL in the target
    must not re-enter the members' histories as a plausible surrogate
    prediction — that would steer the search toward a known-bad point."""
    from repro.core import MeasurementError

    store = SampleStore(":memory:")
    seed_source(store)
    bad = {"x": -2.0, "y": 2.0}  # the source surface's unique maximum

    def cliffy(c):
        if c["x"] == bad["x"] and c["y"] == bad["y"]:
            raise MeasurementError("OOM")
        return {"loss": 1.3 * ((c["x"] - 0.5) ** 2 + (c["y"] + 0.5) ** 2)
                + 5.0}

    tgt = DiscoverySpace(
        space=quad_space(), store=store,
        actions=ActionSpace.make([FunctionExperiment(
            fn=cliffy, properties=("loss",), name="cliffy")]))
    spec = InvestigationSpec(
        name="cliff", space=quad_space(), metric="loss",
        optimizers=(OptimizerSpec("tpe", seed=0),),
        budget=BudgetSpec(max_trials=3, patience=99),
        # linspace picks both ranking extremes, so the failing maximum is
        # guaranteed into the representative sub-space
        transfer=TransferSpec(enabled=True, selection="linspace"))
    res = Investigation(spec, ds=tgt).run()
    t = res.transfer
    assert t.applied and t.n_rep_failed == 1
    from repro.core import Configuration
    bad_digest = Configuration.make(bad).digest
    assert bad_digest not in t.warm_predictions
    assert t.n_warm_trials == t.n_source_samples - 1


def test_transfer_respects_caps():
    store = SampleStore(":memory:")
    seed_source(store)
    res = Investigation(
        target_spec(max_representatives=4, max_warm=10),
        store=store).run()
    t = res.transfer
    assert t.applied
    assert t.n_representatives <= 4
    assert t.n_warm_trials <= 10
    assert res.members[0].warm_trials <= 10


def test_warm_beats_cold_on_paid_measurements_same_seeds():
    """The bench claim, 5-seed smoke (the ≥16-seed version is
    ``python -m benchmarks.transfer_bench``): warm search needs fewer total
    paid measurements to land the target's true optimum."""
    truth_exp = ExperimentSpec(
        "linear-shift", {"base": "quad", "scale": 1.3, "offset": 5.0,
                         "noise": 0.02}).build()
    space = quad_space()
    truth = [truth_exp.measure(c)["loss"] for c in space.all_configurations()]
    threshold = float(min(truth)) + 1e-9

    def paid_to_target(res):
        paid = res.transfer.paid if res.transfer is not None else 0
        for _, t in res.events:
            if t.action in ("measured", "failed"):
                paid += 1
            if t.value is not None and t.value <= threshold:
                return paid
        return 999

    warm_paid = cold_paid = 0
    quality_seen = False
    for seed in range(5):
        warm_store = SampleStore(":memory:")
        seed_source(warm_store)
        warm = Investigation(
            target_spec(seed=seed, max_trials=40, max_representatives=4),
            store=warm_store).run()
        cold = Investigation(
            target_spec(seed=seed, enabled=False, max_trials=40),
            store=SampleStore(":memory:")).run()
        warm_paid += paid_to_target(warm)
        cold_paid += paid_to_target(cold)
        q = warm.prediction_quality()
        if q is not None:  # needs >=2 verified predictions
            quality_seen = True
            assert 0.0 <= q.top5_pct <= 1.0
    assert warm_paid < cold_paid
    assert quality_seen
