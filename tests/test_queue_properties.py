"""Property-based tests for the priority work-queue invariants.

Runs under real ``hypothesis`` when installed, else the API-compatible stub
(:mod:`repro._compat.hypothesis_stub`) registered by ``conftest.py`` — the
invariants are exercised either way:

* **ordering** — pops come out in non-increasing priority order, FIFO
  (insertion order) within equal priorities, regardless of batch sizes;
* **conservation** — under randomly interleaved claim / requeue / finish
  operations from multiple simulated workers, no work item is ever lost
  (everything eventually finishes) and none is ever double-finished;
* **partitioning** — racing claimers never receive the same item twice.
"""

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FakeClock, SampleStore

LEASE_S = 5.0


def fresh_store():
    clock = FakeClock()
    return SampleStore(":memory:", clock=clock), clock


# ------------------------------------------------------------------ ordering


@given(priorities=st.lists(st.sampled_from([0.0, 1.0, 2.5, 2.5, -3.0, 10.0]),
                           min_size=1, max_size=12),
       batch=st.integers(min_value=1, max_value=5))
@settings(max_examples=40, deadline=None)
def test_pops_are_best_first_fifo_within_ties(priorities, batch):
    store, _ = fresh_store()
    ids = [store.enqueue_work("s", f"d{i}", priority=p)
           for i, p in enumerate(priorities)]
    enqueue_pos = {item_id: i for i, item_id in enumerate(ids)}

    popped = []
    while True:
        claims = store.claim_work_batch("w", limit=batch, space_id="s",
                                        lease_s=LEASE_S)
        if not claims:
            break
        popped.extend(claims)
    assert len(popped) == len(ids)

    keys = [(-c["priority"], enqueue_pos[c["item_id"]]) for c in popped]
    assert keys == sorted(keys), (
        "pops must be non-increasing in priority, FIFO within ties")
    store.close()


@given(n=st.integers(min_value=1, max_value=10))
@settings(max_examples=15, deadline=None)
def test_equal_priorities_degrade_to_pure_fifo(n):
    """All-equal priorities (including the unscored 0.0 default) reproduce
    the PR-2 FIFO queue exactly — even with identical enqueue timestamps,
    which the fake clock makes degenerate on purpose."""
    store, _ = fresh_store()
    ids = [store.enqueue_work("s", f"d{i}") for i in range(n)]
    got = [store.claim_work("w", space_id="s", lease_s=LEASE_S)["item_id"]
           for _ in range(n)]
    assert got == ids
    store.close()


# ------------------------------------------------- conservation under chaos


@given(n_items=st.integers(min_value=1, max_value=8),
       script=st.lists(st.tuples(st.sampled_from(["claim", "finish", "die",
                                                  "gc", "tick"]),
                                 st.integers(min_value=0, max_value=3)),
                       min_size=4, max_size=40))
@settings(max_examples=40, deadline=None)
def test_no_item_lost_or_double_finished_under_interleaving(n_items, script):
    """Drive a random interleaving of worker-fleet operations and assert the
    conservation invariants at every step and at the end:

    * an item is finished at most once (zombie finishes rejected);
    * no item is ever lost — after the dust settles, every item is either
      done or still claimable, and draining finishes the lot.
    """
    store, clock = fresh_store()
    ids = [store.enqueue_work("s", f"d{i}", priority=float(i % 3))
           for i in range(n_items)]
    workers = [f"w{k}" for k in range(3)]
    held = {w: [] for w in workers}     # live claims per worker
    zombies = []                        # (worker, item_id) from dead workers
    finished = set()

    for op, arg in script:
        w = workers[arg % len(workers)]
        if op == "claim":
            for claim in store.claim_work_batch(w, limit=1 + arg,
                                                space_id="s", lease_s=LEASE_S):
                assert claim["item_id"] not in finished
                held[w].append(claim["item_id"])
        elif op == "finish":
            if held[w]:
                item = held[w].pop(0)
                if store.finish_work(item, "measured", owner=w):
                    assert item not in finished, "double finish!"
                    finished.add(item)
        elif op == "die":
            # silent death: claims stop heartbeating; the items become
            # zombies that may later attempt a stale finish
            zombies.extend((w, item) for item in held[w])
            held[w] = []
        elif op == "gc":
            clock.advance(LEASE_S + 1.0)  # expire non-renewed leases
            for live in workers:
                if held[live]:
                    store.renew_lease(live, LEASE_S)
            store.requeue_stale_work()
            # a zombie tries to overwrite after the requeue: must bounce
            # unless the item genuinely still belongs to it (it doesn't —
            # its lease expired and it was requeued or re-claimed)
            for zw, zitem in zombies:
                if store.finish_work(zitem, "failed", "late", owner=zw):
                    assert zitem not in finished
                    finished.add(zitem)  # pragma: no cover - must not happen
            zombies = []
        elif op == "tick":
            for live in workers:
                if held[live]:
                    store.renew_lease(live, LEASE_S)
            clock.advance(1.0)

    # settle: expire every outstanding lease, requeue, and drain with one
    # healthy worker — conservation means this terminates with ALL items done
    clock.advance(LEASE_S + 1.0)
    store.requeue_stale_work()
    # zombies from the tail of the script must still bounce
    for zw, zitem in zombies:
        if zitem not in finished:
            assert store.finish_work(zitem, "failed", "late", owner=zw) is False
    guard = 0
    while True:
        claim = store.claim_work("drainer", space_id="s", lease_s=LEASE_S)
        if claim is None:
            break
        assert claim["item_id"] not in finished
        assert store.finish_work(claim["item_id"], "measured", owner="drainer")
        finished.add(claim["item_id"])
        guard += 1
        assert guard <= n_items, "queue yielded more claims than items exist"
    # ...except the ones legitimately finished earlier; nothing vanished
    results = store.fetch_work_results(ids)
    assert set(results) == set(ids) == finished
    store.close()


@given(limits=st.tuples(st.integers(min_value=1, max_value=6),
                        st.integers(min_value=1, max_value=6),
                        st.integers(min_value=1, max_value=6)))
@settings(max_examples=25, deadline=None)
def test_racing_batch_claims_partition_the_queue(limits):
    """However claim batches interleave, every item is handed to exactly one
    worker."""
    store, _ = fresh_store()
    ids = [store.enqueue_work("s", f"d{i}", priority=float(-i))
           for i in range(10)]
    seen = []
    exhausted = False
    while not exhausted:
        exhausted = True
        for k, limit in enumerate(limits):
            claims = store.claim_work_batch(f"w{k}", limit=limit, space_id="s",
                                            lease_s=LEASE_S)
            if claims:
                exhausted = False
            seen.extend(c["item_id"] for c in claims)
    assert sorted(seen) == sorted(ids)
    assert len(set(seen)) == len(seen), "an item was claimed twice"
    store.close()
