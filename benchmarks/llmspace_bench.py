"""LLM deployment-space family benchmark: warm-started sibling vs cold.

The tentpole demonstration of :mod:`repro.workloads.llm`: the repo's own
models, exposed as Discovery Spaces by :class:`DeploymentSpaceFamily`, are
the ideal §IV stress test — one generator yields many *related* spaces
(same model, different sequence length or device topology), so knowledge
measured in one member should transfer into its siblings.

Per pair: build member space A (short sequence length), measure it
exhaustively at the fast dryrun tier (the analytic roofline cost model —
the prior study's "historical data"); then search sibling member B twice
with the same optimizer, seed, and budget:

* **warm** — a declarative :class:`Investigation` built from the family's
  own :meth:`~repro.workloads.llm.DeploymentSpaceFamily.investigation_spec`
  with transfer enabled: it finds member A in the
  :class:`~repro.core.api.catalog.SpaceCatalog`, measures a representative
  sub-space of B, applies the r>0.7 / p<0.01 criteria, and warm-starts from
  surrogate predictions over A's full history (plus the step-⑧
  ``predict_remaining`` sweep, recorded in the artifact);
* **cold** — the same search on a store holding no sibling data.

Pairs:

* **seq-shift** — B is the same 4-chip topology at double the sequence
  length: identical Ω (the FT-TRANS pattern), found by exact dimension
  match; representative selection is the paper's clustering method.
* **topology-shift** — B is the same sequence length on an 8-chip slice:
  the ``mesh`` dimension's labels change (``2x2`` → ``2x4`` …) but keep
  cardinality and semantic order, so the catalog bridges them by
  positional rename *inference* (§IV-1); selection is the top-5 baseline
  (the clustering pick on this surface is too small to clear p<0.01 — a
  legitimate no-go under the paper's criteria, so the bench uses the
  §V-B2 baseline that selects more fit points).

Metric: paid measurements (representatives + measured/failed search
trials) until a trial reaches a top-quantile threshold of the enumerated
ground truth; medians over the seed set; §V-B2 surrogate prediction
quality scored against exhaustive ground truth.

Run directly::

    PYTHONPATH=src python -m benchmarks.llmspace_bench [--quick] [--out F]

``--quick`` is the CI smoke mode (seq-shift only, fewer seeds); either mode
writes the full result set to ``BENCH_llmspace.json``.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import Investigation, SampleStore
from repro.core.api.spec import TransferSpec
from repro.core.transfer import prediction_quality
from repro.workloads.llm import DeploymentSpaceFamily

__all__ = ["run_llmspace_bench", "PAIRS"]

ARCH = "nano-100m"

#: (source member, target member, representative selection) per pair — the
#: member knobs are (seq_len, devices); everything else is the family.
PAIRS = {
    "seq-shift": {"source": (512, 4), "target": (1024, 4),
                  "selection": "clustering"},
    "topology-shift": {"source": (512, 4), "target": (512, 8),
                       "selection": "top5"},
}


def _exhaustive_truth(family: DeploymentSpaceFamily, seq_len: int,
                      devices: int) -> dict:
    """digest -> step_time_s over the full member space, from a scratch
    store (ground truth; never visible to the benchmarked arms)."""
    ds = family.member(seq_len=seq_len, devices=devices,
                       store=SampleStore(":memory:"))
    results = ds.sample_batch(list(ds.remaining_configurations()),
                              operation_id="ground-truth")
    return {r.configuration.digest: r.sample.value("step_time_s")
            for r in results if r.ok}


def _seed_source(family: DeploymentSpaceFamily, store: SampleStore,
                 seq_len: int, devices: int) -> str:
    """Exhaustively measure the source member into the store (the prior
    study §IV transfer discovers) and return its space_id."""
    src = family.member(seq_len=seq_len, devices=devices, store=store)
    src.sample_batch(list(src.remaining_configurations()),
                     operation_id="historical-study")
    return src.space_id


def _paid_to_target(result, threshold: float, budget: int) -> int:
    """Paid deployments (representatives first, then search trials) until
    the first trial at/below the threshold; budget+1 if never reached."""
    paid = result.transfer.paid if result.transfer is not None else 0
    for _, t in result.events:
        if t.action in ("measured", "failed"):
            paid += 1
        if t.value is not None and t.value <= threshold:
            return paid
    return budget + 1


def _run_arm(family: DeploymentSpaceFamily, pair: dict, seed: int,
             trials: int, warm: bool, optimizer: str):
    store = SampleStore(":memory:")
    if warm:
        _seed_source(family, store, *pair["source"])
    seq_len, devices = pair["target"]
    spec = family.investigation_spec(
        seq_len=seq_len, devices=devices,
        optimizer=optimizer, seed=seed,
        max_trials=trials, patience=trials + 1,
        # a budgeted rep pass (paper Table VI: 4-33 points); the warm arm
        # also runs the step-⑧ predict-remaining sweep so the artifact
        # shows the full predicted surface landing in the store
        transfer=TransferSpec(enabled=warm, selection=pair["selection"],
                              max_representatives=8, predict_remaining=warm))
    return Investigation(spec, store=store).run()


def run_llmspace_bench(pairs=None, seeds=range(8), trials: int = 40,
                       quantile: float = 0.02, optimizer: str = "tpe",
                       verbose: bool = True) -> dict:
    """Warm-vs-cold ablation over the family's sibling pairs (see module
    docstring).  Both arms share optimizer family, seed, and budget; the
    warm arm is charged its representative measurements."""
    pairs = pairs if pairs is not None else list(PAIRS)
    family = DeploymentSpaceFamily(ARCH)
    out = {"arch": ARCH, "trials_per_run": trials, "quantile": quantile,
           "optimizer": optimizer, "seeds": list(seeds),
           "family": family.family_meta(0, 1, "dryrun")["family"],
           "pairs": {}}
    for pname in pairs:
        pair = PAIRS[pname]
        tgt_seq, tgt_dev = pair["target"]
        truth = _exhaustive_truth(family, tgt_seq, tgt_dev)
        values = np.array(sorted(truth.values()))
        threshold = float(np.quantile(values, quantile))
        arms = {"warm": [], "cold": []}
        qualities, transfer_example, predicted = [], None, 0
        for seed in seeds:
            for warm, arm in ((True, "warm"), (False, "cold")):
                res = _run_arm(family, pair, seed, trials, warm, optimizer)
                arms[arm].append(_paid_to_target(res, threshold, trials))
                if warm and res.transfer is not None and res.transfer.applied:
                    if transfer_example is None:
                        transfer_example = res.transfer.summary()
                    predicted = max(predicted, res.transfer.n_predicted)
                    scored = [(p, truth[d])
                              for d, p in res.transfer.warm_predictions.items()
                              if d in truth]
                    if len(scored) >= 2:
                        q = prediction_quality(
                            np.array([p for p, _ in scored]),
                            np.array([a for _, a in scored]),
                            n_measured=res.transfer.paid, mode="min")
                        qualities.append(q.summary())
        medians = {arm: float(np.median(v)) for arm, v in arms.items()}
        speedup_pct = round(
            100.0 * (medians["cold"] - medians["warm"])
            / max(medians["cold"], 1e-9), 1)
        row = {
            "source_member": {"seq_len": pair["source"][0],
                              "devices": pair["source"][1]},
            "target_member": {"seq_len": tgt_seq, "devices": tgt_dev},
            "selection": pair["selection"],
            "metric": "step_time_s",
            "space_size": len(truth),
            "target_threshold_s": threshold,
            "median_paid_to_target": medians,
            "per_seed": {k: list(map(int, v)) for k, v in arms.items()},
            "warm_wins": medians["warm"] < medians["cold"],
            "speedup_pct": speedup_pct,
            "transfer": transfer_example,
            "predict_remaining_swept": predicted,
            "prediction_quality_median": None if not qualities else {
                k: float(np.median([q[k] for q in qualities]))
                for k in qualities[0]},
        }
        out["pairs"][pname] = row
        if verbose:
            print(f"[llmspace] {pname}: target {threshold * 1e3:.3f} ms "
                  f"(q{quantile}); paid-to-target median: warm "
                  f"{medians['warm']:.1f} vs cold {medians['cold']:.1f} "
                  f"({speedup_pct}% fewer paid measurements); "
                  f"predicted surface {predicted} points")
    rows = list(out["pairs"].values())
    out["warm_total_median_paid"] = sum(
        r["median_paid_to_target"]["warm"] for r in rows)
    out["cold_total_median_paid"] = sum(
        r["median_paid_to_target"]["cold"] for r in rows)
    out["pairs_won"] = sum(1 for r in rows if r["warm_wins"])
    # the acceptance claim: every sibling pair passes the §IV criteria and
    # the warm-started sibling reaches best-known cost in fewer paid
    # measurements than cold start (median over the seed set)
    out["pass"] = (out["pairs_won"] == len(rows)
                   and all(r["transfer"] is not None for r in rows))
    return out


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke mode: seq-shift only, fewer seeds")
    parser.add_argument("--out", default="BENCH_llmspace.json",
                        help="JSON artifact path (default: %(default)s)")
    args = parser.parse_args(argv)

    t0 = time.perf_counter()
    if args.quick:
        result = run_llmspace_bench(pairs=["seq-shift"], seeds=range(3),
                                    trials=30)
    else:
        result = run_llmspace_bench()
    result["mode_flag"] = "quick" if args.quick else "full"
    result["wall_s"] = round(time.perf_counter() - t0, 1)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"[llmspace] wrote {args.out} in {result['wall_s']}s: "
          f"{'PASS' if result['pass'] else 'FAIL'} "
          f"(warm total {result['warm_total_median_paid']} vs cold "
          f"{result['cold_total_median_paid']})")
    return 0 if result["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
