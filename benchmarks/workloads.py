"""Synthetic workload configuration spaces mirroring the paper's Table III.

The paper measured real deployments (Spark/TPC-DS, TGI inference); offline we
use closed-form performance surfaces with the SAME dimensions and sizes as
Table III, qualitatively shaped to the paper's findings:

* TP-OPT  (120 cfgs)  — plateaued Spark-like surface; optimizers ≈ random.
* SI-OPT  (864 cfgs)  — smooth single-basin latency; BO-friendly.
* MI-OPT  (2268 cfgs) — multimodal with interactions and non-deployable
  cliffs (the paper's OOM points); favours TPE/BOHB-style samplers.

Each returns (DiscoverySpace-ready ProbabilitySpace, experiment, metric,
mode).  Ground truth is enumerable, so best%-style metrics are exact.
"""

from __future__ import annotations

import numpy as np

from repro.core import (ActionSpace, Configuration, Dimension,
                        FunctionExperiment, MeasurementError,
                        ProbabilitySpace)

__all__ = ["make_tp_opt", "make_si_opt", "make_mi_opt", "WORKLOADS",
           "exhaustive_values"]


def make_tp_opt(seed: int = 0):
    space = ProbabilitySpace.make([
        Dimension.discrete("executors", [12, 14, 16, 18, 20, 22]),
        Dimension.discrete("cores_per_exec", [1, 2, 4, 8]),
        Dimension.discrete("mem_gb", [1, 2, 4, 8, 16]),
    ])
    rng = np.random.default_rng(seed)
    jitter = {c.digest: rng.normal(0, 8.0) for c in space.all_configurations()}

    def fn(c):
        work = 3600.0
        parallel = c["executors"] * c["cores_per_exec"] ** 0.55
        t = work / parallel
        if c["mem_gb"] < 4:                      # spill penalty
            t *= 1.9 - 0.2 * c["mem_gb"]
        if c["cores_per_exec"] == 8:             # GC contention plateau
            t *= 1.15
        return {"runtime_s": t + jitter[c.digest]}

    exp = FunctionExperiment(fn=fn, properties=("runtime_s",), name="tpcds")
    return space, exp, "runtime_s", "min"


def make_si_opt(seed: int = 0):
    space = ProbabilitySpace.make([
        Dimension.categorical("gpu_model",
                              ["A100-PCIE-40GB", "Tesla-T4", "V100-PCIE-16GB"]),
        Dimension.discrete("num_gpus", [1, 2, 4]),
        Dimension.discrete("cpu_cores", [2, 4, 8, 16]),
        Dimension.discrete("memory_gi", [16, 32, 64]),
        Dimension.discrete("max_batch", [4, 24, 64, 128]),
        Dimension.discrete("max_seq", [1024, 2048]),
    ])
    rng = np.random.default_rng(seed + 1)
    jitter = {c.digest: rng.normal(0, 4.0) for c in space.all_configurations()}
    tflops = {"A100-PCIE-40GB": 3.0, "V100-PCIE-16GB": 2.0, "Tesla-T4": 1.0}

    def fn(c):
        base = 600.0 / (tflops[c["gpu_model"]] * c["num_gpus"] ** 0.75)
        cpu = 120.0 / c["cpu_cores"]
        batch = 4.0 * np.log2(c["max_batch"])    # batching overhead @p95
        seq = 0.012 * c["max_seq"]
        mem = 20.0 if c["memory_gi"] < 32 else 0.0
        return {"latency95_ms": base + cpu + batch + seq + mem
                + jitter[c.digest]}

    exp = FunctionExperiment(fn=fn, properties=("latency95_ms",), name="tgi-single")
    return space, exp, "latency95_ms", "min"


def make_mi_opt(seed: int = 0):
    space = ProbabilitySpace.make([
        Dimension.discrete("max_batch", [4, 8, 16, 32, 64, 128, 256]),
        Dimension.discrete("max_batch_weight",
                           [19000, 50000, 100000, 1000000, 2000000, 2968750]),
        Dimension.discrete("max_concurrent", [64, 128, 320]),
        Dimension.discrete("max_new_tokens", [512, 1024, 1536]),
        Dimension.discrete("max_seq", [1024, 2048, 4096]),
        Dimension.categorical("flash_attention", [False, True]),
    ])
    rng = np.random.default_rng(seed + 2)
    jitter = {c.digest: rng.normal(0, 6.0) for c in space.all_configurations()}

    def fn(c):
        # OOM cliff: big batch×seq without flash attention is non-deployable
        pressure = c["max_batch"] * c["max_seq"]
        if not c["flash_attention"] and pressure > 128 * 2048:
            raise MeasurementError("OOM")
        throughput = min(c["max_batch"], c["max_concurrent"]) ** 0.8
        t = 4000.0 / throughput
        t += 0.04 * c["max_new_tokens"]
        if c["max_batch_weight"] < 100000:       # queueing mode
            t += 55.0
        elif c["max_batch_weight"] > 2000000 and not c["flash_attention"]:
            t += 90.0                            # thrashing mode
        if c["flash_attention"]:
            t *= 0.82
        if c["max_seq"] == 4096 and c["max_batch"] >= 64:
            t *= 1.3                             # interaction bump
        return {"mean_latency_ms": t + jitter[c.digest]}

    exp = FunctionExperiment(fn=fn, properties=("mean_latency_ms",), name="tgi-multi")
    return space, exp, "mean_latency_ms", "min"


WORKLOADS = {
    "TP-OPT": make_tp_opt,
    "SI-OPT": make_si_opt,
    "MI-OPT": make_mi_opt,
}


def exhaustive_values(space, exp, metric):
    """(configs, values) over deployable points (ground truth)."""
    configs, values = [], []
    for c in space.all_configurations():
        try:
            values.append(exp.measure(c)[metric])
            configs.append(c)
        except MeasurementError:
            continue
    return configs, np.array(values)
