"""Paper Table VI: RSSC knowledge-transfer quality across related spaces.

Three synthetic transfer tests mirror the paper's qualitative findings:

* FT-TRANS analogue — workload-model swap with a strong linear relation
  (transfer ✓, high quality).
* MI-TRANS analogue — infrastructure change, linear globally but noisy near
  the optimum (clustering ✓; the local top5 method false-negatives).
* SI-TRANS analogue — "small" hardware change with a non-monotone response
  (transfer ✗ — RSSC correctly refuses).

Plus ONE REAL transfer test (``real-walltime``): wall-clock step times of two
reduced architectures (xlstm-125m ssm ↔ deepseek-67b dense) over the same
deployment dimensions, measured on this machine — the cross-architecture
reuse scenario of DESIGN.md, with genuinely measured data.
"""

from __future__ import annotations

import numpy as np

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        FunctionExperiment, ProbabilitySpace, SampleStore,
                        prediction_quality, rssc_transfer)

__all__ = ["run_table_vi", "run_real_transfer"]


def _make_pair(kind: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    space = ProbabilitySpace.make([
        Dimension.categorical("infra", ["source-infra"]),
        Dimension.discrete("batch", [2, 4, 8, 16, 32, 64, 128]),
        Dimension.discrete("gpus", [2, 4]),
        Dimension.discrete("tokens", [512, 1024, 2048, 4096]),
    ])
    mapping = {"infra": {"source-infra": "target-infra"}}

    def base(c):
        return 5e5 / (c["batch"] ** 0.6 * c["gpus"]) + 0.05 * c["tokens"]

    jit_s = {c.digest: rng.normal(0, 10) for c in space.all_configurations()}
    tgt_space = space.map_values(mapping)
    jit_t = {c.digest: rng.normal(0, 10) for c in tgt_space.all_configurations()}

    def src_fn(c):
        return {"tokens_per_s": base(c) + jit_s[c.digest]}

    def tgt_fn(c):
        v = base(c)
        if kind == "linear":
            out = 0.7 * v + 300.0 + jit_t[c.digest]
        elif kind == "noisy-optimum":
            noise = jit_t[c.digest] * (6.0 if v < 2e4 else 0.5)
            out = 1.3 * v - 100.0 + noise
        else:  # 'broken': non-monotone response to the change
            out = 2e4 + 8e3 * np.sin(v / 7e3) + jit_t[c.digest] * 3
        return {"tokens_per_s": out}

    store = SampleStore(":memory:")
    ds_src = DiscoverySpace(
        space=space,
        actions=ActionSpace.make([FunctionExperiment(
            fn=src_fn, properties=("tokens_per_s",), name="bench-src")]),
        store=store)
    ds_tgt = DiscoverySpace(
        space=tgt_space,
        actions=ActionSpace.make([FunctionExperiment(
            fn=tgt_fn, properties=("tokens_per_s",), name="bench-tgt")]),
        store=store)
    return ds_src, ds_tgt, mapping, tgt_fn


TESTS = {
    "FT-TRANS(linear)": "linear",
    "MI-TRANS(noisy-optimum)": "noisy-optimum",
    "SI-TRANS(broken)": "broken",
}


def _evaluate(res, ds_tgt, tgt_fn, metric="tokens_per_s", mode="min"):
    row = res.summary()
    if not res.transferable:
        row.update({"best%": None, "top5%": None, "rank_resolution": None,
                    "%savings": None})
        return row
    preds = res.predicted_space.read()
    pred_vals = np.array([s.value(metric) for s in preds])
    true_vals = np.array([tgt_fn(s.configuration)[metric] for s in preds])
    q = prediction_quality(pred_vals, true_vals,
                           n_measured=res.n_target_measured, mode=mode)
    row.update(q.summary())
    return row


def run_table_vi(verbose: bool = True) -> list:
    rows = []
    for tname, kind in TESTS.items():
        for method in ("clustering", "top5", "linspace"):
            ds_src, ds_tgt, mapping, tgt_fn = _make_pair(kind, seed=3)
            for c in list(ds_src.remaining_configurations()):
                ds_src.sample(c)  # exhaustively characterized source (paper §V-A)
            res = rssc_transfer(ds_src, ds_tgt, "tokens_per_s", mapping,
                                selection=method,
                                rng=np.random.default_rng(0))
            row = {"test_case": tname, **_evaluate(res, ds_tgt, tgt_fn)}
            rows.append(row)
            if verbose:
                print(f"[table-vi] {tname:24s} {method:10s} "
                      f"r={row['r']:+.3f} p={row['p_value']:.2g} "
                      f"transfer={row['transfer']} best%={row['best%']} "
                      f"top5%={row['top5%']} savings={row['%savings']}")
    return rows


def run_real_transfer(verbose: bool = True) -> dict:
    """Real measured transfer: xlstm-125m ↔ deepseek-67b reduced-config
    wall-times over identical deployment dimensions (identity mapping —
    the change is in the action space, like the paper's FT-TRANS)."""
    from repro.tuning.experiments import WalltimeExperiment

    space = ProbabilitySpace.make([
        Dimension.discrete("batch", [1, 2, 4]),
        Dimension.discrete("seq", [32, 64, 128]),
        Dimension.discrete("attn_q_chunk", [16, 32, 64]),
        Dimension.categorical("remat", ["none", "full"]),
    ])
    store = SampleStore(":memory:")
    src_exp = WalltimeExperiment("xlstm-125m", repeats=2)
    tgt_exp = WalltimeExperiment("deepseek-67b", repeats=2)
    ds_src = DiscoverySpace(space=space, actions=ActionSpace.make([src_exp]),
                            store=store)
    ds_tgt = DiscoverySpace(space=space, actions=ActionSpace.make([tgt_exp]),
                            store=store)
    for c in list(ds_src.remaining_configurations()):
        ds_src.sample(c)
    res = rssc_transfer(ds_src, ds_tgt, "step_ms", mapping=None,
                        rng=np.random.default_rng(1))
    row = res.summary()
    if res.transferable:
        # ground truth: exhaustively measure the target for scoring only
        truth_ds = DiscoverySpace(space=space,
                                  actions=ActionSpace.make([tgt_exp]),
                                  store=store)
        vals, preds = [], []
        for s in res.predicted_space.read():
            preds.append(s.value("step_ms"))
            vals.append(truth_ds.sample(s.configuration).value("step_ms"))
        q = prediction_quality(np.array(preds), np.array(vals),
                               n_measured=res.n_target_measured, mode="min")
        row.update(q.summary())
    if verbose:
        print(f"[real-transfer] xlstm→deepseek walltime: {row}")
    return row
