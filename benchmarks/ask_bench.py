"""Ask-latency benchmark: numpy vs jitted/pallas optimizer hot paths.

After PR 5, warm-start and campaign foreign tells inject thousands of
trials into every member's history, so the surrogate fit + acquisition —
BO-GP's O(|H|³) Cholesky and per-candidate posterior, TPE's per-dimension
Parzen densities — sit on the ask critical path.  This bench measures the
ask hot path per backend over a grid of history length × candidate-pool
size and writes ``BENCH_ask.json``.

What exactly is timed
---------------------

The backend-dispatched scoring APIs the accelerated backends replace —
``GPBayesOpt._acquisition`` (fit + batched EI over the whole encoded pool)
and ``TPE._score`` (good/bad Parzen ratio for every candidate) — plus, as
context, one end-to-end ``Optimizer.ask`` row per family at the gate point
(including candidate-pool sampling and encoding, identical across
backends).  Per grid point: ``first_ms`` is the cold first call (for jax
backends this includes jit compile; shape bucketing means one compile
serves a whole history regime) and ``ms`` is the median of the following
repeats.  For BO-GP the accelerated backends separate fit from predict
(sklearn-style) and cache the Cholesky factorization until the history
content changes, so their ``ms`` is the acquisition cost against a fitted
surrogate — ``first_ms`` is the with-refit cost — while the numpy
reference refits on every call by construction.

The gate
--------

``--quick`` is the CI mode: a reduced grid that still contains the
(|H|=2048, pool=4096) acceptance point, plus a soft regression gate — exit
nonzero if the jitted BO-GP path is not at least as fast as numpy there.
The acceptance criterion for this PR is >=5x at that point; the gate only
enforces >=1x so routine CI noise cannot mask a real regression signal
with flakes.

Run directly::

    PYTHONPATH=src python -m benchmarks.ask_bench [--quick] [--out F]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (ActionSpace, Dimension, DiscoverySpace,
                        FunctionExperiment, ProbabilitySpace, SampleStore)
from repro.core.optimizers import GPBayesOpt, TPE
from repro.core.optimizers.accel import jax_available, pallas_available
from repro.core.optimizers.base import SearchAdapter, Trial

__all__ = ["run_grid", "main"]

HISTORY_SIZES = (32, 256, 2048, 8192)
POOL_SIZES = (1024, 4096)
QUICK_HISTORY = (32, 256, 2048)
QUICK_POOLS = (4096,)
#: The acceptance/gate point: jitted ask must beat numpy here.
GATE_HISTORY, GATE_POOL = 2048, 4096


def _space() -> ProbabilitySpace:
    """A million-option mixed space (the paper's target regime): pools are
    drawn from it, so candidate encodings look like real searches."""
    return ProbabilitySpace.make([
        Dimension.discrete("cpu", sorted(int(v) for v in
                                         np.linspace(1, 128, 40))),
        Dimension.discrete("mem_gb", sorted(int(v) for v in
                                            np.linspace(1, 512, 40))),
        Dimension.categorical("instance", [f"type-{i}" for i in range(12)]),
        Dimension.continuous("util_target", 0.1, 0.95),
    ])


def _history(space, n, seed):
    rng = np.random.default_rng(seed)
    configs = [space.sample_configuration(rng) for _ in range(n)]
    y = rng.random(n)
    return configs, y


def _pool(space, n, seed):
    rng = np.random.default_rng(10_000 + seed)
    return [space.sample_configuration(rng) for _ in range(n)]


def _timed(fn, repeats):
    """(first_ms, median_ms_of_repeats) — first call separated so jit
    compile never pollutes the steady-state number."""
    t0 = time.perf_counter()
    fn()
    first = (time.perf_counter() - t0) * 1e3
    laps = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        laps.append((time.perf_counter() - t0) * 1e3)
    return first, float(np.median(laps))


def _gp_row(space, backend, h, p, repeats, seed=0):
    opt = GPBayesOpt(seed=0, backend=backend, max_candidates=p)
    configs, y = _history(space, h, seed)
    X = np.stack([space.encode(c) for c in configs])
    Xc = np.stack([space.encode(c) for c in _pool(space, p, seed)])
    first, med = _timed(lambda: opt._acquisition(X, y, Xc), repeats)
    return {"family": "bo-gp", "backend": backend, "history": h, "pool": p,
            "first_ms": round(first, 3), "ms": round(med, 3)}


def _tpe_row(space, backend, h, p, repeats, seed=0):
    opt = TPE(seed=0, backend=backend, max_candidates=p)
    configs, y = _history(space, h, seed)
    order = np.argsort(y)
    n_good = max(1, int(np.ceil(opt.gamma * h)))
    good = [configs[i] for i in order[:n_good]]
    bad = [configs[i] for i in order[n_good:]]
    pool = _pool(space, p, seed)
    first, med = _timed(lambda: opt._score(space, good, bad, pool), repeats)
    return {"family": "tpe", "backend": backend, "history": h, "pool": p,
            "first_ms": round(first, 3), "ms": round(med, 3)}


def _e2e_ask_row(space, family, backend, h, p, repeats, seed=0):
    """Full Optimizer.ask at the gate point: pool sampling + encode +
    score + top-n, on an adapter preloaded with a synthetic history."""
    cls = {"bo-gp": GPBayesOpt, "tpe": TPE}[family]
    opt = cls(seed=0, backend=backend, max_candidates=p)
    exp = FunctionExperiment(fn=lambda c: {"m": 0.0}, properties=("m",),
                             name="bench")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                        store=SampleStore(":memory:"))
    adapter = SearchAdapter(ds, "m", "min")
    configs, y = _history(space, h, seed)
    adapter.tell([Trial(c, float(v), "measured", i)
                  for i, (c, v) in enumerate(zip(configs, y))])
    rng = np.random.default_rng(7)
    first, med = _timed(lambda: opt.ask(adapter, rng, n=1), repeats)
    return {"family": family, "backend": backend, "history": h, "pool": p,
            "first_ms": round(first, 3), "ms": round(med, 3), "e2e": True}


def _add_speedups(rows):
    """speedup = numpy ms / backend ms at the same grid point."""
    ref = {(r["family"], r["history"], r["pool"], bool(r.get("e2e"))):
           r["ms"] for r in rows if r["backend"] == "numpy"}
    for r in rows:
        base = ref.get((r["family"], r["history"], r["pool"],
                        bool(r.get("e2e"))))
        if base is not None and r["ms"] > 0:
            r["speedup"] = round(base / r["ms"], 2)


def run_grid(quick: bool = False, verbose: bool = True) -> dict:
    space = _space()
    histories = QUICK_HISTORY if quick else HISTORY_SIZES
    pools = QUICK_POOLS if quick else POOL_SIZES
    repeats = 3 if quick else 5
    backends = ["numpy"]
    if jax_available():
        backends.append("jax")
        # the interpreted (CPU) pallas path is a correctness vehicle, not a
        # perf claim — only grid it in full mode, and off-CPU it runs real
        if not quick or pallas_available():
            backends.append("pallas")
    rows = []
    for h in histories:
        for p in pools:
            for backend in backends:
                if backend == "pallas" and quick and (h > 256 or p > 4096):
                    continue  # interpret-mode pallas at depth: full mode only
                rows.append(_gp_row(space, backend, h, p, repeats))
                rows.append(_tpe_row(space, backend, h, p, repeats))
                if verbose:
                    for r in rows[-2:]:
                        print(f"[ask] {r['family']:5s} {r['backend']:6s} "
                              f"|H|={r['history']:<5d} pool={r['pool']:<5d} "
                              f"first={r['first_ms']:9.1f}ms "
                              f"ms={r['ms']:9.1f}")
    # end-to-end context rows at the gate point (numpy + jax)
    gate_h = GATE_HISTORY if GATE_HISTORY in histories else max(histories)
    gate_p = GATE_POOL if GATE_POOL in pools else max(pools)
    for family in ("bo-gp", "tpe"):
        for backend in backends[:2]:
            rows.append(_e2e_ask_row(space, family, backend, gate_h, gate_p,
                                     repeats))
    _add_speedups(rows)

    gate = {"history": gate_h, "pool": gate_p, "enforced": False,
            "passed": True}
    if "jax" in backends:
        by = {(r["family"], r["backend"]): r["ms"] for r in rows
              if r["history"] == gate_h and r["pool"] == gate_p
              and not r.get("e2e")}
        gate.update(
            enforced=True,
            numpy_ms=by[("bo-gp", "numpy")], jax_ms=by[("bo-gp", "jax")],
            speedup=round(by[("bo-gp", "numpy")] / by[("bo-gp", "jax")], 2),
            tpe_speedup=round(by[("tpe", "numpy")] / by[("tpe", "jax")], 2),
            passed=by[("bo-gp", "jax")] <= by[("bo-gp", "numpy")])
    result = {"schema": 1, "quick": quick, "jax": jax_available(),
              "pallas": pallas_available(), "rows": rows, "gate": gate}
    if verbose and gate["enforced"]:
        print(f"[ask] gate |H|={gate_h} pool={gate_p}: "
              f"bo-gp {gate['speedup']}x, tpe {gate['tpe_speedup']}x "
              f"({'PASS' if gate['passed'] else 'FAIL'})")
    return result


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke mode: reduced grid, keeps the gate")
    parser.add_argument("--out", default="BENCH_ask.json")
    args = parser.parse_args(argv)
    result = run_grid(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[ask] wrote {args.out}")
    if result["gate"]["enforced"] and not result["gate"]["passed"]:
        print("[ask] REGRESSION: jitted bo-gp ask slower than numpy at "
              f"|H|={result['gate']['history']}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
