"""§Roofline: the per-(arch × shape) baseline table from dry-run artifacts.

Reads the JSON results saved by ``repro.launch.dryrun`` under
``experiments/dryrun/`` and emits the roofline table: three terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio, and a one-line lever per
cell.  (The dry-run itself needs the 512-device env and is run as its own
entry point; this module only aggregates.)
"""

from __future__ import annotations

import glob
import json
import os

__all__ = ["load_reports", "render_table", "lever_for"]

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_reports(mesh: str = "16x16") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        rows.append(r)
    return rows


def lever_for(row: dict) -> str:
    """One sentence: what would move the dominant term down."""
    if str(row.get("status", "")).startswith("skip"):
        return row["status"]
    roof = row["roofline"]
    dom = roof["dominant"]
    shape = row["shape"]
    if dom == "compute":
        if roof.get("useful_ratio", 1) < 0.7:
            return ("compute-bound with low useful ratio: cut remat recompute "
                    "or causal-band waste (band_skip / larger chunks)")
        return "compute-bound near useful peak: only batching/quantization help"
    if dom == "memory":
        if shape.startswith("decode") or shape.startswith("long"):
            return ("memory-bound decode: weights+KV stream per token — "
                    "raise batch per chip, quantize KV cache, or shrink TP "
                    "degree to cut weight re-reads")
        return ("memory-bound: increase arithmetic intensity — larger "
                "microbatch per device, fuse elementwise chains, avoid fp32 "
                "residual copies")
    return ("collective-bound: move FSDP gathers off the critical path "
            "(overlap), shard a different axis, or compress cross-pod grads")


def render_table(mesh: str = "16x16") -> str:
    rows = load_reports(mesh)
    lines = [
        f"### Roofline baselines — mesh {mesh} "
        f"({'256' if mesh == '16x16' else '512'} chips, v5e constants)",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful | roofline_frac | bytes/dev | lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if str(r.get("status", "")).startswith("skip"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                f"{r['status']} |")
            continue
        roof = r["roofline"]
        bpd = roof.get("bytes_per_device")
        bpd_s = f"{bpd / 1e9:.1f}G" if bpd else "?"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {roof['compute_s']:.4g} | "
            f"{roof['memory_s']:.4g} | {roof['collective_s']:.4g} | "
            f"{roof['dominant']} | {roof['useful_ratio']:.2f} | "
            f"{roof['roofline_fraction']:.3f} | {bpd_s} | {lever_for(r)} |")
    return "\n".join(lines)


def main() -> None:
    for mesh in ("16x16", "2x16x16"):
        print(render_table(mesh))
        print()


if __name__ == "__main__":
    main()
