"""Cross-space transfer-reuse benchmark: warm-started vs cold search.

The repo's reproduction of the paper's second headline claim — Discovery
Spaces enable transfer of knowledge across similar search spaces for large
configuration-search speed-ups (§IV-3/4, §V-B) — now end to end through the
declarative API: a *warm* :class:`~repro.core.api.investigation.Investigation`
discovers the previously-measured related space in the
:class:`~repro.core.api.catalog.SpaceCatalog`, measures only a representative
sub-space in the target, applies the r>0.7 / p<0.01 criteria, and warm-starts
its optimizer's history with surrogate predictions over the source's full
history; a *cold* investigation runs the same optimizer, seed, and budget on
a store with no source data.

Two related space pairs (dimensions from the paper's Table III workloads):

* **SI-OPT-rename** — the TGI single-instance space with every
  ``gpu_model`` value renamed (PCIE→SXM generations, the §IV-1
  ``map_values`` pattern; the catalog *infers* the rename positionally) and
  an affine-plus-noise shift of the performance surface (new hardware,
  same shape);
* **TP-OPT-provider** — the Spark/TPC-DS space unchanged, surface scaled
  and offset (same workload on a different provider; found in the catalog
  by exact dimension match, different action space).

Metric: *paid measurements to best-known cost* — measured + failed
deployments (the warm arm is charged its representative measurements first)
until a trial lands at or below a top-quantile threshold of the enumerated
ground truth; median over the seed set, speed-up percentage reported.  The
surrogate's §V-B2 prediction quality (best%, top5%, rank resolution) is
scored against the exhaustive ground truth per seed.

Run directly::

    PYTHONPATH=src python -m benchmarks.transfer_bench [--quick] [--out F]

``--quick`` is the CI smoke mode (one pair, fewer seeds); either mode writes
the full result set to ``BENCH_transfer.json``.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (ActionSpace, Configuration, DiscoverySpace,
                        FunctionExperiment, Investigation, SampleStore)
from repro.core.api.investigation import TransferReport  # noqa: F401 (doc)
from repro.core.api.spec import TransferSpec
from repro.core.entities import content_hash
from repro.core.optimizers import OPTIMIZER_REGISTRY
from repro.core.transfer import prediction_quality

from .workloads import (exhaustive_values, make_mi_opt, make_si_opt,
                        make_tp_opt)

__all__ = ["run_transfer_bench", "PAIRS"]


def _jitter(seed: int, digest: str, scale: float) -> float:
    """Deterministic per-configuration noise keyed on the digest."""
    h = int(content_hash([seed, digest])[:8], 16)
    return scale * (2.0 * (h / 0xFFFFFFFF) - 1.0)


def make_si_opt_rename():
    """SI-OPT source + a gpu-generation-renamed target with an affine+noise
    shifted surface.  The value rename is what the catalog must bridge."""
    space, exp, metric, mode = make_si_opt()
    rename = {"gpu_model": {"A100-PCIE-40GB": "A100-SXM4-40GB",
                            "Tesla-T4": "Tesla-L4",
                            "V100-PCIE-16GB": "V100-SXM2-16GB"}}
    inverse = {d: {t: s for s, t in m.items()} for d, m in rename.items()}
    tgt_space = space.map_values(rename)

    def tgt_fn(c: Configuration):
        src_c = space.translate(c, inverse)
        base = exp.measure(src_c)[metric]
        return {metric: 1.35 * base + 25.0 + _jitter(11, c.digest, 3.0)}

    tgt_exp = FunctionExperiment(fn=tgt_fn, properties=(metric,),
                                 name="tgi-single-sxm")
    return {"space": space, "exp": exp, "tgt_space": tgt_space,
            "tgt_exp": tgt_exp, "metric": metric, "mode": mode}


def make_tp_opt_provider():
    """TP-OPT source + an identically-dimensioned target on a 'different
    provider': scaled + offset + noise surface, exact catalog match.  The
    hard case: TP-OPT is the paper's plateaued workload where optimizers
    barely beat random, so a cheap cold search leaves transfer little room."""
    space, exp, metric, mode = make_tp_opt()

    def tgt_fn(c: Configuration):
        base = exp.measure(c)[metric]
        return {metric: 0.8 * base + 40.0 + _jitter(7, c.digest, 4.0)}

    tgt_exp = FunctionExperiment(fn=tgt_fn, properties=(metric,),
                                 name="tpcds-provider-b")
    return {"space": space, "exp": exp, "tgt_space": space,
            "tgt_exp": tgt_exp, "metric": metric, "mode": mode}


def make_mi_opt_provider():
    """MI-OPT source + a provider-shifted target: the multimodal TGI space
    with OOM cliffs — non-deployable configurations fail in BOTH spaces, so
    the transfer stage must survive failed representative measurements (they
    are skipped in the fit but still paid)."""
    space, exp, metric, mode = make_mi_opt()

    def tgt_fn(c: Configuration):
        base = exp.measure(c)[metric]  # raises MeasurementError on the cliff
        return {metric: 1.15 * base + 12.0 + _jitter(13, c.digest, 5.0)}

    tgt_exp = FunctionExperiment(fn=tgt_fn, properties=(metric,),
                                 name="tgi-multi-provider-b")
    return {"space": space, "exp": exp, "tgt_space": space,
            "tgt_exp": tgt_exp, "metric": metric, "mode": mode}


PAIRS = {
    "SI-OPT-rename": make_si_opt_rename,
    "TP-OPT-provider": make_tp_opt_provider,
    "MI-OPT-provider": make_mi_opt_provider,
}


def _seed_source(store: SampleStore, pair: dict) -> str:
    """Exhaustively measure the source space into the store (the paper's
    well-sampled prior study) and return its space_id."""
    src = DiscoverySpace(space=pair["space"],
                         actions=ActionSpace.make([pair["exp"]]),
                         store=store)
    src.sample_batch(list(src.remaining_configurations()),
                     operation_id="historical-study")
    return src.space_id


def _paid_to_target(result, threshold: float, mode: str, budget: int) -> int:
    """Paid deployments (transfer representatives first, then search trials)
    until the first trial at/beyond the target threshold; budget+1 if the
    run never reached it."""
    paid = result.transfer.paid if result.transfer is not None else 0
    for _, t in result.events:
        if t.action in ("measured", "failed"):
            paid += 1
        if t.value is None:
            continue
        if (t.value <= threshold) if mode == "min" else (t.value >= threshold):
            return paid
    return budget + 1


def _run_arm(pair: dict, seed: int, trials: int, warm: bool,
             optimizer: str) -> "tuple":
    store = SampleStore(":memory:")
    if warm:
        _seed_source(store, pair)
    ds = DiscoverySpace(space=pair["tgt_space"],
                        actions=ActionSpace.make([pair["tgt_exp"]]),
                        store=store)
    inv = Investigation.from_components(
        ds, [OPTIMIZER_REGISTRY[optimizer](seed=seed)], pair["metric"],
        mode=pair["mode"], max_trials=trials, patience=trials + 1,
        backend="serial",
        # a budgeted rep pass (paper Table VI: 4-33 points; 8 here keeps the
        # paid warm-up small relative to the search it replaces)
        transfer=TransferSpec(enabled=warm, max_representatives=8),
        name="transfer-bench")
    return inv.run(), store


def run_transfer_bench(pairs=None, seeds=range(16), trials: int = 60,
                       quantile: float = 0.01, optimizer: str = "tpe",
                       verbose: bool = True) -> dict:
    """Warm-vs-cold ablation over a seed set (see module docstring).

    Both arms run the same optimizer family, seed, and per-run trial budget;
    the warm arm is additionally charged every representative measurement
    its transfer stage paid for.  Reported per pair: median (over seeds)
    paid-measurements-to-target for each arm, the speed-up percentage, and
    the surrogate's §V-B2 prediction quality vs exhaustive ground truth.
    """
    pairs = pairs if pairs is not None else list(PAIRS)
    out = {"trials_per_run": trials, "quantile": quantile,
           "optimizer": optimizer, "seeds": list(seeds), "pairs": {}}
    for pname in pairs:
        pair = PAIRS[pname]()
        metric, mode = pair["metric"], pair["mode"]
        configs, truth = exhaustive_values(pair["tgt_space"], pair["tgt_exp"],
                                           metric)
        truth_by_digest = {c.digest: v for c, v in zip(configs, truth)}
        threshold = float(np.quantile(
            truth, quantile if mode == "min" else 1 - quantile))
        arms = {"warm": [], "cold": []}
        qualities, transfer_example = [], None
        for seed in seeds:
            for warm, arm in ((True, "warm"), (False, "cold")):
                res, _ = _run_arm(pair, seed, trials, warm, optimizer)
                arms[arm].append(_paid_to_target(res, threshold, mode, trials))
                if warm and res.transfer is not None and res.transfer.applied:
                    if transfer_example is None:
                        transfer_example = res.transfer.summary()
                    preds = res.transfer.warm_predictions
                    scored = [(p, truth_by_digest[d])
                              for d, p in preds.items()
                              if d in truth_by_digest]
                    if len(scored) >= 2:
                        q = prediction_quality(
                            np.array([p for p, _ in scored]),
                            np.array([a for _, a in scored]),
                            n_measured=res.transfer.paid, mode=mode)
                        qualities.append(q.summary())
        medians = {arm: float(np.median(v)) for arm, v in arms.items()}
        speedup_pct = round(
            100.0 * (medians["cold"] - medians["warm"])
            / max(medians["cold"], 1e-9), 1)
        row = {
            "metric": metric,
            "mode": mode,
            "space_size": pair["tgt_space"].size,
            "target_threshold": round(threshold, 3),
            "median_paid_to_target": medians,
            "per_seed": {k: list(map(int, v)) for k, v in arms.items()},
            "warm_wins": medians["warm"] < medians["cold"],
            "speedup_pct": speedup_pct,
            "transfer": transfer_example,
            "prediction_quality_median": None if not qualities else {
                k: float(np.median([q[k] for q in qualities]))
                for k in qualities[0]},
        }
        out["pairs"][pname] = row
        if verbose:
            pq = row["prediction_quality_median"]
            print(f"[transfer] {pname}: target {row['target_threshold']} "
                  f"(q{quantile}); paid-to-target median: warm "
                  f"{medians['warm']:.1f} vs cold {medians['cold']:.1f} "
                  f"({speedup_pct}% fewer paid measurements); "
                  f"surrogate quality {pq}")
    rows = list(out["pairs"].values())
    out["warm_total_median_paid"] = sum(
        r["median_paid_to_target"]["warm"] for r in rows)
    out["cold_total_median_paid"] = sum(
        r["median_paid_to_target"]["cold"] for r in rows)
    out["pairs_won"] = sum(1 for r in rows if r["warm_wins"])
    # the acceptance claim: warm-started search reaches best-known cost in
    # fewer paid measurements than cold search (median over the seed set)
    # on at least two related space pairs, transfer applied on every pair
    out["pass"] = out["pairs_won"] >= min(2, len(rows)) \
        and all(r["transfer"] is not None for r in rows)
    return out


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke mode: one pair, fewer seeds")
    parser.add_argument("--out", default="BENCH_transfer.json",
                        help="JSON artifact path (default: %(default)s)")
    args = parser.parse_args(argv)

    t0 = time.perf_counter()
    if args.quick:
        result = run_transfer_bench(pairs=["SI-OPT-rename"], seeds=range(3),
                                    trials=40)
    else:
        result = run_transfer_bench()
    result["mode_flag"] = "quick" if args.quick else "full"
    result["wall_s"] = round(time.perf_counter() - t0, 1)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"[transfer] wrote {args.out} in {result['wall_s']}s: "
          f"{'PASS' if result['pass'] else 'FAIL'} "
          f"(warm total {result['warm_total_median_paid']} vs cold "
          f"{result['cold_total_median_paid']})")
    return 0 if result["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
