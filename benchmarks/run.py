"""Benchmark harness: one module per paper table/figure.

  * Table V  — optimizer trials/best% per workload  (optimizers_bench)
  * Fig. 6   — P(95th pctile) vs samples            (optimizers_bench)
  * Fig. 7   — incremental-sampling savings         (incremental)
  * Table VI — RSSC transfer quality                (rssc_bench)
  * §III-D   — batched engine serial vs 4 workers   (parallel_bench)
  * §V       — sharing: campaign vs isolated fleet  (campaign_bench)
  * §Roofline — aggregated dry-run baselines        (roofline_bench)

Prints one CSV block per benchmark: ``name,us_per_call,derived``, where
``us_per_call`` is the mean wall-time per primitive operation of that
benchmark (one optimizer trial / one RSSC transfer / one table render) and
``derived`` is the benchmark's headline metric.

Set QUICK=1 for a fast pass (fewer runs).
"""

from __future__ import annotations

import json
import os
import time


def _csv(name: str, us_per_call: float, derived: str) -> None:
    print(f"CSV,{name},{us_per_call:.1f},{derived}")


def main() -> None:
    quick = os.environ.get("QUICK", "0") == "1"
    n_runs = 3 if quick else 10
    results = {}

    from . import (incremental, optimizers_bench, parallel_bench,
                   roofline_bench, rssc_bench)

    # ---------------- Table V
    t0 = time.time()
    table_v = optimizers_bench.run_table_v(n_runs=n_runs)
    dt = time.time() - t0
    n_trials = sum(r["median_trials"] * n_runs for r in table_v)
    best = max(r["best_pct"] for r in table_v)
    _csv("table_v_optimizers", 1e6 * dt / max(n_trials, 1),
         f"best%={best};rows={len(table_v)}")
    results["table_v"] = table_v

    # ---------------- Fig 6
    t0 = time.time()
    fig6 = optimizers_bench.run_fig6(n_runs=n_runs,
                                     n_samples=30 if quick else 60)
    dt = time.time() - t0
    mi = fig6.get("MI-OPT", {})
    probe = {k: round(float(v[-1]), 3) for k, v in mi.items()}
    _csv("fig6_p_found", 1e6 * dt / (len(fig6) * n_runs * 3),
         f"MI-OPT_final={probe}")
    results["fig6"] = {w: {k: list(map(float, v)) for k, v in c.items()}
                       for w, c in fig6.items()}

    # ---------------- Fig 7
    t0 = time.time()
    fig7 = incremental.run_fig7(n_runs=12 if quick else 30,
                                n_permutations=10 if quick else 20,
                                checkpoints=(10,) if quick else (10, 20, 30))
    dt = time.time() - t0
    savings = {w: v["savings_pct"] for w, v in fig7.items()}
    _csv("fig7_incremental", 1e6 * dt / max(len(fig7), 1),
         f"savings={savings}")
    results["fig7"] = fig7

    # ---------------- Table VI
    t0 = time.time()
    table_vi = rssc_bench.run_table_vi()
    dt = time.time() - t0
    n_ok = sum(1 for r in table_vi if r["transfer"])
    _csv("table_vi_rssc", 1e6 * dt / max(len(table_vi), 1),
         f"transfers={n_ok}/{len(table_vi)}")
    results["table_vi"] = table_vi

    # ---------------- real measured transfer (skipped in QUICK mode)
    if not quick:
        t0 = time.time()
        real = rssc_bench.run_real_transfer()
        dt = time.time() - t0
        _csv("real_transfer_walltime", 1e6 * dt,
             f"r={real.get('r')};transfer={real.get('transfer')};"
             f"best%={real.get('best%')}")
        results["real_transfer"] = real

    # ---------------- parallel engine (serial vs 4 workers, same seed)
    t0 = time.time()
    par = parallel_bench.run_parallel_bench()
    dt = time.time() - t0
    _csv("parallel_engine", 1e6 * dt / max(par["trials"] * 2, 1),
         f"speedup={par['speedup']};identical={par['identical_sample_set']}")
    results["parallel_engine"] = par

    # ---------------- §V sharing efficiency (campaign vs isolated fleet)
    t0 = time.time()
    from . import campaign_bench
    sharing = campaign_bench.run_sharing_bench(
        workloads=["MI-OPT"] if quick else None,
        seeds=range(3) if quick else range(16),
        per_member=10 if quick else 15, verbose=False)
    dt = time.time() - t0
    shared = sharing["shared_total_median_paid"]
    isolated = sharing["isolated_total_median_paid"]
    _csv("sharing_campaign", 1e6 * dt / max(len(sharing["workloads"]), 1),
         f"shared_paid={shared};isolated_paid={isolated};"
         f"pass={sharing['pass']}")
    results["sharing"] = sharing

    # ---------------- roofline aggregation
    t0 = time.time()
    n_cells = 0
    for mesh in ("16x16", "2x16x16"):
        rows = roofline_bench.load_reports(mesh)
        n_cells += len(rows)
    dt = time.time() - t0
    _csv("roofline_aggregate", 1e6 * dt / max(n_cells, 1),
         f"cells={n_cells}")

    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"[benchmarks] results saved to {out}")


if __name__ == "__main__":
    main()
