"""Queue-soak: churn the lease-based priority queue under a supervised fleet.

The CI hardening step for the distributed work queue (paper §III-D): one
:class:`~repro.core.execution.fleet.FleetSupervisor` autoscaling a fleet of
queue workers (default max 2) while the investigator pushes wave after wave
of prioritized work items through the ``QueueBackend`` for a fixed wall-clock
budget (default 30 s).  Every wave injects a fault — a "ghost" worker claims
an item on a near-zero lease and goes silent; the supervisor's hygiene pass
must re-queue it and the fleet must redo it — and then checks the
conservation invariants:

* every submitted item completes (nothing lost, nothing stuck);
* the ghost's late ``finish_work`` is rejected (owner guard);
* the queue is empty after each drain and every result is ok;
* measurements happened exactly once per configuration (reuse thereafter).

Exit code 0 = all invariants held for the whole budget; any violation
asserts.  Run::

    PYTHONPATH=src python -m benchmarks.queue_soak --budget 30 --workers 2
"""

from __future__ import annotations

import argparse
import os
import tempfile
import threading
import time

import numpy as np

from repro.core import (ActionSpace, AutoscalePolicy, DiscoverySpace,
                        Dimension, FunctionExperiment, ProbabilitySpace,
                        SampleStore)
from repro.core.execution import WorkItem
from repro.core.execution.fleet import FleetSupervisor

__all__ = ["run_soak"]


def _soak_measure(c):
    time.sleep(0.001)
    return {"cost": (c["x"] - 0.5) ** 2 + 0.1 * c["y"]}


def _soak_ds(store_path: str) -> DiscoverySpace:
    space = ProbabilitySpace.make([
        Dimension.discrete("x", [round(v, 3) for v in np.linspace(-2, 2, 8)]),
        Dimension.discrete("y", list(range(4))),
    ])
    exp = FunctionExperiment(fn=_soak_measure, properties=("cost",),
                             name="soak")
    return DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                          store=SampleStore(store_path),
                          claim_timeout_s=30.0, lease_s=2.0)


def run_soak(budget_s: float = 30.0, workers: int = 2,
             step_timeout_s: float = 20.0, seed: int = 0,
             verbose: bool = True) -> dict:
    """Run the soak; returns the summary dict (asserts on any violation)."""
    rng = np.random.default_rng(seed)
    waves = ghosts_recovered = items_done = 0
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "soak.db")
        ds = _soak_ds(path)
        store = ds.store
        configs = list(ds.space.all_configurations())

        policy = AutoscalePolicy(min_workers=1, max_workers=max(1, workers),
                                 idle_retire_s=1.0)
        supervisor = FleetSupervisor(lambda: _soak_ds(path), policy=policy,
                                     claim_batch=2)
        stop = threading.Event()

        def supervise():
            while not stop.is_set():
                supervisor.step()
                stop.wait(0.05)

        thread = threading.Thread(target=supervise, daemon=True)
        thread.start()
        deadline = time.monotonic() + budget_s
        try:
            while time.monotonic() < deadline:
                waves += 1
                size = int(rng.integers(4, 12))
                idx = rng.choice(len(configs), size=size, replace=False)
                wave = [configs[i] for i in idx]
                priorities = [float(p) for p in rng.normal(size=size)]

                # fault injection: a ghost races the live fleet for a fresh
                # item, claims it on a near-zero lease, and goes silent; if
                # the ghost wins, the supervisor's hygiene pass must re-queue
                # the item and the fleet must redo it.  (If the fleet wins
                # the race the item just completes normally — either way it
                # must complete exactly once.)
                ghost_digest = store.put_configuration(wave[0])
                ghost_item = store.enqueue_work(ds.space_id, ghost_digest,
                                                priority=99.0)
                ghost = store.claim_work_batch("ghost", limit=1,
                                               space_id=ds.space_id,
                                               lease_s=0.05)
                ghost_won = bool(ghost) and ghost[0]["item_id"] == ghost_item

                engine = ds.execution_backend("queue")
                for i, (config, priority) in enumerate(zip(wave, priorities)):
                    store.put_configuration(config)
                    engine.submit(WorkItem(config, config.digest, i,
                                           priority=priority))
                results = engine.drain(timeout_s=step_timeout_s)

                # conservation: every submitted item came back ok, exactly once
                assert sorted(r.item.tag for r in results) == list(range(size))
                assert all(r.action in ("measured", "reused")
                           for r in results), [r.action for r in results]
                items_done += size

                # the injected item must complete — recovered from the ghost
                # or served by the fleet directly — and the ghost's zombie
                # finish must bounce off the owner guard
                t0 = time.monotonic()
                while not store.fetch_work_results([ghost_item]):
                    assert time.monotonic() - t0 < step_timeout_s, \
                        "ghost-claimed item was never recovered"
                    time.sleep(0.01)
                assert store.finish_work(ghost_item, "failed", "zombie",
                                         owner="ghost") is False
                if ghost_won:
                    ghosts_recovered += 1
                t0 = time.monotonic()
                while store.pending_work(ds.space_id):
                    assert time.monotonic() - t0 < step_timeout_s, \
                        "queue never drained after the wave"
                    time.sleep(0.01)
        finally:
            stop.set()
            thread.join(timeout=10.0)
            supervisor.stop()

        # measure-once held across every wave: exactly one landed value row
        # per (configuration, experiment) cell ever touched — workers raced
        # the same cells hundreds of times and never double-measured
        measured = int(store._rows(
            "SELECT COUNT(DISTINCT config_digest) FROM property_values")[0][0])
        doubled = store._rows(
            "SELECT config_digest, experiment_id, COUNT(*) FROM property_values"
            " GROUP BY config_digest, experiment_id HAVING COUNT(*) > 1")
        assert not doubled, f"double-measured cells: {doubled}"
        stats = store.work_queue_stats(ds.space_id)
        assert 0 < measured <= len(configs)
        assert stats["queued"] == 0 and stats["running"] == 0

    summary = {"budget_s": budget_s, "waves": waves,
               "work_items_done": items_done + ghosts_recovered,
               "ghosts_recovered": ghosts_recovered,
               "distinct_measured": measured,
               "fleet_processed": supervisor.processed,
               "max_workers": workers}
    if verbose:
        print(f"[soak] {waves} waves / {summary['work_items_done']} work items "
              f"in {budget_s:.0f}s budget; {ghosts_recovered} ghost claims "
              f"recovered; {measured} distinct configs measured exactly once; "
              f"fleet processed {supervisor.processed} items "
              f"(max {workers} workers, 1 supervisor)")
    return summary


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--budget", type=float, default=30.0,
                        help="wall-clock soak budget in seconds")
    parser.add_argument("--workers", type=int, default=2,
                        help="max fleet size under the supervisor")
    parser.add_argument("--step-timeout", type=float, default=20.0,
                        help="per-wave drain/recovery timeout in seconds")
    args = parser.parse_args(argv)
    summary = run_soak(budget_s=args.budget, workers=args.workers,
                       step_timeout_s=args.step_timeout)
    print(f"[soak] PASS: all queue invariants held for {summary['waves']} waves")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
