"""Actuation-lifecycle benchmark: trace replay at depth, priced honestly.

The phased actuation path (``repro.core.connector``) promises three things
this bench measures and gates, writing ``BENCH_actuation.json``:

* **replay** — recorded-trace replay throughput through the *full*
  ``sample -> store`` path (claims, records, failure rows, billed
  properties) on a fresh SQLite store: trials/s, plus the virtual-vs-wall
  compression ratio (hours of recorded actuation replayed in wall-clock
  seconds — the whole point of traces).  Acceptance: >= 50 trials/s.
* **overhead** — the lifecycle adapter's per-trial cost over calling the
  connector's four phases directly (retry bookkeeping, billing, teardown
  discipline).  Acceptance: < 2 ms/trial — the adapter must be noise next
  to any real cloud actuation.
* **billing** — exact failed-trial cost accounting: after the replay, the
  sum of every successful trial's ``provisioned_cost`` property plus every
  failed trial's billed failure cost must reconcile with the rate times
  the provisioned seconds recorded in the trace, to 1e-6 relative.
  Scout/Lynceus both charge failed trials; a drifting ledger here means
  the lifecycle dropped or double-billed a phase window.

``--quick`` is the CI mode (reduced trial count).  Run directly::

    PYTHONPATH=src python -m benchmarks.actuation_bench [--quick] [--out F]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

from repro.core import (ActionSpace, Configuration, Dimension, DiscoverySpace,
                        ProbabilitySpace, SampleStore)
from repro.core.clock import FakeClock
from repro.core.connector import (Deployment, ExperimentConnector,
                                  FlatPricing, LifecycleExperiment,
                                  RetryPolicy, TraceConnector, write_trace)

__all__ = ["run_bench", "main"]

RATE_PER_S = 0.01
PROVISION_S = 5.0
RUN_S = 10.0
TEARDOWN_S = 1.0
RETRY = {"provision_attempts": 3, "run_attempts": 1, "backoff_s": 1.0,
         "backoff_factor": 2.0, "max_backoff_s": 60.0, "jitter": 0.1}


def _synthesize_trace(path: str, n: int) -> tuple:
    """Deterministic n-trial trace: every 7th trial flakes provisioning
    once (retried at replay), every 20th never provisions (billed failure),
    the rest measure cleanly."""
    header = {"trace": "actuation-v1", "name": "bench-cloud", "version": "1",
              "params": {"region": "bench"}, "properties": ["m"],
              "retry": dict(RETRY),
              "pricing": {"kind": "flat", "rate_per_s": RATE_PER_S}}
    trials = []
    for i in range(n):
        config = {"i": i}
        digest = Configuration.make(config).digest
        if i % 20 == 0:
            attempts = [{"phase": "provision", "ok": False, "s": PROVISION_S,
                         "reason": "zone outage"} for _ in range(3)]
            props = None
        else:
            attempts = []
            if i % 7 == 0:
                attempts.append({"phase": "provision", "ok": False,
                                 "s": PROVISION_S,
                                 "reason": "insufficient capacity"})
            attempts += [{"phase": "provision", "ok": True, "s": PROVISION_S},
                         {"phase": "run", "ok": True, "s": RUN_S},
                         {"phase": "parse", "ok": True, "s": 0.0},
                         {"phase": "teardown", "ok": True, "s": TEARDOWN_S}]
            props = {"m": float(i)}
        trials.append({"config": config, "digest": digest,
                       "attempts": attempts, "properties": props})
    write_trace(path, header, trials)
    return header, trials


def bench_replay(path: str, n: int, workdir: str) -> dict:
    clock = FakeClock()
    connector = TraceConnector(path, clock=clock)
    experiment = LifecycleExperiment(
        connector, retry=RetryPolicy(**{**RETRY, "backoff_s": 0.0}),
        pricing=FlatPricing(rate_per_s=RATE_PER_S), clock=clock)
    ds = DiscoverySpace(
        space=ProbabilitySpace.make([Dimension.discrete("i", list(range(n)))]),
        actions=ActionSpace.make([experiment]),
        store=SampleStore(os.path.join(workdir, "replay.db")))
    configs = [Configuration.make({"i": i}) for i in range(n)]
    wall0, virt0 = time.perf_counter(), clock.time()
    results = ds.sample_batch(configs, operation_id="bench")
    wall = time.perf_counter() - wall0
    virtual = clock.time() - virt0
    failed = sum(1 for r in results if not r.ok)
    return {
        "trials": n,
        "failed_trials": failed,
        "wall_s": round(wall, 3),
        "trials_per_s": round(n / wall, 1),
        "virtual_hours_replayed": round(virtual / 3600.0, 3),
        "virtual_over_wall": round(virtual / max(wall, 1e-9), 1),
        "_ds": ds,  # stripped before serialization; billing bench reads it
    }


def bench_billing(ds: DiscoverySpace, trials: list) -> dict:
    """Reconcile the store's ledger against the trace's provisioned
    seconds (backoff waits are unbilled — you hold no instance while you
    wait to retry)."""
    expected = RATE_PER_S * sum(ev["s"] for t in trials
                                for ev in t["attempts"])
    measured_cost = 0.0
    for s in ds.read():
        for v in s.properties.values():
            if v.name == "provisioned_cost":
                measured_cost += v.value
    summary = ds.store.failure_summary(ds.space_id)
    failed_cost = sum(p["cost"] for p in summary.values())
    actual = measured_cost + failed_cost
    drift = abs(actual - expected) / max(expected, 1e-9)
    return {
        "expected_cost": round(expected, 6),
        "measured_trials_cost": round(measured_cost, 6),
        "failed_trials_cost": round(failed_cost, 6),
        "failures_by_phase": {k: v["count"] for k, v in summary.items()},
        "relative_drift": drift,
    }


class _InstantConnector(ExperimentConnector):
    name = "instant"
    version = "1"

    @property
    def parameterization(self):
        return {}

    @property
    def observed_properties(self):
        return ("m",)

    def provision(self, configuration):
        return Deployment(ident="i", configuration=configuration, handle="h")

    def run(self, deployment):
        return {"m": 1.0}


def bench_overhead(n: int) -> dict:
    """Lifecycle adapter vs calling the four phases directly."""
    clock = FakeClock()
    connector = _InstantConnector()
    experiment = LifecycleExperiment(
        connector, retry=RetryPolicy(**RETRY),
        pricing=FlatPricing(rate_per_s=RATE_PER_S), clock=clock)
    configs = [Configuration.make({"i": i}) for i in range(n)]

    t0 = time.perf_counter()
    for c in configs:
        experiment.measure(c)
    lifecycle_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    for c in configs:
        d = connector.provision(c)
        props = dict(connector.parse(connector.run(d)))
        connector.teardown(d)
        del props
    direct_s = time.perf_counter() - t0

    per_trial_us = (lifecycle_s - direct_s) / n * 1e6
    return {"trials": n,
            "lifecycle_us_per_trial": round(lifecycle_s / n * 1e6, 2),
            "direct_us_per_trial": round(direct_s / n * 1e6, 2),
            "overhead_us_per_trial": round(per_trial_us, 2)}


def run_bench(quick: bool = False) -> dict:
    n = 200 if quick else 2000
    overhead_n = 2000 if quick else 20_000
    workdir = tempfile.mkdtemp(prefix="actuation_bench_")
    trace_path = os.path.join(workdir, "trace.jsonl")
    _header, trials = _synthesize_trace(trace_path, n)

    replay = bench_replay(trace_path, n, workdir)
    ds = replay.pop("_ds")
    billing = bench_billing(ds, trials)
    overhead = bench_overhead(overhead_n)

    gates = {
        "replay_ge_50_trials_per_s": replay["trials_per_s"] >= 50.0,
        "lifecycle_overhead_under_2ms":
            overhead["overhead_us_per_trial"] < 2000.0,
        "billing_reconciles_1e-6":
            billing["relative_drift"] < 1e-6,
        "billing_relative_drift": billing["relative_drift"],
    }
    billing["relative_drift"] = round(billing["relative_drift"], 9)
    gates["billing_relative_drift"] = billing["relative_drift"]
    return {
        "generated_by": "benchmarks/actuation_bench.py",
        "mode": "quick" if quick else "full",
        "note": ("replay = recorded-trace replay through the full "
                 "sample->store path on FakeClock (zero real sleeps); "
                 "billing reconciles provisioned_cost properties + failure "
                 "rows against the trace's provisioned seconds."),
        "replay": replay,
        "overhead": overhead,
        "billing": billing,
        "gates": gates,
    }


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="CI mode: 200-trial trace, 2k-trial overhead "
                             "loop")
    parser.add_argument("--out", default="BENCH_actuation.json")
    args = parser.parse_args(argv)
    result = run_bench(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")
    failed = [name for name, ok in result["gates"].items()
              if isinstance(ok, bool) and not ok]
    if failed:
        print(f"GATE FAILURE: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
