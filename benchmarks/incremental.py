"""Paper Fig. 7: passive incremental sampling — % time saved by reusing
samples from a shared store across sequential optimization runs.

Scenario (as in the paper §V-C4): multiple researchers independently run
optimizations with different algorithms on the SAME Discovery Space, one
after the other, all against one common context.  The normalized cost of the
i-th run = new measurements / total samples; averaged over permutations of
the run order (legal because runs are independent — the Reconcilable
characteristic).
"""

from __future__ import annotations

import numpy as np

from repro.core import ActionSpace, DiscoverySpace, SampleStore
from repro.core.optimizers import OPTIMIZER_REGISTRY, run_optimizer

from .workloads import WORKLOADS

__all__ = ["run_fig7"]


def _simulate_sequence(space, exp, metric, mode, run_specs, rng):
    """Execute runs sequentially against one shared store; returns the
    per-run (measured, total) counts in execution order."""
    store = SampleStore(":memory:")
    counts = []
    for (oname, seed) in run_specs:
        ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                            store=store)
        opt = OPTIMIZER_REGISTRY[oname](seed=seed)
        run = run_optimizer(opt, ds, metric, mode, max_trials=80, patience=5,
                            rng=np.random.default_rng(seed * 7919 + 13))
        counts.append((run.num_measured, run.num_trials))
    return counts


def run_fig7(n_runs: int = 30, n_permutations: int = 20,
             checkpoints=(10, 20, 30), verbose: bool = True) -> dict:
    """% of measurement cost saved by run i (vs. a cold store), averaged over
    permutations of the run order.

    Full re-execution per permutation is expensive; like the paper we exploit
    run independence: execute each run once in isolation to get its trial
    sequence, then replay permutations against a simulated store (a set of
    visited configuration digests).
    """
    out = {}
    optimizers = list(OPTIMIZER_REGISTRY)
    for wname, factory in WORKLOADS.items():
        space, exp, metric, mode = factory()
        # trial sequences of each run in isolation
        sequences = []
        for i in range(n_runs):
            oname = optimizers[i % len(optimizers)]
            ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                                store=SampleStore(":memory:"))
            run = run_optimizer(OPTIMIZER_REGISTRY[oname](seed=i), ds, metric,
                                mode, max_trials=80, patience=5,
                                rng=np.random.default_rng(i * 31 + 5))
            sequences.append([t.configuration.digest for t in run.trials])

        rng = np.random.default_rng(123)
        cost_at_pos = np.zeros((n_permutations, n_runs))
        for p in range(n_permutations):
            order = rng.permutation(n_runs)
            seen: set = set()
            for pos, run_idx in enumerate(order):
                seq = sequences[run_idx]
                new = sum(1 for d in seq if d not in seen)
                seen.update(seq)
                cost_at_pos[p, pos] = new / max(len(seq), 1)
        mean_cost = cost_at_pos.mean(axis=0)
        savings = {f"after_{k}_runs": round(100 * (1 - mean_cost[k - 1]), 1)
                   for k in checkpoints if k <= n_runs}
        out[wname] = {"mean_cost_by_position": mean_cost.tolist(),
                      "savings_pct": savings}
        if verbose:
            print(f"[fig7] {wname}: % time saved {savings}")
    return out
