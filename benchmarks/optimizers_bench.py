"""Paper Table V + Fig. 6: optimizer comparison over workload spaces.

For each workload × optimizer: 10 runs with random starts and the paper's
stopping rule (no improvement in 5 trials).  Reports max/median trials,
best%/median best% (percentile of the space's CDF reached), and the
P(≥1 sample in the 95th percentile) vs N curve against the analytic
hypergeometric random-walk baseline.
"""

from __future__ import annotations

import numpy as np

from repro.core import ActionSpace, DiscoverySpace, SampleStore
from repro.core.optimizers import (OPTIMIZER_REGISTRY, hypergeom_p_found,
                                   run_optimizer)

from .workloads import WORKLOADS, exhaustive_values

__all__ = ["run_table_v", "run_fig6"]

OPTIMIZERS = ("bo-gp", "tpe", "bohb")


def _percentile_of(value: float, values: np.ndarray, mode: str = "min") -> float:
    """best%: fraction of the space this value beats (100 = global best)."""
    if mode == "min":
        return float((values > value).mean() * 100.0)
    return float((values < value).mean() * 100.0)


def run_table_v(n_runs: int = 10, max_trials: int = 120, patience: int = 5,
                verbose: bool = True) -> list:
    rows = []
    for wname, factory in WORKLOADS.items():
        space, exp, metric, mode = factory()
        _, truth = exhaustive_values(space, exp, metric)
        for oname in OPTIMIZERS:
            trials, bests = [], []
            for run_i in range(n_runs):
                ds = DiscoverySpace(space=space,
                                    actions=ActionSpace.make([exp]),
                                    store=SampleStore(":memory:"))
                opt = OPTIMIZER_REGISTRY[oname](seed=run_i)
                run = run_optimizer(opt, ds, metric, mode,
                                    max_trials=max_trials, patience=patience,
                                    rng=np.random.default_rng(1000 + run_i))
                trials.append(run.num_trials)
                bests.append(_percentile_of(run.best.value, truth, mode))
            row = {
                "test_case": wname, "optimizer": oname,
                "max_trials": int(np.max(trials)),
                "median_trials": float(np.median(trials)),
                "best_pct": round(float(np.max(bests)), 1),
                "median_pct": round(float(np.median(bests)), 1),
                "space_size": space.size,
            }
            rows.append(row)
            if verbose:
                print(f"[table-v] {wname:7s} {oname:6s} trials max/med "
                      f"{row['max_trials']}/{row['median_trials']:.1f} "
                      f"best%/med% {row['best_pct']}/{row['median_pct']}")
    return rows


def run_fig6(n_runs: int = 10, n_samples: int = 60, verbose: bool = True) -> dict:
    """P(found ≥1 config in 95th pctile) after N samples, per optimizer,
    plus the analytic hypergeometric random baseline."""
    out = {}
    for wname, factory in WORKLOADS.items():
        space, exp, metric, mode = factory()
        configs, truth = exhaustive_values(space, exp, metric)
        thresh = np.quantile(truth, 0.05 if mode == "min" else 0.95)
        target_digests = {
            c.digest for c, v in zip(configs, truth)
            if (v <= thresh if mode == "min" else v >= thresh)}
        curves = {}
        for oname in OPTIMIZERS:
            found_at = np.full((n_runs, n_samples), False)
            for run_i in range(n_runs):
                ds = DiscoverySpace(space=space,
                                    actions=ActionSpace.make([exp]),
                                    store=SampleStore(":memory:"))
                opt = OPTIMIZER_REGISTRY[oname](seed=50 + run_i)
                run = run_optimizer(opt, ds, metric, mode,
                                    max_trials=n_samples,
                                    patience=n_samples,  # run to N samples
                                    rng=np.random.default_rng(77 + run_i))
                hit = False
                for j, t in enumerate(run.trials[:n_samples]):
                    hit = hit or (t.configuration.digest in target_digests)
                    found_at[run_i, j] = hit
                found_at[run_i, len(run.trials):] = hit
            curves[oname] = found_at.mean(axis=0)
        curves["random"] = np.array([
            hypergeom_p_found(space.size, len(target_digests), n + 1)
            for n in range(n_samples)])
        out[wname] = curves
        if verbose:
            n_probe = min(n_samples, 30) - 1
            msg = " ".join(f"{k}={v[n_probe]:.2f}" for k, v in curves.items())
            print(f"[fig6] {wname}: P(hit 95th pct) @{n_probe + 1} samples: {msg}")
    return out
