"""Store hot-path benchmark: the §III-D rendezvous measured at depth.

Every subsystem rendezvouses through the shared sample store, so its write
and read hot paths bound the whole system's throughput.  This bench
measures four metric families on BOTH backends — the in-process SQLite
reference and the served store (an in-tree ``StoreServer`` over a unix
socket, so the numbers include real socket framing + msgpack round-trips)
— and writes ``BENCH_store.json``:

* **append** — sampling-record events/s: the per-row ``append_record``
  path (one correlated-MAX insert per row) vs the coalesced
  ``append_records`` batch path (one MAX + one ``executemany`` + one WAL
  commit per batch).  Acceptance: batched >= 3x per-row on the reference
  backend.  The served store additionally reports the pipelined per-row
  rate (N frames per round-trip) — the protocol's answer to slow links.
* **sync** — foreign-tell sync latency: ``consume_records_since`` of a
  128-row delta against 10⁴ and then 10⁶ *resident* records.  The
  watermark read is an indexed range scan, so the acceptance criterion is
  flatness: at-10⁶ within ±20% of at-10⁴.  (PR 5's cross-process
  investigation observed ~8 ms per sync through the filesystem — recorded
  here as ``baseline_cross_process_ms`` for continuity.)
* **claims** — work-queue throughput under 8 concurrent workers
  (claim_work_batch/finish_work_batch over a shared queue, batch 8):
  items/s partitioned with no double-claims.
* **catalog** — catalog-query latency at depth: ``space_stats`` (the
  SpaceCatalog's entry scan, covered by the ``rec_stats`` index) and
  ``measured_property_values`` over a well-sampled space (the transfer-
  source read).

``--quick`` is the CI mode: reduced depths (10⁴ resident records), plus a
soft regression gate — exit nonzero if the served backend's sync latency
exceeds 3x the in-process SQLite number (the served store's promise is
"one socket hop", so a blowout here means a protocol regression, not
noise).  The full run (default) builds the 10⁶-record store and also
enforces the two acceptance gates (batched >= 3x, sync flat ±20%).

Run directly::

    PYTHONPATH=src python -m benchmarks.store_bench [--quick] [--out F]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import statistics
import tempfile
import threading
import time

from repro.core import Configuration
from repro.core.entities import PropertyValue
from repro.core.store.client import ClientStore
from repro.core.store.server import StoreServer
from repro.core.store.sqlite import SampleStore

__all__ = ["run_bench", "main"]

SPACE = "bench-space"
APPEND_SPACE = "bench-append-space"  # own space: keeps SPACE's depth exact
OP = "bench-op"
DISTINCT_CONFIGS = 10_000   # resident distinct configurations at depth
SYNC_DELTA = 128            # new rows per measured sync
APPEND_BATCH = 512


def _median_ms(fn, repeats: int) -> float:
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e3)
    return statistics.median(samples)


def _configs(n: int) -> list:
    return [Configuration(values=(("size", i), ("tier", i % 7)))
            for i in range(n)]


def _fill_to_depth(store, digests, depth: int) -> None:
    """Grow the space's resident record to ``depth`` rows (batched)."""
    have = store.space_stats().get(SPACE, {}).get("records", 0)
    chunk = 20_000
    while have < depth:
        n = min(chunk, depth - have)
        store.append_records(
            SPACE, "op-resident",
            [(digests[(have + i) % len(digests)], "measured")
             for i in range(n)])
        have += n


# ------------------------------------------------------------------ families


def bench_append(store, per_row_n: int, batched_n: int,
                 pipelined: bool = False) -> dict:
    """Per-row vs batched append, interleaved in rounds so both paths see
    the same table-growth profile (B-tree depth, WAL checkpoint stalls) —
    timing one path on a small table and the other while growing it 50x
    would flatter whichever ran first."""
    digests = store.put_configurations(_configs(256))
    rounds = 10
    row_chunk, batch_chunk = per_row_n // rounds, batched_n // rounds
    per_row_s = batched_s = 0.0
    for _ in range(rounds):
        t0 = time.perf_counter()
        for i in range(row_chunk):
            store.append_record(APPEND_SPACE, f"{OP}-row", digests[i % 256],
                                "measured")
        per_row_s += time.perf_counter() - t0

        t0 = time.perf_counter()
        done = 0
        while done < batch_chunk:
            n = min(APPEND_BATCH, batch_chunk - done)
            store.append_records(APPEND_SPACE, f"{OP}-batch",
                                 [(digests[(done + i) % 256], "measured")
                                  for i in range(n)])
            done += n
        batched_s += time.perf_counter() - t0
    per_row_rps = rounds * row_chunk / per_row_s
    batched_rps = rounds * batch_chunk / batched_s

    out = {"per_row_rps": round(per_row_rps, 1),
           "batched_rps": round(batched_rps, 1),
           "batch_size": APPEND_BATCH,
           "speedup_batched_vs_per_row": round(batched_rps / per_row_rps, 2)}
    if pipelined and isinstance(store, ClientStore):
        # per-row appends, but N request frames per network round-trip
        t0 = time.perf_counter()
        done = 0
        while done < per_row_n:
            n = min(64, per_row_n - done)
            store._call_many([
                ("append_record",
                 [APPEND_SPACE, f"{OP}-pipe", digests[(done + i) % 256],
                  "measured"])
                for i in range(n)])
            done += n
        out["pipelined_per_row_rps"] = round(
            per_row_n / (time.perf_counter() - t0), 1)
    return out


def bench_sync(store, digests, repeats: int) -> float:
    """Median ms to sync a SYNC_DELTA-row delta at the current depth."""
    def one_sync():
        watermark = store.last_record_rowid(SPACE)
        store.append_records(SPACE, "op-writer",
                             [(digests[i % len(digests)], "measured")
                              for i in range(SYNC_DELTA)])
        t0 = time.perf_counter()
        records, new_mark = store.consume_records_since(SPACE, watermark)
        assert len(records) == SYNC_DELTA and new_mark > watermark
        return (time.perf_counter() - t0) * 1e3

    for _ in range(5):
        one_sync()  # warmup: page in the index tail after a bulk fill
    samples = [one_sync() for _ in range(repeats)]
    return round(statistics.median(samples), 3)


def bench_claims(store, n_items: int, workers: int = 8,
                 claim_batch: int = 8) -> dict:
    digests = store.put_configurations(_configs(min(n_items, 1024)))
    for i in range(n_items):
        store.enqueue_work(SPACE, digests[i % len(digests)],
                           priority=float(i % 13))
    finished = []
    lock = threading.Lock()
    barrier = threading.Barrier(workers + 1)

    def worker(name):
        barrier.wait()
        mine = 0
        while True:
            batch = store.claim_work_batch(name, limit=claim_batch,
                                           space_id=SPACE, lease_s=300.0)
            if not batch:
                break
            store.finish_work_batch(
                [(c["item_id"], "measured", None) for c in batch],
                owner=name)
            mine += len(batch)
        with lock:
            finished.append(mine)

    threads = [threading.Thread(target=worker, args=(f"w{i}",))
               for i in range(workers)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    assert sum(finished) == n_items, "queue items lost or double-counted"
    return {"workers": workers, "claim_batch": claim_batch,
            "items": n_items, "items_per_s": round(n_items / elapsed, 1)}


def bench_catalog(store, digests, repeats: int) -> dict:
    # a measured property over a slice of the space: the transfer-source read
    sample = digests[:500]
    store.append_records(SPACE, "op-catalog",
                         [(digest, "measured") for digest in sample])
    for i, digest in enumerate(sample):
        store.put_values(digest, [PropertyValue(
            name="cost", value=float(i), experiment_id="exp-bench",
            predicted=False, timestamp=0.0)])
    stats_ms = _median_ms(store.space_stats, repeats)

    def read_pairs():
        store.invalidate_config_cache()  # cold decode, the honest number
        pairs = store.measured_property_values(SPACE, "cost")
        assert len(pairs) >= len(sample)

    pairs_ms = _median_ms(read_pairs, max(3, repeats // 3))
    return {"space_stats_ms": round(stats_ms, 3),
            "measured_property_values_ms": round(pairs_ms, 3),
            "measured_digests": len(sample)}


# ------------------------------------------------------------------- driver


def _bench_backend(store, depths, quick: bool, pipelined: bool) -> dict:
    digests = store.put_configurations(_configs(DISTINCT_CONFIGS))
    append = bench_append(store,
                          per_row_n=500 if quick else 2_000,
                          batched_n=20_000 if quick else 100_000,
                          pipelined=pipelined)
    sync = {}
    repeats = 20 if quick else 40
    for depth in depths:
        _fill_to_depth(store, digests, depth)
        sync[f"at_{depth}"] = {
            "resident_records": depth,
            "sync_ms": bench_sync(store, digests, repeats),
            "delta_rows": SYNC_DELTA,
        }
    claims = bench_claims(store, n_items=800 if quick else 4_000)
    catalog = bench_catalog(store, digests, repeats)
    return {"append": append, "sync": sync, "claims": claims,
            "catalog": catalog}


def run_bench(quick: bool = False, workdir: str = None) -> dict:
    depths = [10_000, 1_000_000] if not quick else [10_000]
    owns_dir = workdir is None
    workdir = workdir or tempfile.mkdtemp(prefix="store_bench_")
    try:
        sqlite_store = SampleStore(os.path.join(workdir, "ref.db"))
        sqlite_result = _bench_backend(sqlite_store, depths, quick,
                                       pipelined=False)
        sqlite_store.close()

        server = StoreServer(
            SampleStore(os.path.join(workdir, "served.db")),
            unix_path=os.path.join(workdir, "served.sock")).start()
        client = ClientStore(server.url)
        server_result = _bench_backend(client, depths, quick, pipelined=True)
        client.close()
        server.shutdown()
    finally:
        if owns_dir:
            shutil.rmtree(workdir, ignore_errors=True)

    deep, shallow = f"at_{depths[-1]}", f"at_{depths[0]}"
    sync_flat_ratio = (sqlite_result["sync"][deep]["sync_ms"]
                       / max(sqlite_result["sync"][shallow]["sync_ms"], 1e-9))
    server_sync_ms = server_result["sync"][deep]["sync_ms"]
    server_sync_ratio = (server_sync_ms
                         / max(sqlite_result["sync"][deep]["sync_ms"], 1e-9))
    gates = {
        # served store = one socket hop: within 3x of in-process, OR under
        # an absolute 2 ms — both syncs are sub-millisecond, so the pure
        # ratio flaps with timer noise while a real protocol regression
        # (an extra round-trip, a lost pipelining path) adds milliseconds.
        # Either way it stays far below the 8 ms filesystem rendezvous.
        "server_sync_within_3x": (server_sync_ratio <= 3.0
                                  or server_sync_ms <= 2.0),
        "server_sync_ratio_vs_sqlite": round(server_sync_ratio, 2),
        # batch coalescing must actually pay (acceptance: >= 3x)
        "batched_append_speedup": sqlite_result["append"][
            "speedup_batched_vs_per_row"],
        "batched_append_ge_3x": sqlite_result["append"][
            "speedup_batched_vs_per_row"] >= 3.0,
    }
    if not quick:
        # flatness across 10⁴ -> 10⁶ resident records (acceptance: ±20%)
        gates["sync_flat_ratio_1e6_vs_1e4"] = round(sync_flat_ratio, 3)
        gates["sync_flat_within_20pct"] = 0.8 <= sync_flat_ratio <= 1.2

    return {
        "generated_by": "benchmarks/store_bench.py",
        "mode": "quick" if quick else "full",
        "max_resident_records": depths[-1],
        "baseline_cross_process_ms": 8.0,  # PR 5's observed sync latency
        "note": ("sqlite = in-process reference backend; server = StoreServer"
                 " over a unix socket via ClientStore (msgpack frames)."),
        "sqlite": sqlite_result,
        "server": server_result,
        "gates": gates,
    }


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="CI mode: 10⁴-record depth + the 3x served-sync "
                             "soft gate")
    parser.add_argument("--out", default="BENCH_store.json")
    args = parser.parse_args(argv)
    result = run_bench(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")
    failed = [name for name, ok in result["gates"].items()
              if isinstance(ok, bool) and not ok]
    if failed:
        print(f"GATE FAILURE: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
