"""SLA-constrained multi-objective benchmark: constrained search vs
unconstrained-then-post-filter.

The paper's headline use case (abstract: "minimal cost while meeting a
defined service level agreement") over the Table-III workload surfaces:
each workload gains a synthetic *provisioning cost* property shaped so the
cheapest configurations are exactly the ones that violate a latency SLA —
a cost-only search is actively steered toward SLA violators.

Two arms per workload, same optimizer family (BO-GP), seed, and budget:

* **constrained** — an :class:`~repro.core.api.investigation.Investigation`
  with ``objective.constraints = [latency <= bound]``: feasibility-weighted
  EI acquisition, infeasible trials excluded from the incumbent.
* **unconstrained+post-filter** — minimize cost with no constraint, then
  post-hoc discard trials whose ground-truth latency violates the bound
  (the workflow the objective DSL replaces).

Metric: *paid measurements* (measured + failed deployments) until the first
feasible trial at/below the top-decile feasible cost of the exhaustive
ground truth (the best-known-feasible-cost threshold — the strict minimum
sits on the SLA boundary under measurement jitter, so the decile quantile
plays the role transfer_bench's top-quantile threshold does); median over a
seed set.  Both arms are additionally scored
with the hypervolume of their measured (cost, latency) points over paid
measurements — the multi-objective coverage the store's Pareto ``frontier``
view exposes — and the constrained arm's store frontier is read back
through :meth:`~repro.core.store.base.StoreBackend.frontier`.

Run directly::

    PYTHONPATH=src python -m benchmarks.moo_bench [--quick] [--out F]

``--quick`` is the CI smoke mode (fewer seeds/trials); either mode writes
the full result set to ``BENCH_moo.json``.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (ActionSpace, DiscoverySpace, FunctionExperiment,
                        Investigation, MeasurementError, SampleStore)
from repro.core.api.spec import ConstraintSpec, ObjectiveSpec
from repro.core.optimizers import OPTIMIZER_REGISTRY
from repro.core.pareto import hypervolume

from .workloads import make_mi_opt, make_si_opt, make_tp_opt

__all__ = ["run_moo_bench", "SLA_WORKLOADS"]

COST = "cost_per_h"


def _sla_tp_opt():
    """TP-OPT + per-hour cluster price: small/slow clusters are cheapest
    and sit on the spill/parallelism penalty — they miss any runtime SLA."""
    space, exp, metric, _ = make_tp_opt()

    def fn(c):
        out = dict(exp.measure(c))
        out[COST] = c["executors"] * (0.05 * c["cores_per_exec"]
                                      + 0.012 * c["mem_gb"])
        return out

    return {"name": "TP-OPT", "space": space, "latency": metric,
            "quantile": 0.35,
            "exp": FunctionExperiment(fn=fn, properties=(metric, COST),
                                      name="tpcds-sla")}


def _sla_si_opt():
    """SI-OPT + GPU-tier instance price: a single T4 is the cheapest
    deployment and the slowest — p95 SLAs need bigger silicon."""
    space, exp, metric, _ = make_si_opt()
    price = {"A100-PCIE-40GB": 3.0, "V100-PCIE-16GB": 1.8, "Tesla-T4": 0.6}

    def fn(c):
        out = dict(exp.measure(c))
        out[COST] = (price[c["gpu_model"]] * c["num_gpus"]
                     + 0.02 * c["cpu_cores"] + 0.004 * c["memory_gi"])
        return out

    return {"name": "SI-OPT", "space": space, "latency": metric,
            "quantile": 0.35,
            "exp": FunctionExperiment(fn=fn, properties=(metric, COST),
                                      name="tgi-single-sla")}


def _sla_mi_opt():
    """MI-OPT + provisioned-capacity price (batch/concurrency/sequence
    capacity drives instance sizing): low-capacity serving is cheap but
    slow, and the OOM cliff makes some big configs non-deployable."""
    space, exp, metric, _ = make_mi_opt()

    def fn(c):
        out = dict(exp.measure(c))  # raises MeasurementError on the cliff
        out[COST] = (0.20 * np.log2(c["max_batch"])
                     + 0.10 * np.log2(c["max_concurrent"] / 32)
                     + 0.15 * np.log2(c["max_seq"] / 512)
                     + 0.10 * (c["max_new_tokens"] / 512)
                     + (0.25 if c["flash_attention"] else 0.0))
        return out

    return {"name": "MI-OPT", "space": space, "latency": metric,
            "quantile": 0.30,
            "exp": FunctionExperiment(fn=fn, properties=(metric, COST),
                                      name="tgi-multi-sla")}


SLA_WORKLOADS = {
    "TP-OPT": _sla_tp_opt,
    "SI-OPT": _sla_si_opt,
    "MI-OPT": _sla_mi_opt,
}


def _ground_truth(wl: dict, goal_quantile: float = 0.10) -> dict:
    """Exhaustive (cost, latency) per deployable digest + the SLA bound
    (latency quantile), best-known feasible cost, and the goal threshold
    (``goal_quantile`` of the feasible cost distribution)."""
    truth = {}
    for c in wl["space"].all_configurations():
        try:
            out = wl["exp"].measure(c)
        except MeasurementError:
            continue
        truth[c.digest] = (float(out[COST]), float(out[wl["latency"]]))
    lats = np.array([v[1] for v in truth.values()])
    bound = float(np.quantile(lats, wl["quantile"]))
    feas = [cost for cost, lat in truth.values() if lat <= bound]
    return {"truth": truth, "bound": bound,
            "best_feasible_cost": float(min(feas)),
            "goal_cost": float(np.quantile(feas, goal_quantile)),
            "cheapest_cost": float(min(c for c, _ in truth.values())),
            "feasible_fraction": len(feas) / len(truth)}


def _run_arm(wl: dict, gt: dict, seed: int, trials: int,
             constrained: bool):
    store = SampleStore(":memory:")
    ds = DiscoverySpace(space=wl["space"],
                        actions=ActionSpace.make([wl["exp"]]), store=store)
    objective = None
    if constrained:
        objective = ObjectiveSpec(constraints=(
            ConstraintSpec(wl["latency"], "<=", gt["bound"]),))
    inv = Investigation.from_components(
        ds, [OPTIMIZER_REGISTRY["bo-gp"](seed=seed)], COST, mode="min",
        max_trials=trials, patience=trials + 1, backend="serial",
        objective=objective, name="moo-bench")
    return inv.run(), ds


def _score(result, gt: dict, budget: int):
    """(paid-to-goal, hypervolume-over-paid) for one run, judged against
    ground truth so both arms face the same post-filter."""
    goal = gt["goal_cost"]
    ref = (max(c for c, _ in gt["truth"].values()) * 1.05,
           max(l for _, l in gt["truth"].values()) * 1.05)
    paid, paid_to_goal, points, hv = 0, budget + 1, [], []
    for _, t in result.events:
        if t.action not in ("measured", "failed"):
            continue
        paid += 1
        pt = gt["truth"].get(t.configuration.digest)
        if pt is not None and t.action == "measured":
            points.append(pt)
            if pt[1] <= gt["bound"] and pt[0] <= goal \
                    and paid_to_goal > budget:
                paid_to_goal = paid
        hv.append(hypervolume(points, ref))
    return paid_to_goal, hv


def run_moo_bench(workloads=None, seeds=range(8), trials: int = 50,
                  verbose: bool = True) -> dict:
    """Constrained-vs-post-filter ablation over a seed set (module
    docstring).  Reported per workload: median paid measurements to the
    best-known feasible cost for each arm, the win flag, final-hypervolume
    medians, and the size of the constrained store's Pareto frontier."""
    workloads = workloads if workloads is not None else list(SLA_WORKLOADS)
    out = {"trials_per_run": trials, "seeds": list(seeds),
           "optimizer": "bo-gp", "cost_property": COST, "workloads": {}}
    for wname in workloads:
        wl = SLA_WORKLOADS[wname]()
        gt = _ground_truth(wl)
        arms = {"constrained": [], "unconstrained_postfilter": []}
        hv_final = {k: [] for k in arms}
        hv_curve, frontier_size = None, None
        for seed in seeds:
            for constrained, arm in ((True, "constrained"),
                                     (False, "unconstrained_postfilter")):
                res, ds = _run_arm(wl, gt, seed, trials, constrained)
                paid_to_goal, hv = _score(res, gt, trials)
                arms[arm].append(paid_to_goal)
                hv_final[arm].append(hv[-1] if hv else 0.0)
                if constrained and hv_curve is None:
                    hv_curve = [round(v, 4) for v in hv]
                    frontier_size = len(ds.store.frontier(
                        ds.space_id, [COST, wl["latency"]]))
        medians = {arm: float(np.median(v)) for arm, v in arms.items()}
        row = {
            "latency_property": wl["latency"],
            "sla_bound": round(gt["bound"], 3),
            "space_size": wl["space"].size,
            "feasible_fraction": round(gt["feasible_fraction"], 3),
            "best_feasible_cost": round(gt["best_feasible_cost"], 4),
            "goal_cost": round(gt["goal_cost"], 4),
            "cheapest_cost_overall": round(gt["cheapest_cost"], 4),
            "median_paid_to_feasible_best": medians,
            "per_seed": {k: list(map(int, v)) for k, v in arms.items()},
            "constrained_wins":
                medians["constrained"] < medians["unconstrained_postfilter"],
            "hypervolume_final_median": {
                k: round(float(np.median(v)), 4) for k, v in hv_final.items()},
            "hypervolume_curve_seed0_constrained": hv_curve,
            "store_frontier_size": frontier_size,
        }
        out["workloads"][wname] = row
        if verbose:
            print(f"[moo] {wname}: SLA {wl['latency']} <= "
                  f"{row['sla_bound']} (feasible "
                  f"{row['feasible_fraction']:.0%}); paid-to-feasible-best "
                  f"median: constrained {medians['constrained']:.1f} vs "
                  f"post-filter {medians['unconstrained_postfilter']:.1f}; "
                  f"frontier {frontier_size} point(s)")
    rows = list(out["workloads"].values())
    out["workloads_won"] = sum(1 for r in rows if r["constrained_wins"])
    # the acceptance claim: constrained BO-GP reaches the best-known
    # feasible cost in fewer paid measurements than unconstrained search
    # plus post-hoc filtering on at least two of the three workloads
    out["pass"] = out["workloads_won"] >= min(2, len(rows))
    return out


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke mode: fewer seeds and trials")
    parser.add_argument("--out", default="BENCH_moo.json",
                        help="JSON artifact path (default: %(default)s)")
    args = parser.parse_args(argv)

    t0 = time.perf_counter()
    if args.quick:
        result = run_moo_bench(seeds=range(3), trials=40)
    else:
        result = run_moo_bench()
    result["mode_flag"] = "quick" if args.quick else "full"
    result["wall_s"] = round(time.perf_counter() - t0, 1)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"[moo] wrote {args.out} in {result['wall_s']}s: "
          f"{'PASS' if result['pass'] else 'FAIL'} "
          f"({result['workloads_won']}/{len(result['workloads'])} "
          f"workloads won)")
    return 0 if result["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
