"""Parallel-engine benchmark: batched vs pipelined vs priority-scheduled.

Validates four claims of the execution subsystem (paper §III-D —
distributed investigation through one shared sample store):

* **equivalence** — for a fixed seed, the 4-worker run produces a
  byte-identical reconciled sample set (and identical sampling record) to
  the serial run: parallelism changes wall-clock, never results;
* **speedup** — with a simulated measurement latency of ≥10 ms per
  experiment (cloud deployments are seconds-to-minutes; 10 ms keeps the
  bench quick), 4 workers deliver ≥2× wall-clock improvement;
* **pipelining** — on *heterogeneous* (mixed-duration) experiments the
  pipelined engine (``max_inflight=N`` over the process-isolated backend)
  beats the barrier-synchronized batch engine on wall-clock, because a
  straggling slow experiment never stalls the next ask (Lynceus-style
  trial dispatch);
* **priority scheduling** — on the same heterogeneous workload, a
  ``QueueBackend`` fleet popping acquisition-scored work items best-first
  reaches the best-cost configuration in fewer measured experiments than
  the FIFO queue (time-to-best-cost, the Lynceus early-convergence claim);
  written to a separate ``BENCH_queue.json`` artifact together with the
  measured store-rendezvous overhead of a real out-of-process worker.

Run directly::

    PYTHONPATH=src python -m benchmarks.parallel_bench [--quick] [--out F]

``--quick`` is the CI smoke mode: fewer trials/attempts, and the gate
relaxes to "pipelined throughput ≥ serial".  Either mode writes the full
result set to a ``BENCH_parallel.json`` artifact (plus ``BENCH_queue.json``
for the scheduling bench).  Via the harness (``benchmarks.run``) the
equivalence bench prints the CSV row
``CSV,parallel_engine,<us_per_trial>,speedup=<x>;identical=<bool>``.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import tempfile
import threading
import time

import numpy as np

from repro.core import (ActionSpace, DiscoverySpace, Dimension,
                        FunctionExperiment, ProbabilitySpace, SampleStore)
from repro.core.entities import canonical_json, content_hash
from repro.core.execution import WorkItem
from repro.core.execution.worker import run_worker
from repro.core.optimizers import OPTIMIZER_REGISTRY, run_optimizer
from repro.core.optimizers.tpe import tpe_score

__all__ = ["run_parallel_bench", "run_pipelined_bench",
           "run_queue_priority_bench", "reconciled_digest"]

MEASURE_LATENCY_S = 0.010  # simulated deployment+measurement cost
# heterogeneous workload: per-tier latency multipliers (cloud reality — a
# spot instance cold-start next to a warm dedicated box)
HETERO_TIERS = {"fast": 1.0, "medium": 2.0, "slow": 10.0}


def _space(n=12):
    vals = [round(v, 3) for v in np.linspace(-2, 2, n)]
    return ProbabilitySpace.make([
        Dimension.discrete("cpu_request", vals),
        Dimension.discrete("memory_gb", vals),
        Dimension.categorical("instance", ["spot", "dedicated"]),
    ])


def _experiment(latency_s: float = MEASURE_LATENCY_S) -> FunctionExperiment:
    def measure(c):
        time.sleep(latency_s)  # the deploy-and-benchmark cost
        penalty = 0.0 if c["instance"] == "spot" else 0.6
        return {"cost": (c["cpu_request"] - 0.5) ** 2
                + (c["memory_gb"] + 0.5) ** 2 + penalty}
    return FunctionExperiment(fn=measure, properties=("cost",), name="deploy")


def reconciled_digest(ds: DiscoverySpace) -> str:
    """Content hash of the reconciled sample set {x}, excluding timestamps:
    two runs with this digest equal hold byte-identical sample data."""
    payload = sorted(
        (s.configuration.digest,
         sorted((v.name, v.value, v.experiment_id, v.predicted)
                for v in s.properties.values()))
        for s in ds.read()
    )
    return content_hash(payload)


def _one_run(workers: int, optimizer: str, batch_size: int, max_trials: int,
             latency_s: float, seed: int):
    ds = DiscoverySpace(space=_space(), actions=ActionSpace.make(
        [_experiment(latency_s)]), store=SampleStore(":memory:"))
    t0 = time.perf_counter()
    run = run_optimizer(OPTIMIZER_REGISTRY[optimizer](seed=seed), ds, "cost",
                        "min", max_trials=max_trials, patience=max_trials + 1,
                        rng=np.random.default_rng(seed),
                        batch_size=batch_size, workers=workers)
    wall = time.perf_counter() - t0
    record = canonical_json([
        (r.seq, r.config_digest, r.action)
        for r in ds.timeseries(run.operation_id)])
    return {
        "workers": workers,
        "wall_s": wall,
        "trials": run.num_trials,
        "measured": run.num_measured,
        "sample_set_digest": reconciled_digest(ds),
        "record_digest": content_hash(record),
        "best": run.best.value if run.best else None,
    }


def run_parallel_bench(optimizer: str = "random", batch_size: int = 8,
                       max_trials: int = 48, workers: int = 4,
                       latency_s: float = MEASURE_LATENCY_S,
                       seed: int = 0, attempts: int = 3,
                       verbose: bool = True) -> dict:
    serial = _one_run(1, optimizer, batch_size, max_trials, latency_s, seed)

    # Result equivalence must hold on EVERY attempt; the wall-clock gate is
    # best-of-N (timing on a shared machine is load-sensitive, results are
    # not allowed to be).
    identical = True
    speedup = 0.0
    parallel = None
    for _ in range(max(1, attempts)):
        attempt = _one_run(workers, optimizer, batch_size, max_trials,
                           latency_s, seed)
        identical &= (
            serial["sample_set_digest"] == attempt["sample_set_digest"]
            and serial["record_digest"] == attempt["record_digest"])
        ratio = serial["wall_s"] / max(attempt["wall_s"], 1e-9)
        if parallel is None or ratio > speedup:
            parallel, speedup = attempt, ratio
        if not identical or speedup >= 2.0:
            break
    out = {
        "optimizer": optimizer,
        "batch_size": batch_size,
        "trials": serial["trials"],
        "latency_ms": latency_s * 1e3,
        "serial_s": round(serial["wall_s"], 3),
        "parallel_s": round(parallel["wall_s"], 3),
        "workers": workers,
        "speedup": round(speedup, 2),
        "identical_sample_set": identical,
        "best": serial["best"],
    }
    if verbose:
        print(f"[parallel] {optimizer} batch={batch_size} "
              f"trials={out['trials']} latency={out['latency_ms']:.0f}ms: "
              f"serial {out['serial_s']}s vs {workers}w {out['parallel_s']}s "
              f"=> {out['speedup']}x, identical={identical}")
    return out


# ------------------------------------------------ pipelined vs batch engine


def _hetero_measure(c, base_s):
    """Module-level (picklable / fork-safe) heterogeneous experiment."""
    time.sleep(base_s * HETERO_TIERS[c["tier"]])
    penalty = {"fast": 0.0, "medium": 0.3, "slow": 0.6}[c["tier"]]
    return {"cost": (c["cpu_request"] - 0.5) ** 2 + penalty}


def _hetero_ds(store, base_s):
    space = ProbabilitySpace.make([
        Dimension.discrete("cpu_request", [round(v, 3) for v in np.linspace(-2, 2, 8)]),
        Dimension.categorical("tier", list(HETERO_TIERS)),
    ])
    exp = FunctionExperiment(fn=functools.partial(_hetero_measure, base_s=base_s),
                             properties=("cost",), name="hetero-deploy")
    return DiscoverySpace(space=space, actions=ActionSpace.make([exp]), store=store)


def _engine_run(engine: str, workers: int, max_trials: int, base_s: float,
                seed: int, store_dir: str) -> float:
    """One full-space search under the given engine; returns wall seconds.

    All engines exhaust the same finite space (identical total measurement
    work), so wall-clock differences are pure scheduling: barrier stalls for
    the batch engine, straggler overlap for the pipelined one.
    """
    store = SampleStore(os.path.join(store_dir, f"{engine}-{seed}.db"))
    ds = _hetero_ds(store, base_s)
    opt = OPTIMIZER_REGISTRY["random"](seed=seed)
    kwargs = dict(max_trials=max_trials, patience=max_trials + 1,
                  rng=np.random.default_rng(seed))
    t0 = time.perf_counter()
    if engine == "serial":
        run = run_optimizer(opt, ds, "cost", "min", **kwargs)
    elif engine == "batch":
        run = run_optimizer(opt, ds, "cost", "min", batch_size=workers,
                            workers=workers, **kwargs)
    elif engine == "pipelined":
        run = run_optimizer(opt, ds, "cost", "min", max_inflight=workers,
                            backend="process", **kwargs)
    else:  # pragma: no cover - caller bug
        raise ValueError(engine)
    wall = time.perf_counter() - t0
    assert run.num_trials == max_trials, (engine, run.num_trials)
    store.close()
    return wall


def run_pipelined_bench(workers: int = 4, max_trials: int = 24,
                        base_latency_s: float = 2 * MEASURE_LATENCY_S,
                        seed: int = 0, attempts: int = 3,
                        verbose: bool = True) -> dict:
    """Pipelined-vs-batch on heterogeneous experiments (best of N attempts).

    ``max_trials`` defaults to |Ω| (8 cpu values × 3 tiers = 24) so every
    engine exhausts the space — identical measurement work regardless of
    tell order; latency tiers span 1×–10× the base.
    """
    best = None
    for attempt in range(max(1, attempts)):
        with tempfile.TemporaryDirectory() as d:
            walls = {e: _engine_run(e, workers, max_trials, base_latency_s,
                                    seed, d)
                     for e in ("serial", "batch", "pipelined")}
        out = {
            "workers": workers,
            "trials": max_trials,
            "base_latency_ms": base_latency_s * 1e3,
            "tiers": HETERO_TIERS,
            "serial_s": round(walls["serial"], 3),
            "batch_s": round(walls["batch"], 3),
            "pipelined_s": round(walls["pipelined"], 3),
            "speedup_vs_serial": round(walls["serial"] / max(walls["pipelined"], 1e-9), 2),
            "speedup_vs_batch": round(walls["batch"] / max(walls["pipelined"], 1e-9), 2),
            "attempt": attempt + 1,
        }
        if best is None or out["speedup_vs_batch"] > best["speedup_vs_batch"]:
            best = out
        if best["speedup_vs_batch"] > 1.0 and best["speedup_vs_serial"] > 1.0:
            break
    if verbose:
        print(f"[pipelined] hetero {best['trials']} trials x "
              f"{best['base_latency_ms']:.0f}ms(1-10x) {workers}w: "
              f"serial {best['serial_s']}s, batch {best['batch_s']}s, "
              f"pipelined {best['pipelined_s']}s => "
              f"{best['speedup_vs_batch']}x vs batch, "
              f"{best['speedup_vs_serial']}x vs serial")
    return best


# ------------------------------------------ priority-vs-FIFO queue scheduling


def _one_queue_run(prioritized: bool, warmup: int, base_s: float, seed: int,
                   store_dir: str) -> dict:
    """One QueueBackend drain of the heterogeneous space by a single worker.

    Warm up with ``warmup`` serially-measured configurations, score the
    remaining pool with a TPE acquisition fit on the warmup history, enqueue
    the whole pool (scores as priorities, or flat for FIFO), and let one
    worker loop drain it.  Returns the claim-order trace and the 1-based
    number of measured experiments until the best-cost configuration —
    deterministic for a fixed seed: one worker, one pop order.
    """
    mode = "priority" if prioritized else "fifo"
    store = SampleStore(os.path.join(store_dir, f"queue-{mode}-{seed}.db"))
    ds = _hetero_ds(store, base_s)
    rng = np.random.default_rng(seed)
    pool = list(ds.space.all_configurations())
    warm_idx = rng.choice(len(pool), size=warmup, replace=False)
    warm = [pool[i] for i in warm_idx]
    warm_results = ds.sample_batch(warm, operation_id="warmup")
    values = np.array([r.sample.value("cost") for r in warm_results])

    # the acquisition model: TPE good/bad split over the warmup history
    order = np.argsort(values)
    n_good = max(1, int(np.ceil(0.3 * len(values))))
    good = [warm[i] for i in order[:n_good]]
    bad = [warm[i] for i in order[n_good:]] or good
    remaining = [c for c in pool
                 if c.digest not in {w.digest for w in warm}]
    scores = tpe_score(ds.space, good, bad, remaining)

    engine = ds.execution_backend("queue")
    for i, config in enumerate(remaining):
        store.put_configuration(config)
        engine.submit(WorkItem(config, config.digest, i,
                               priority=float(scores[i]) if prioritized else 0.0))
    worker = threading.Thread(
        target=run_worker, args=(_hetero_ds(SampleStore(store.path), base_s),),
        kwargs={"idle_timeout_s": 1.0})
    t0 = time.perf_counter()
    worker.start()
    results = engine.drain(timeout_s=120.0)
    wall = time.perf_counter() - t0
    worker.join()
    assert len(results) == len(remaining)

    def measured_cost(digest: str) -> float:
        return [v.value for v in store.get_values(digest)
                if v.name == "cost"][0]

    best_digest = min((c.digest for c in remaining), key=measured_cost)
    claimed = [row[0] for row in store._rows(
        "SELECT config_digest FROM work_items"
        " WHERE status='done' AND claimed_at IS NOT NULL"
        " ORDER BY claimed_at, rowid")]
    time_to_best = claimed.index(best_digest) + 1
    store.close()
    return {"mode": mode, "pool": len(remaining), "warmup": warmup,
            "time_to_best": time_to_best, "wall_s": round(wall, 3)}


def _rendezvous_overhead(base_s: float, n_items: int, seed: int,
                         store_dir: str) -> dict:
    """Size the store-rendezvous cost honestly: drain ``n_items`` through a
    real out-of-process CLI worker (process boundary + database file — the
    closest a single host gets to the cross-host §III-D deployment) and
    report per-item overhead over the ideal serial measurement time.  The
    number includes the worker's interpreter cold start amortized over the
    items — exactly the cost a late-joining remote worker pays in practice
    (on a networked filesystem, add its round-trip latency on top)."""
    import subprocess
    import sys
    path = os.path.join(store_dir, f"rendezvous-{seed}.db")
    store = SampleStore(path)
    ds = _hetero_ds(store, base_s)
    configs = list(ds.space.all_configurations())[:n_items]
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        [os.path.join(root, "src"), here,
         os.environ.get("PYTHONPATH", "")]))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.core.execution.worker",
         "--store", path, "--factory", "parallel_bench:_rendezvous_factory",
         "--idle-timeout", "10", "--claim-batch", "4",
         "--max-items", str(n_items)],
        env=env, stdout=subprocess.PIPE, text=True)
    t0 = time.perf_counter()
    results = ds.sample_batch(configs, operation_id="rendezvous",
                              backend="queue")
    wall = time.perf_counter() - t0
    proc.communicate(timeout=60)
    ideal = sum(base_s * HETERO_TIERS[c["tier"]] for c in configs)
    store.close()
    return {
        "items": len(configs),
        "ok": all(r.ok for r in results) and proc.returncode == 0,
        "wall_s": round(wall, 3),
        "ideal_measure_s": round(ideal, 3),
        "overhead_ms_per_item": round((wall - ideal) / len(configs) * 1e3, 2),
    }


def _rendezvous_factory(store_path):
    """Worker factory for the rendezvous-overhead bench (module:callable)."""
    return _hetero_ds(SampleStore(store_path), _RENDEZVOUS_BASE_S)


_RENDEZVOUS_BASE_S = 0.002


def run_queue_priority_bench(warmup: int = 6, base_s: float = 0.002,
                             seed: int = 0, rendezvous_items: int = 8,
                             verbose: bool = True) -> dict:
    """Priority-vs-FIFO time-to-best-cost on the heterogeneous workload.

    Both runs enqueue the identical remaining pool after an identical warmup;
    the only difference is whether the TPE acquisition scores ride along as
    work-item priorities.  Fewer measured experiments to reach the best-cost
    configuration = earlier usable answer under a budget (Lynceus).
    """
    with tempfile.TemporaryDirectory() as d:
        fifo = _one_queue_run(False, warmup, base_s, seed, d)
        prio = _one_queue_run(True, warmup, base_s, seed, d)
        overhead = _rendezvous_overhead(_RENDEZVOUS_BASE_S, rendezvous_items,
                                        seed, d)
    out = {
        "warmup": warmup,
        "pool": prio["pool"],
        "base_latency_ms": base_s * 1e3,
        "fifo_time_to_best": fifo["time_to_best"],
        "priority_time_to_best": prio["time_to_best"],
        "priority_wins": prio["time_to_best"] < fifo["time_to_best"],
        "fifo_wall_s": fifo["wall_s"],
        "priority_wall_s": prio["wall_s"],
        "rendezvous_overhead": overhead,
    }
    if verbose:
        print(f"[queue] priority-vs-FIFO over {out['pool']} queued configs "
              f"(+{warmup} warmup): time-to-best {prio['time_to_best']} vs "
              f"{fifo['time_to_best']} measured experiments => "
              f"{'priority wins' if out['priority_wins'] else 'NO WIN'}; "
              f"rendezvous overhead "
              f"{overhead['overhead_ms_per_item']}ms/item")
    return out


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke mode: fewer trials/attempts; gate is "
                             "pipelined >= serial throughput")
    parser.add_argument("--out", default="BENCH_parallel.json",
                        help="JSON artifact path (default: %(default)s)")
    parser.add_argument("--queue-out", default="BENCH_queue.json",
                        help="priority-vs-FIFO artifact path (default: %(default)s)")
    args = parser.parse_args(argv)

    if args.quick:
        equivalence = [run_parallel_bench(optimizer="random", attempts=2)]
        pipelined = run_pipelined_bench(attempts=2)
    else:
        equivalence = [run_parallel_bench(optimizer=o) for o in ("random", "tpe")]
        pipelined = run_pipelined_bench()
    queue = run_queue_priority_bench()

    eq_ok = all(r["identical_sample_set"] and r["speedup"] >= 2.0
                for r in equivalence)
    # quick mode gates on not regressing below serial; the full bench must
    # demonstrate the pipelining win over the barrier-synchronized engine
    pipe_ok = (pipelined["speedup_vs_serial"] >= 1.0 if args.quick
               else pipelined["speedup_vs_batch"] > 1.0
               and pipelined["speedup_vs_serial"] > 1.0)
    # priority scheduling must beat FIFO to the best-cost configuration
    queue_ok = queue["priority_wins"] and queue["rendezvous_overhead"]["ok"]
    ok = eq_ok and pipe_ok and queue_ok

    payload = {"mode": "quick" if args.quick else "full",
               "equivalence": equivalence, "pipelined": pipelined,
               "pass": ok}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    queue_payload = {"mode": "quick" if args.quick else "full",
                     "queue_scheduling": queue, "pass": queue_ok}
    with open(args.queue_out, "w") as f:
        json.dump(queue_payload, f, indent=2, sort_keys=True)
    print(f"[parallel] wrote {args.out} and {args.queue_out}")
    print(f"[parallel] acceptance: {'PASS' if ok else 'FAIL'} "
          f"(equivalence+2x: {eq_ok}, pipelined: {pipe_ok}, "
          f"priority-queue: {queue_ok})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
