"""Parallel-engine benchmark: the same search, serial vs 4 workers.

Validates the two claims of the batched ask/tell engine (paper §III-D —
distributed investigation through one shared sample store):

* **equivalence** — for a fixed seed, the 4-worker run produces a
  byte-identical reconciled sample set (and identical sampling record) to
  the serial run: parallelism changes wall-clock, never results;
* **speedup** — with a simulated measurement latency of ≥10 ms per
  experiment (cloud deployments are seconds-to-minutes; 10 ms keeps the
  bench quick), 4 workers deliver ≥2× wall-clock improvement.

Run directly::

    PYTHONPATH=src python -m benchmarks.parallel_bench

or via the harness (``benchmarks.run``), which prints the CSV row
``CSV,parallel_engine,<us_per_trial>,speedup=<x>;identical=<bool>``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (ActionSpace, DiscoverySpace, Dimension,
                        FunctionExperiment, ProbabilitySpace, SampleStore)
from repro.core.entities import canonical_json, content_hash
from repro.core.optimizers import OPTIMIZER_REGISTRY, run_optimizer

__all__ = ["run_parallel_bench", "reconciled_digest"]

MEASURE_LATENCY_S = 0.010  # simulated deployment+measurement cost


def _space(n=12):
    vals = [round(v, 3) for v in np.linspace(-2, 2, n)]
    return ProbabilitySpace.make([
        Dimension.discrete("cpu_request", vals),
        Dimension.discrete("memory_gb", vals),
        Dimension.categorical("instance", ["spot", "dedicated"]),
    ])


def _experiment(latency_s: float = MEASURE_LATENCY_S) -> FunctionExperiment:
    def measure(c):
        time.sleep(latency_s)  # the deploy-and-benchmark cost
        penalty = 0.0 if c["instance"] == "spot" else 0.6
        return {"cost": (c["cpu_request"] - 0.5) ** 2
                + (c["memory_gb"] + 0.5) ** 2 + penalty}
    return FunctionExperiment(fn=measure, properties=("cost",), name="deploy")


def reconciled_digest(ds: DiscoverySpace) -> str:
    """Content hash of the reconciled sample set {x}, excluding timestamps:
    two runs with this digest equal hold byte-identical sample data."""
    payload = sorted(
        (s.configuration.digest,
         sorted((v.name, v.value, v.experiment_id, v.predicted)
                for v in s.properties.values()))
        for s in ds.read()
    )
    return content_hash(payload)


def _one_run(workers: int, optimizer: str, batch_size: int, max_trials: int,
             latency_s: float, seed: int):
    ds = DiscoverySpace(space=_space(), actions=ActionSpace.make(
        [_experiment(latency_s)]), store=SampleStore(":memory:"))
    t0 = time.perf_counter()
    run = run_optimizer(OPTIMIZER_REGISTRY[optimizer](seed=seed), ds, "cost",
                        "min", max_trials=max_trials, patience=max_trials + 1,
                        rng=np.random.default_rng(seed),
                        batch_size=batch_size, workers=workers)
    wall = time.perf_counter() - t0
    record = canonical_json([
        (r.seq, r.config_digest, r.action)
        for r in ds.timeseries(run.operation_id)])
    return {
        "workers": workers,
        "wall_s": wall,
        "trials": run.num_trials,
        "measured": run.num_measured,
        "sample_set_digest": reconciled_digest(ds),
        "record_digest": content_hash(record),
        "best": run.best.value if run.best else None,
    }


def run_parallel_bench(optimizer: str = "random", batch_size: int = 8,
                       max_trials: int = 48, workers: int = 4,
                       latency_s: float = MEASURE_LATENCY_S,
                       seed: int = 0, attempts: int = 3,
                       verbose: bool = True) -> dict:
    serial = _one_run(1, optimizer, batch_size, max_trials, latency_s, seed)

    # Result equivalence must hold on EVERY attempt; the wall-clock gate is
    # best-of-N (timing on a shared machine is load-sensitive, results are
    # not allowed to be).
    identical = True
    speedup = 0.0
    parallel = None
    for _ in range(max(1, attempts)):
        attempt = _one_run(workers, optimizer, batch_size, max_trials,
                           latency_s, seed)
        identical &= (
            serial["sample_set_digest"] == attempt["sample_set_digest"]
            and serial["record_digest"] == attempt["record_digest"])
        ratio = serial["wall_s"] / max(attempt["wall_s"], 1e-9)
        if parallel is None or ratio > speedup:
            parallel, speedup = attempt, ratio
        if not identical or speedup >= 2.0:
            break
    out = {
        "optimizer": optimizer,
        "batch_size": batch_size,
        "trials": serial["trials"],
        "latency_ms": latency_s * 1e3,
        "serial_s": round(serial["wall_s"], 3),
        "parallel_s": round(parallel["wall_s"], 3),
        "workers": workers,
        "speedup": round(speedup, 2),
        "identical_sample_set": identical,
        "best": serial["best"],
    }
    if verbose:
        print(f"[parallel] {optimizer} batch={batch_size} "
              f"trials={out['trials']} latency={out['latency_ms']:.0f}ms: "
              f"serial {out['serial_s']}s vs {workers}w {out['parallel_s']}s "
              f"=> {out['speedup']}x, identical={identical}")
    return out


def main() -> int:
    results = [run_parallel_bench(optimizer=o) for o in ("random", "tpe")]
    ok = all(r["identical_sample_set"] and r["speedup"] >= 2.0 for r in results)
    print(f"[parallel] acceptance: "
          f"{'PASS' if ok else 'FAIL'} (need byte-identical + >=2x)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
