"""Sharing-efficiency benchmark: cooperative campaigns vs isolated optimizers.

The repo's reproduction of the paper's §V headline: "safe, transparent
sharing of data between executions of best-of-breed optimizers increasing
the efficiency of optimal configuration detection".  Because no single
optimizer family wins across workloads (Lazuka et al. 2022 — the paper's
motivation for running several), the practitioner's unit of comparison is a
*fleet* of heterogeneous optimizers (random, TPE, BO-GP, BOHB), and the
experiment is a sharing ablation on that fleet, same seeds, same per-member
budgets:

* **isolated** — every member searches on its OWN store: no reuse, no
  shared history (running N independent optimizers, today's default);
* **store-reuse** — one shared store, ``share_history=False``: members
  reuse each other's measurements transparently (the common-context §III-C
  baseline) but each model trains only on its own trials;
* **shared** — one shared store, ``share_history=True``: every completed
  measurement is folded into every member's history (foreign tells) — each
  model trains on the union of the fleet's data.

The metric is fleet *time-to-best-cost*: paid deployments (measured +
failed — an OOM'd deployment costs money too), in fleet round-robin order,
until a configuration at or below the best-known-cost threshold (a top
quantile of the enumerated ground truth) first lands.  The isolated fleet
reaches the target exactly when its best member does — "the best isolated
optimizer on the same seeds" — so the sharing claim holds when the shared
campaign's median is lower.  Per-family single-optimizer results (each
family alone with the FULL fleet budget: an oracle that knew the winning
family in advance) are also reported for transparency.

Run directly::

    PYTHONPATH=src python -m benchmarks.campaign_bench [--quick] [--out F]

``--quick`` is the CI smoke mode (one workload, fewer seeds/trials); either
mode writes the full result set to ``BENCH_sharing.json``.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ActionSpace, Campaign, DiscoverySpace, SampleStore
from repro.core.optimizers import OPTIMIZER_REGISTRY, run_optimizer

from .workloads import WORKLOADS, exhaustive_values

__all__ = ["run_sharing_bench"]

FAMILIES = ("random", "tpe", "bo-gp", "bohb")


def _member_rngs(seed: int):
    return [np.random.default_rng(1000 + seed + 31 * i)
            for i in range(len(FAMILIES))]


def _make_ds(factory):
    space, exp, metric, mode = factory()
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                        store=SampleStore(":memory:"))
    return ds, metric, mode


def _paid_to_target(trials, threshold: float, mode: str):
    """Paid deployments (measured + failed) until the first trial at or
    below (above, for max) the target threshold; None if never reached."""
    paid = 0
    for t in trials:
        if t.action in ("measured", "failed"):
            paid += 1
        if t.value is None:
            continue
        if (t.value <= threshold) if mode == "min" else (t.value >= threshold):
            return paid
    return None


def _interleave(runs):
    """Merge per-member trial lists round-robin — the fleet event order of
    N optimizers running concurrently without any coordination."""
    merged, i = [], 0
    while any(i < len(r) for r in runs):
        for r in runs:
            if i < len(r):
                merged.append(r[i])
        i += 1
    return merged


def _isolated_fleet(factory, seed: int, per_member: int):
    """The no-sharing fleet: each family on its own store (same rngs and
    per-member budget as the campaign), merged round-robin."""
    runs = []
    for name, rng in zip(FAMILIES, _member_rngs(seed)):
        ds, metric, mode = _make_ds(factory)
        run = run_optimizer(OPTIMIZER_REGISTRY[name](seed=seed), ds, metric,
                            mode, max_trials=per_member,
                            patience=per_member + 1, rng=rng)
        runs.append(run.trials)
    return _interleave(runs), mode


def _campaign_fleet(factory, seed: int, per_member: int, share: bool):
    ds, metric, mode = _make_ds(factory)
    campaign = Campaign(
        ds, [OPTIMIZER_REGISTRY[name](seed=seed) for name in FAMILIES],
        metric, mode=mode, max_trials=per_member, patience=per_member + 1,
        share_history=share,
        # serial backend => full-information sharing: every ask trains on
        # every measurement the fleet has completed, the §V efficiency
        # setting (concurrent backends trade staleness for wall-clock)
        backend="serial",
        rngs=_member_rngs(seed))
    res = campaign.run()
    return res, mode


def _single_family(factory, name: str, seed: int, budget: int):
    """Oracle baseline: one family alone with the FULL fleet budget."""
    ds, metric, mode = _make_ds(factory)
    run = run_optimizer(OPTIMIZER_REGISTRY[name](seed=seed), ds, metric, mode,
                        max_trials=budget, patience=budget + 1,
                        rng=np.random.default_rng(1000 + seed))
    return run.trials, mode


def run_sharing_bench(workloads=None, seeds=range(16), per_member: int = 15,
                      quantile: float = 0.01, verbose: bool = True) -> dict:
    """Sharing ablation over a seed set (see module docstring).

    Every arm spends the same total budget (``per_member × len(FAMILIES)``
    paid deployments at most) with the same per-member rng streams; we
    report the median (over seeds) paid-measurements-to-target per arm.
    Unreached runs count as budget+1."""
    workloads = workloads if workloads is not None else list(WORKLOADS)
    total_budget = per_member * len(FAMILIES)
    miss = total_budget + 1
    out = {"per_member_trials": per_member, "total_budget": total_budget,
           "quantile": quantile, "seeds": list(seeds), "families": FAMILIES,
           "workloads": {}}
    for wname in workloads:
        factory = WORKLOADS[wname]
        space, exp, metric, mode = factory()
        _, truth = exhaustive_values(space, exp, metric)
        threshold = float(np.quantile(
            truth, quantile if mode == "min" else 1 - quantile))
        arms = {"isolated": [], "store_reuse": [], "shared": []}
        oracle: dict = {name: [] for name in FAMILIES}
        reused: list = []
        for seed in seeds:
            fleet_trials, m = _isolated_fleet(factory, seed, per_member)
            arms["isolated"].append(
                _paid_to_target(fleet_trials, threshold, m) or miss)
            for share, arm in ((False, "store_reuse"), (True, "shared")):
                res, m = _campaign_fleet(factory, seed, per_member, share)
                trials = [t for _, t in res.events]
                arms[arm].append(_paid_to_target(trials, threshold, m) or miss)
                if share:
                    reused.append(sum(1 for _, t in res.events
                                      if t.action == "reused"))
            for name in FAMILIES:
                trials, m = _single_family(factory, name, seed, total_budget)
                oracle[name].append(
                    _paid_to_target(trials, threshold, m) or miss)
        medians = {arm: float(np.median(v)) for arm, v in arms.items()}
        oracle_medians = {n: float(np.median(v)) for n, v in oracle.items()}
        best_oracle = min(oracle_medians, key=oracle_medians.get)
        row = {
            "metric": metric,
            "mode": mode,
            "space_size": space.size,
            "target_threshold": round(threshold, 3),
            "median_paid_to_target": medians,
            "per_seed": {k: list(map(int, v)) for k, v in arms.items()},
            "shared_reused_trials_per_seed": list(map(int, reused)),
            "oracle_single_family_median": oracle_medians,
            "best_oracle_family": best_oracle,
            "sharing_wins": medians["shared"] < medians["isolated"],
            "sharing_speedup_vs_isolated": round(
                medians["isolated"] / max(medians["shared"], 1e-9), 2),
        }
        out["workloads"][wname] = row
        if verbose:
            print(f"[sharing] {wname}: target {row['target_threshold']} "
                  f"(q{quantile}); paid-to-target median: isolated "
                  f"{medians['isolated']:.1f}, store-reuse "
                  f"{medians['store_reuse']:.1f}, shared "
                  f"{medians['shared']:.1f} "
                  f"({row['sharing_speedup_vs_isolated']}x vs isolated); "
                  f"oracle best single family {best_oracle}="
                  f"{oracle_medians[best_oracle]:.1f}")
    rows = out["workloads"].values()
    shared_total = sum(r["median_paid_to_target"]["shared"] for r in rows)
    isolated_total = sum(r["median_paid_to_target"]["isolated"] for r in rows)
    out["shared_total_median_paid"] = shared_total
    out["isolated_total_median_paid"] = isolated_total
    # the §V claim: the shared fleet reaches best-known cost in fewer paid
    # measurements than the isolated fleet (whose hit time IS its best
    # member's — "the best isolated optimizer") on every workload
    out["pass"] = all(r["sharing_wins"] for r in rows) \
        and shared_total < isolated_total
    return out


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke mode: one workload, fewer seeds")
    parser.add_argument("--out", default="BENCH_sharing.json",
                        help="JSON artifact path (default: %(default)s)")
    args = parser.parse_args(argv)

    t0 = time.perf_counter()
    if args.quick:
        result = run_sharing_bench(workloads=["MI-OPT"], seeds=range(3),
                                   per_member=10)
    else:
        result = run_sharing_bench()
    result["mode_flag"] = "quick" if args.quick else "full"
    result["wall_s"] = round(time.perf_counter() - t0, 1)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"[sharing] wrote {args.out} in {result['wall_s']}s: "
          f"{'PASS' if result['pass'] else 'FAIL'} "
          f"(shared total {result['shared_total_median_paid']} vs isolated "
          f"fleet {result['isolated_total_median_paid']})")
    return 0 if result["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
