"""Sharded serving steps.

``prefill``: full-sequence forward emitting sharded KV caches.
``decode``:  one new token against a seq_len KV cache (ring buffers for
window layers, recurrent state for RG-LRU/xLSTM layers).

Cache shardings come from ``distributed.sharding.cache_specs``: KV heads TP
when they divide the model axis; otherwise the cache *length* is split over
the model axis (flash-decode style split-KV) so decode attention still
parallelizes 16-way.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import (DeploymentConfig, batch_specs, cache_specs,
                                    param_specs)
from ..models.model import LMModel

__all__ = ["make_prefill_step", "make_decode_step"]


def _ns(mesh, tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree,
                        is_leaf=lambda x: isinstance(x, P))


def make_prefill_step(model: LMModel, deployment: DeploymentConfig, mesh: Mesh,
                      capacity: int, jit: bool = True):
    """prefill(params, batch) -> (last-token logits (B,V), caches).

    Encoder-only models have no decode caches: their "prefill" is the
    encoder forward, returning full-sequence logits and no cache."""
    pspecs = param_specs(model.logical_specs(), deployment)
    bspecs = batch_specs(model.cfg, deployment, kind="prefill")
    bt = tuple(deployment.batch_axes)

    if model.cfg.is_encoder_only:
        logit_spec = P(bt, deployment.seq_axis, deployment.rule("vocab"))

        def encode(params, batch):
            logits, _ = model.forward(params, batch)
            return logits, ()

        if not jit:
            return encode, (pspecs, bspecs), (logit_spec, ())
        fn = jax.jit(encode,
                     in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
                     out_shardings=(_ns(mesh, logit_spec), ()))
        return fn, (pspecs, bspecs), (logit_spec, ())

    cspecs = cache_specs(model.cfg, deployment)
    logit_spec = P(bt, deployment.rule("vocab"))

    def prefill(params, batch):
        return model.prefill(params, batch, capacity)

    if not jit:
        return prefill, (pspecs, bspecs), (logit_spec, cspecs)
    fn = jax.jit(prefill,
                 in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
                 out_shardings=(_ns(mesh, logit_spec), _ns(mesh, cspecs)))
    return fn, (pspecs, bspecs), (logit_spec, cspecs)


def make_decode_step(model: LMModel, deployment: DeploymentConfig, mesh: Mesh,
                     jit: bool = True):
    """decode(params, batch, caches, index) -> (logits (B,V), new caches)."""
    pspecs = param_specs(model.logical_specs(), deployment)
    bspecs = batch_specs(model.cfg, deployment, kind="decode")
    cspecs = cache_specs(model.cfg, deployment)
    bt = tuple(deployment.batch_axes)
    logit_spec = P(bt, deployment.rule("vocab"))

    def decode(params, batch, caches, index):
        return model.decode_step(params, batch, caches, index)

    if not jit:
        return decode, (pspecs, bspecs, cspecs, P()), (logit_spec, cspecs)
    fn = jax.jit(decode,
                 in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs),
                               _ns(mesh, cspecs), NamedSharding(mesh, P())),
                 out_shardings=(_ns(mesh, logit_spec), _ns(mesh, cspecs)),
                 donate_argnums=(2,))
    return fn, (pspecs, bspecs, cspecs, P()), (logit_spec, cspecs)
