import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- multi-pod dry-run driver -------------------------------------------------
# Lowers + compiles every (architecture × input shape) cell for the production
# mesh (16×16 single pod; 2×16×16 multi-pod), prints memory_analysis() and
# cost_analysis(), and derives the three roofline terms per cell.
#
# The two lines above MUST stay the first two lines of this module: jax locks
# the device count on first init, and only the dry-run gets 512 placeholder
# devices (smoke tests and benches see 1 CPU device).

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCHITECTURES, SHAPES, ShapeSpec,
                           cell_applicability, get_config)
from repro.distributed.sharding import (DeploymentConfig, batch_specs,
                                        default_deployment)
from repro.launch.mesh import make_production_mesh
from repro.models.model import LMModel
from repro.roofline.analysis import analyze_compiled
from repro.roofline.hw import HW_V5E
from repro.serving.serve_step import make_decode_step, make_prefill_step
from repro.training.train_step import init_train_state, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def batch_structs(cfg, shape, kind: str):
    B, S = shape.global_batch, shape.seq_len
    if kind == "decode":
        S_in = 1
    else:
        S_in = S
    out = {}
    if cfg.uses_tokens:
        out["tokens"] = jax.ShapeDtypeStruct((B, S_in), jnp.int32)
    else:
        out["embeds"] = jax.ShapeDtypeStruct((B, S_in, cfg.frontend_dim),
                                             jnp.bfloat16)
    if kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, S_in), jnp.int32)
    return out


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference (N = active
    params excluding the token-embedding table, D = tokens processed)."""
    n = cfg.active_param_count()
    if cfg.uses_tokens:
        n -= cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n * tokens


def lower_cell(arch: str, shape_name, mesh, deployment=None):
    """Build and lower the step function for one cell.  Returns (lowered,
    meta) — compile separately so callers can time the phases.

    ``shape_name`` is a key of :data:`~repro.configs.SHAPES` or a
    :class:`~repro.configs.ShapeSpec` directly (the LLM deployment-space
    family lowers off-matrix sequence lengths via
    :func:`~repro.configs.custom_shape`)."""
    cfg = get_config(arch)
    shape = shape_name if isinstance(shape_name, ShapeSpec) \
        else SHAPES[shape_name]
    if deployment is None:
        deployment = default_deployment(cfg, mesh, shape_kind=shape.kind,
                                        global_batch=shape.global_batch,
                                        seq_len=shape.seq_len)
    model = LMModel(cfg, deployment.model_options())
    kind = shape.kind

    if kind == "train":
        step, sspecs, bspecs = make_train_step(model, deployment, mesh)
        state_struct = jax.eval_shape(
            lambda k: init_train_state(model, k), jax.random.PRNGKey(0))
        lowered = step.lower(state_struct, batch_structs(cfg, shape, kind))
    elif kind == "prefill":
        fn, _, _ = make_prefill_step(model, deployment, mesh,
                                     capacity=shape.seq_len)
        params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        lowered = fn.lower(params_struct, batch_structs(cfg, shape, kind))
    elif kind == "decode":
        fn, _, _ = make_decode_step(model, deployment, mesh)
        params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        index = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = fn.lower(params_struct, batch_structs(cfg, shape, kind),
                           cache_struct, index)
    else:
        raise ValueError(kind)
    return lowered, {"cfg": cfg, "shape": shape, "deployment": deployment}


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False,
                deployment: Optional[DeploymentConfig] = None,
                mesh=None, verbose: bool = True, save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "x".join(str(d) for d in mesh.devices.shape)
    ok, reason = cell_applicability(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_desc}
    if not ok:
        result.update(status=f"skip({reason})")
        if verbose:
            print(f"[dryrun] {arch} × {shape_name} × {mesh_desc}: SKIP — {reason}")
        return result

    t0 = time.time()
    with mesh:
        lowered, meta = lower_cell(arch, shape_name, mesh, deployment)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem_repr = None
        try:
            mem_repr = str(compiled.memory_analysis())
        except Exception as e:  # pragma: no cover
            mem_repr = f"<memory_analysis unavailable: {e}>"
        chips = mesh.devices.size
        mesh_groups = dict(zip(mesh.axis_names, mesh.devices.shape))
        report = analyze_compiled(
            compiled, arch, shape_name, mesh_desc, chips, mesh_groups,
            model_flops=model_flops_for(cfg, shape))

    result.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory_analysis=mem_repr,
        roofline=report.summary(),
        hlo_flops_per_device=report.hlo_flops,
        hlo_bytes_per_device=report.hlo_bytes,
        collective_bytes=report.collective,
        collective_counts=report.collective_counts,
        model_flops=report.model_flops,
        deployment=_deployment_json(meta["deployment"]),
    )
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_desc}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"         memory_analysis: {mem_repr}")
        print(f"         cost_analysis: flops/dev={report.hlo_flops:.3e} "
              f"bytes/dev={report.hlo_bytes:.3e}")
        print(f"         roofline: {report.summary()}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_desc}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def _deployment_json(dep: DeploymentConfig) -> dict:
    d = dict(dep.__dict__)
    d["rules"] = dict(dep.rules)
    return d


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHITECTURES) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    failures = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            for shape_name in shapes:
                try:
                    results.append(dryrun_cell(arch, shape_name, mesh=mesh,
                                               multi_pod=multi_pod))
                except Exception as e:
                    failures += 1
                    print(f"[dryrun] {arch} × {shape_name} "
                          f"(multi_pod={multi_pod}): FAILED — {e}")
                    traceback.print_exc()
                    if args.fail_fast:
                        raise
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if str(r.get("status", "")).startswith("skip"))
    print(f"\n[dryrun] done: {n_ok} ok, {n_skip} skipped, {failures} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
