"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — jax locks the device count on first init,
and only the dry-run entry point is allowed to request 512 placeholder
devices via XLA_FLAGS.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax

__all__ = ["make_production_mesh", "make_mesh", "available_devices",
           "mesh_split_options", "parse_mesh_split"]


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one v5e pod is 16×16 = 256 chips
    (data × model); the multi-pod config is 2 pods = 512 chips with a
    leading 'pod' axis (DP across pods over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests, elastic re-meshing, deployment search)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def available_devices() -> int:
    return len(jax.devices())


def mesh_split_options(devices: int) -> tuple:
    """Canonical ``data×model`` splits of a ``devices``-chip slice, as
    ``"DxM"`` labels: full-TP, the most-square split, full-DP.

    Every power-of-two topology yields the SAME number of options in the
    same semantic order (TP-heavy → balanced → DP-heavy) for ``devices >=
    4``, so two family-sibling Discovery Spaces on different topologies have
    same-cardinality categorical mesh dimensions — exactly what the
    catalog's positional rename inference needs to bridge them (§IV-1).
    Pure arithmetic: never touches jax device state.
    """
    if devices < 1 or devices & (devices - 1):
        raise ValueError(f"devices must be a power of two, got {devices}")
    half = 1
    while half * half < devices:
        half *= 2
    splits = [(1, devices), (devices // half, half), (devices, 1)]
    seen, out = set(), []
    for data, model in splits:
        if (data, model) not in seen:
            seen.add((data, model))
            out.append(f"{data}x{model}")
    return tuple(out)


def parse_mesh_split(label: str) -> tuple:
    """``"2x4"`` → ``(2, 4)`` (data, model)."""
    data, _, model = label.partition("x")
    return int(data), int(model)
