"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — jax locks the device count on first init,
and only the dry-run entry point is allowed to request 512 placeholder
devices via XLA_FLAGS.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax

__all__ = ["make_production_mesh", "make_mesh", "available_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: one v5e pod is 16×16 = 256 chips
    (data × model); the multi-pod config is 2 pods = 512 chips with a
    leading 'pod' axis (DP across pods over DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (tests, elastic re-meshing, deployment search)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def available_devices() -> int:
    return len(jax.devices())
