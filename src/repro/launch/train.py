"""End-to-end training launcher: data → sharded train step → checkpoints,
with restart-after-failure and elastic re-meshing.

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \\
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

The launcher is deliberately structured the way a 1000-node job would be:
  1. build/restore: if the checkpoint dir has a latest step, resume from it
     (restart-after-failure path — also the entry point after an elastic
     re-mesh, since checkpoints are mesh-independent);
  2. deterministic data cursor = global step (stream is seekable, so resume
     needs no data-state persistence);
  3. checkpoint every N steps (async), retain K;
  4. XLA latency-hiding flags are set for collective/compute overlap.
"""

import os

# latency-hiding scheduler: overlap collectives with compute (harmless on CPU)
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_enable_fast_math=false")

import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.sharding import default_deployment, named_sharding_tree
from repro.checkpoint.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh
from repro.models.model import LMModel
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def build(args):
    n_dev = len(jax.devices())
    model_axis = args.model_axis if args.model_axis else 1
    data_axis = n_dev // model_axis
    mesh = make_mesh((data_axis, model_axis), ("data", "model"))
    cfg = get_config(args.arch, smoke=args.smoke)
    deployment = default_deployment(cfg, mesh, shape_kind="train",
                                    global_batch=args.batch, seq_len=args.seq)
    deployment = replace(deployment, microbatches=args.microbatches,
                         compute_dtype=args.compute_dtype)
    model = LMModel(cfg, deployment.model_options())
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps)
    step_fn, state_specs, bspecs = make_train_step(model, deployment, mesh,
                                                   opt_cfg)
    return mesh, cfg, model, deployment, step_fn, state_specs, bspecs


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--compute-dtype", default="float32")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--stop-after", type=int, default=0,
                    help="simulate failure: exit after N steps")
    args = ap.parse_args(argv)

    mesh, cfg, model, deployment, step_fn, state_specs, bspecs = build(args)
    with mesh:
        mgr = None
        start_step = 0
        state = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep=3,
                                    save_every=args.ckpt_every)
            latest = mgr.latest_step()
            if latest is not None:
                template = jax.eval_shape(
                    lambda k: init_train_state(model, k), jax.random.PRNGKey(0))
                shardings = named_sharding_tree(state_specs, mesh)
                state, manifest = mgr.restore_latest(template, shardings)
                start_step = int(manifest["step"])
                print(f"[train] restored checkpoint at step {start_step}")
        if state is None:
            state = init_train_state(model, jax.random.PRNGKey(args.steps))
            state = jax.device_put(state, named_sharding_tree(state_specs, mesh))

        data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.seq,
                                        global_batch=args.batch, seed=13))
        data.start(cursor=start_step)

        losses = []
        t0 = time.time()
        for step in range(start_step, args.steps):
            cursor, batch = next(data)
            assert cursor == step, f"data cursor {cursor} != step {step}"
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
            if mgr is not None and mgr.should_save(step + 1):
                mgr.save(step + 1, state, {"loss": loss})
            if args.stop_after and (step + 1 - start_step) >= args.stop_after:
                # simulated hard failure: NO final checkpoint — restart must
                # recover from the last periodic one.  The failure loses
                # future work, not durability: an in-flight async save of an
                # *earlier* step still lands (atomic tmp+rename), so drain it
                # before "crashing" — otherwise resume races the save thread.
                if mgr is not None:
                    mgr.wait()
                print(f"[train] simulated failure after {args.stop_after} steps")
                data.stop()
                return {"first_loss": losses[0], "last_loss": losses[-1],
                        "steps_run": len(losses), "resumed_from": start_step}
        data.stop()
        # `losses` is empty when resuming a run that already completed
        # (start_step == steps): nothing ran, nothing new to checkpoint.
        if mgr is not None and losses:
            mgr.save(start_step + len(losses), state, {"loss": losses[-1]},
                     async_=False)
            mgr.wait()
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps_run": len(losses), "resumed_from": start_step}


if __name__ == "__main__":
    out = main()
    print(f"[train] done: {out}")
