"""Serving launcher: prefill + batched decode over the sharded serving path.

CPU-scale demo of the production serving loop:
  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --smoke \\
      --batch 4 --prompt-len 32 --gen 16
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import default_deployment
from repro.launch.mesh import make_mesh
from repro.models.model import LMModel
from repro.serving.serve_step import make_decode_step, make_prefill_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: nothing to decode")
    capacity = args.prompt_len + args.gen
    deployment = default_deployment(cfg, mesh, shape_kind="decode",
                                    global_batch=args.batch)
    model = LMModel(cfg, deployment.model_options())

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        prefill, _, _ = make_prefill_step(model, deployment, mesh, capacity)
        decode, _, _ = make_decode_step(model, deployment, mesh)

        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.batch, args.prompt_len)).astype(np.int32)
        batch = {"tokens": jnp.asarray(prompts)} if cfg.uses_tokens else \
            {"embeds": jnp.asarray(rng.normal(
                size=(args.batch, args.prompt_len, cfg.frontend_dim)),
                jnp.float32)}

        t0 = time.time()
        logits, caches = prefill(params, batch)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0

        generated = [np.asarray(tok)]
        t0 = time.time()
        for i in range(args.gen - 1):
            step_batch = {"tokens": tok[:, None]} if cfg.uses_tokens else \
                {"embeds": jnp.zeros((args.batch, 1, cfg.frontend_dim),
                                     jnp.float32)}
            logits, caches = decode(params, step_batch, caches,
                                    args.prompt_len + i)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            generated.append(np.asarray(tok))
        t_decode = time.time() - t0

    out = np.stack(generated, axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] {cfg.name}: prefill {args.prompt_len} tok × {args.batch} "
          f"seqs in {t_prefill * 1e3:.0f} ms; decoded {args.gen - 1} steps at "
          f"{tps:.1f} tok/s")
    print(f"[serve] sample continuation (seq 0): {out[0][:12].tolist()}")
    return {"prefill_ms": t_prefill * 1e3, "tokens_per_s": tps,
            "tokens": out}


if __name__ == "__main__":
    main()
