"""Action-space experiments over deployment configurations.

Both experiments are phased through the actuation lifecycle
(:mod:`repro.core.connector`): *provision* is the deployment step (building
the model and compiling the jitted step on the production mesh), *run* is
the measurement proper (roofline analysis of the compiled artifact / the
timed step), *parse* shapes the properties, *teardown* is free (compiled
artifacts are process-local and garbage-collected).  The public classes are
compatibility shims — :class:`~repro.core.connector.LifecycleExperiment`
subclasses with the historical constructor signatures and identities — so
stored provenance reconciles and optimizer trajectories stay draw-for-draw
with the monolithic originals.

* :class:`DryrunRooflineExperiment` — provision = ``jit(step).lower()
  .compile()`` on the production mesh; run = trip-corrected roofline terms
  from the compiled artifact (the honest measurement available on this
  CPU-only container; identical interface to a wall-clock experiment on real
  TPUs).  Non-compiling or over-HBM configurations raise
  :class:`MeasurementError` — the paper's "non-deployable points".
* :class:`WalltimeExperiment` — real wall-clock timing of a reduced-config
  step on the local device (used by the optimizer benchmarks so that the
  paper-validation spaces contain genuinely *measured* data).

Both are hermetic: identity = (name, version, parameterization) where the
parameterization pins (arch, shape, mesh, hw) — so samples reconcile across
processes through the common context, and a different mesh or hardware is a
*different* Discovery Space (which is exactly what RSSC then bridges).
"""

from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

from ..core.actions import MeasurementError
from ..core.clock import SYSTEM_CLOCK, Clock
from ..core.connector import (Deployment, ExperimentConnector,
                              LifecycleExperiment, PricingModel, RetryPolicy)
from ..core.entities import Configuration
from ..roofline.hw import HWSpec, HW_V5E

__all__ = ["DryrunRooflineExperiment", "WalltimeExperiment",
           "DryrunRooflineConnector", "WalltimeConnector"]


class DryrunRooflineConnector(ExperimentConnector):
    """Phased dry-run roofline measurement (see module docstring)."""

    name = "dryrun-roofline"
    version = "1"

    def __init__(self, arch: str, shape_name: str, mesh, hw: HWSpec = HW_V5E,
                 hbm_limit: Optional[float] = None,
                 clock: Clock = SYSTEM_CLOCK):
        self.arch = arch
        self.shape_name = shape_name
        self.mesh = mesh
        self.hw = hw
        self.hbm_limit = hbm_limit
        # every phase timestamp/duration this connector records goes through
        # the injectable clock, so virtual-clock specs and trace replays of
        # tuning experiments are deterministic (a FakeClock legitimately
        # reports zero compile time)
        self.clock = clock

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return {"arch": self.arch, "shape": self.shape_name,
                "mesh": "x".join(map(str, self.mesh.devices.shape)),
                "hw": self.hw.name}

    @property
    def observed_properties(self) -> Sequence[str]:
        return ("compute_s", "memory_s", "collective_s", "step_time_s",
                "roofline_fraction", "hlo_flops", "bytes_per_device",
                "compile_s")

    def provision(self, configuration: Configuration) -> Deployment:
        """Deploy: translate the configuration and compile on the mesh.  A
        non-compiling configuration is the configuration's fault, not the
        infrastructure's — terminal :class:`MeasurementError`, no retry."""
        # imports deferred: this experiment requires the dry-run device env
        from ..configs import SHAPES, get_config
        from ..launch.dryrun import lower_cell
        from .deployment import deployment_from_configuration

        cfg = get_config(self.arch)
        shape = SHAPES[self.shape_name]
        dep = deployment_from_configuration(
            configuration, cfg, self.mesh, shape_kind=shape.kind,
            global_batch=shape.global_batch, seq_len=shape.seq_len)
        created_at = self.clock.time()
        t0 = self.clock.monotonic()
        try:
            with self.mesh:
                lowered, _ = lower_cell(self.arch, self.shape_name, self.mesh,
                                        dep)
                compiled = lowered.compile()
        except Exception as e:
            raise MeasurementError(f"non-deployable: {type(e).__name__}: {e}")
        compile_s = self.clock.monotonic() - t0
        return Deployment(
            ident=f"dryrun-{configuration.digest[:12]}",
            configuration=configuration, created_at=created_at,
            handle=compiled, meta={"compile_s": compile_s, "cfg": cfg,
                                   "shape": shape})

    def run(self, deployment: Deployment) -> Any:
        from ..launch.dryrun import model_flops_for
        from ..roofline.analysis import analyze_compiled

        cfg = deployment.meta["cfg"]
        shape = deployment.meta["shape"]
        chips = self.mesh.devices.size
        groups = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        report = analyze_compiled(
            deployment.handle, self.arch, self.shape_name,
            "x".join(map(str, self.mesh.devices.shape)), chips, groups,
            model_flops=model_flops_for(cfg, shape), hw=self.hw)
        return report, deployment.meta["compile_s"]

    def parse(self, raw: Any) -> Mapping[str, float]:
        report, compile_s = raw
        if (self.hbm_limit is not None and report.bytes_per_device is not None
                and report.bytes_per_device > self.hbm_limit):
            raise MeasurementError(
                f"over HBM: {report.bytes_per_device / 1e9:.1f} GB "
                f"> {self.hbm_limit / 1e9:.1f} GB")
        return DryrunRooflineExperiment._report_properties(report, compile_s)


class DryrunRooflineExperiment(LifecycleExperiment):
    """Compatibility shim: :class:`DryrunRooflineConnector` behind the
    historical constructor/identity (provenance reconciles; see module
    docstring).  ``retry``/``pricing``/``clock`` are new, optional, and —
    when left at their defaults — change nothing observable."""

    def __init__(self, arch: str, shape_name: str, mesh, hw: HWSpec = HW_V5E,
                 hbm_limit: Optional[float] = None,
                 retry: Optional[RetryPolicy] = None,
                 pricing: Optional[PricingModel] = None,
                 clock: Clock = SYSTEM_CLOCK):
        super().__init__(
            DryrunRooflineConnector(arch, shape_name, mesh, hw=hw,
                                    hbm_limit=hbm_limit, clock=clock),
            retry=retry, pricing=pricing, clock=clock)

    @staticmethod
    def _report_properties(report, compile_s: float) -> Mapping[str, float]:
        out = {
            "compute_s": report.compute_s,
            "memory_s": report.memory_s,
            "collective_s": report.collective_s,
            "step_time_s": report.step_time_s,
            "roofline_fraction": report.roofline_fraction,
            "hlo_flops": report.hlo_flops,
            "compile_s": compile_s,
        }
        # A report without a byte count must OMIT bytes_per_device, never
        # record 0.0: a zero sentinel silently satisfies any memory SLA
        # (`bytes_per_device <= limit`), while constraint evaluation treats
        # a missing property as infeasible.  (NaN is no alternative —
        # sqlite3 binds float('nan') as NULL, corrupting the read path.)
        if report.bytes_per_device is not None:
            out["bytes_per_device"] = float(report.bytes_per_device)
        return out


class WalltimeConnector(ExperimentConnector):
    """Phased wall-clock step timing (see module docstring): provision
    builds + compiles the jitted step, run times it."""

    name = "walltime"
    version = "1"

    def __init__(self, arch: str, repeats: int = 3, compute_dtype="float32",
                 arch_scale: float = 1.0, clock: Clock = SYSTEM_CLOCK):
        self.arch = arch
        self.repeats = repeats
        self.compute_dtype = compute_dtype
        self.arch_scale = arch_scale
        # injectable timing source (see DryrunRooflineConnector.__init__)
        self.clock = clock

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return {"arch": self.arch, "repeats": self.repeats,
                "scale": self.arch_scale, "dtype": str(self.compute_dtype)}

    @property
    def observed_properties(self) -> Sequence[str]:
        return ("step_ms", "tokens_per_s")

    def provision(self, configuration: Configuration) -> Deployment:
        import jax
        import numpy as np

        from ..configs import get_config
        from ..models.attention import AttnOptions
        from ..models.blocks import ModelOptions
        from ..models.model import LMModel

        d = configuration.as_dict()
        batch = int(d.get("batch", 2))
        seq = int(d.get("seq", 64))
        q_chunk = int(d.get("attn_q_chunk", 64))
        remat = str(d.get("remat", "none"))
        cfg = get_config(self.arch, smoke=True)
        model = LMModel(cfg, ModelOptions(
            attn=AttnOptions(impl="xla", q_chunk=q_chunk, kv_chunk=q_chunk),
            remat=remat))
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        b = {"labels": rng.integers(0, cfg.vocab_size, (batch, seq))}
        if cfg.uses_tokens:
            b["tokens"] = rng.integers(0, cfg.vocab_size, (batch, seq))
        else:
            b["embeds"] = rng.normal(size=(batch, seq, cfg.frontend_dim)) \
                .astype("float32")
        b = {k: jax.numpy.asarray(v) for k, v in b.items()}

        @jax.jit
        def step(params, batch):
            loss, m = model.loss(params, batch)
            return loss

        try:
            step(params, b).block_until_ready()  # compile
        except Exception as e:
            raise MeasurementError(f"non-deployable: {e}")
        return Deployment(
            ident=f"walltime-{configuration.digest[:12]}",
            configuration=configuration, created_at=self.clock.time(),
            handle=(step, params, b),
            meta={"batch": batch, "seq": seq})

    def run(self, deployment: Deployment) -> Any:
        step, params, b = deployment.handle
        try:
            times = []
            for _ in range(self.repeats):
                t0 = self.clock.monotonic()
                step(params, b).block_until_ready()
                times.append(self.clock.monotonic() - t0)
        except Exception as e:
            raise MeasurementError(f"non-deployable: {e}")
        return min(times), deployment.meta

    def parse(self, raw: Any) -> Mapping[str, float]:
        best, meta = raw
        # a virtual clock can legitimately observe zero elapsed time
        best = max(best, 1e-9)
        return {"step_ms": best * 1e3,
                "tokens_per_s": meta["batch"] * meta["seq"] / best}


class WalltimeExperiment(LifecycleExperiment):
    """Compatibility shim: :class:`WalltimeConnector` behind the historical
    constructor/identity."""

    def __init__(self, arch: str, repeats: int = 3, compute_dtype="float32",
                 arch_scale: float = 1.0,
                 retry: Optional[RetryPolicy] = None,
                 pricing: Optional[PricingModel] = None,
                 clock: Clock = SYSTEM_CLOCK):
        super().__init__(
            WalltimeConnector(arch, repeats=repeats,
                              compute_dtype=compute_dtype,
                              arch_scale=arch_scale, clock=clock),
            retry=retry, pricing=pricing, clock=clock)
