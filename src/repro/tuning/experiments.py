"""Action-space experiments over deployment configurations.

* :class:`DryrunRooflineExperiment` — deploy = ``jit(step).lower().compile()``
  on the production mesh; measure = trip-corrected roofline terms from the
  compiled artifact (the honest measurement available on this CPU-only
  container; identical interface to a wall-clock experiment on real TPUs).
  Non-compiling or over-HBM configurations raise :class:`MeasurementError`
  — the paper's "non-deployable points".
* :class:`WalltimeExperiment` — real wall-clock timing of a reduced-config
  step on the local device (used by the optimizer benchmarks so that the
  paper-validation spaces contain genuinely *measured* data).

Both are hermetic: identity = (name, version, parameterization) where the
parameterization pins (arch, shape, mesh, hw) — so samples reconcile across
processes through the common context, and a different mesh or hardware is a
*different* Discovery Space (which is exactly what RSSC then bridges).
"""

from __future__ import annotations

import time
from typing import Any, Mapping, Optional, Sequence

from ..core.actions import Experiment, MeasurementError
from ..core.entities import Configuration
from ..roofline.hw import HWSpec, HW_V5E

__all__ = ["DryrunRooflineExperiment", "WalltimeExperiment"]


class DryrunRooflineExperiment(Experiment):
    name = "dryrun-roofline"
    version = "1"

    def __init__(self, arch: str, shape_name: str, mesh, hw: HWSpec = HW_V5E,
                 hbm_limit: Optional[float] = None):
        self.arch = arch
        self.shape_name = shape_name
        self.mesh = mesh
        self.hw = hw
        self.hbm_limit = hbm_limit

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return {"arch": self.arch, "shape": self.shape_name,
                "mesh": "x".join(map(str, self.mesh.devices.shape)),
                "hw": self.hw.name}

    @property
    def observed_properties(self) -> Sequence[str]:
        return ("compute_s", "memory_s", "collective_s", "step_time_s",
                "roofline_fraction", "hlo_flops", "bytes_per_device",
                "compile_s")

    def measure(self, configuration: Configuration) -> Mapping[str, float]:
        # imports deferred: this experiment requires the dry-run device env
        from ..configs import SHAPES, get_config
        from ..launch.dryrun import lower_cell, model_flops_for
        from ..roofline.analysis import analyze_compiled
        from .deployment import deployment_from_configuration

        cfg = get_config(self.arch)
        shape = SHAPES[self.shape_name]
        dep = deployment_from_configuration(
            configuration, cfg, self.mesh, shape_kind=shape.kind,
            global_batch=shape.global_batch, seq_len=shape.seq_len)
        t0 = time.time()
        try:
            with self.mesh:
                lowered, _ = lower_cell(self.arch, self.shape_name, self.mesh,
                                        dep)
                compiled = lowered.compile()
        except Exception as e:
            raise MeasurementError(f"non-deployable: {type(e).__name__}: {e}")
        compile_s = time.time() - t0
        chips = self.mesh.devices.size
        groups = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        report = analyze_compiled(
            compiled, self.arch, self.shape_name,
            "x".join(map(str, self.mesh.devices.shape)), chips, groups,
            model_flops=model_flops_for(cfg, shape), hw=self.hw)
        if (self.hbm_limit is not None and report.bytes_per_device is not None
                and report.bytes_per_device > self.hbm_limit):
            raise MeasurementError(
                f"over HBM: {report.bytes_per_device / 1e9:.1f} GB "
                f"> {self.hbm_limit / 1e9:.1f} GB")
        return self._report_properties(report, compile_s)

    @staticmethod
    def _report_properties(report, compile_s: float) -> Mapping[str, float]:
        out = {
            "compute_s": report.compute_s,
            "memory_s": report.memory_s,
            "collective_s": report.collective_s,
            "step_time_s": report.step_time_s,
            "roofline_fraction": report.roofline_fraction,
            "hlo_flops": report.hlo_flops,
            "compile_s": compile_s,
        }
        # A report without a byte count must OMIT bytes_per_device, never
        # record 0.0: a zero sentinel silently satisfies any memory SLA
        # (`bytes_per_device <= limit`), while constraint evaluation treats
        # a missing property as infeasible.  (NaN is no alternative —
        # sqlite3 binds float('nan') as NULL, corrupting the read path.)
        if report.bytes_per_device is not None:
            out["bytes_per_device"] = float(report.bytes_per_device)
        return out


class WalltimeExperiment(Experiment):
    """Wall-clock step timing of a reduced config on the local device(s).

    The configuration space maps to real compute knobs (batch, seq, chunk
    sizes, remat) — this produces genuinely measured performance surfaces
    for the optimizer/RSSC validation benchmarks.
    """

    name = "walltime"
    version = "1"

    def __init__(self, arch: str, repeats: int = 3, compute_dtype="float32",
                 arch_scale: float = 1.0):
        self.arch = arch
        self.repeats = repeats
        self.compute_dtype = compute_dtype
        self.arch_scale = arch_scale

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return {"arch": self.arch, "repeats": self.repeats,
                "scale": self.arch_scale, "dtype": str(self.compute_dtype)}

    @property
    def observed_properties(self) -> Sequence[str]:
        return ("step_ms", "tokens_per_s")

    def measure(self, configuration: Configuration) -> Mapping[str, float]:
        import jax
        import numpy as np

        from ..configs import get_config
        from ..models.attention import AttnOptions
        from ..models.blocks import ModelOptions
        from ..models.model import LMModel

        d = configuration.as_dict()
        batch = int(d.get("batch", 2))
        seq = int(d.get("seq", 64))
        q_chunk = int(d.get("attn_q_chunk", 64))
        remat = str(d.get("remat", "none"))
        cfg = get_config(self.arch, smoke=True)
        model = LMModel(cfg, ModelOptions(
            attn=AttnOptions(impl="xla", q_chunk=q_chunk, kv_chunk=q_chunk),
            remat=remat))
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        b = {"labels": rng.integers(0, cfg.vocab_size, (batch, seq))}
        if cfg.uses_tokens:
            b["tokens"] = rng.integers(0, cfg.vocab_size, (batch, seq))
        else:
            b["embeds"] = rng.normal(size=(batch, seq, cfg.frontend_dim)) \
                .astype("float32")
        b = {k: jax.numpy.asarray(v) for k, v in b.items()}

        @jax.jit
        def step(params, batch):
            loss, m = model.loss(params, batch)
            return loss

        try:
            step(params, b).block_until_ready()  # compile
            times = []
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                step(params, b).block_until_ready()
                times.append(time.perf_counter() - t0)
        except Exception as e:
            raise MeasurementError(f"non-deployable: {e}")
        best = min(times)
        return {"step_ms": best * 1e3,
                "tokens_per_s": batch * seq / best}
