"""The deployment Discovery Space: TPU deployment knobs as (P, Ω).

This is the direct analogue of the paper's cloud configuration spaces
(Table III): where the paper searched {GPU model, #GPUs, CPU cores, batch
limits}, the framework searches {sharding rules, remat policy, microbatches,
attention chunk sizes, MoE capacity, sequence sharding}.  Each architecture
family contributes its own dimensions (§Arch-applicability in DESIGN.md).

``deployment_space`` builds the ProbabilitySpace; ``deployment_from_
configuration`` maps a sampled Configuration back onto a DeploymentConfig so
the Action-space experiments (`experiments.py`) can deploy it.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Optional, Sequence

from ..core import Configuration, Dimension, ProbabilitySpace
from ..distributed.sharding import DeploymentConfig, default_deployment
from ..models.config import ModelConfig

__all__ = ["deployment_dimensions", "deployment_space",
           "deployment_from_configuration"]


def deployment_dimensions(cfg: ModelConfig, mesh, shape_kind: str = "train",
                          global_batch: int = 256) -> list:
    """Architecture- and shape-aware deployment dimensions."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axis_sizes.get("model", 1)
    dp = axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)
    local_batch = max(global_batch // dp, 1)

    dims = [
        Dimension.categorical("remat", ["none", "dots", "full"]),
        Dimension.discrete("attn_q_chunk", [256, 512, 1024]),
        Dimension.discrete("attn_kv_chunk", [256, 512, 1024]),
        Dimension.categorical("band_skip", [False, True]),
        Dimension.categorical("embed_rule", ["none", "data"]),
    ]
    if shape_kind == "train":
        micro_opts = sorted({m for m in (1, 2, 4, 8, 16)
                             if m <= local_batch and local_batch % m == 0})
        dims.append(Dimension.discrete("microbatches", micro_opts or [1]))
        dims.append(Dimension.categorical("param_cast",
                                          ["per_microbatch", "once"]))
    if cfg.num_experts:
        dims.append(Dimension.discrete(
            "moe_capacity_factor", [1.0, 1.25, 1.5, 2.0]))
        choices = ["replicate"]
        if cfg.num_experts % model_n == 0:
            choices.append("expert_parallel")
        f = cfg.moe_d_ff or cfg.d_ff
        if f % model_n == 0:
            choices.append("hidden_tp")
        dims.append(Dimension.categorical("moe_shard", choices))
    if cfg.family == "ssm":
        dims.append(Dimension.discrete("mlstm_chunk", [64, 128, 256]))
    return dims


def deployment_space(cfg: ModelConfig, mesh, shape_kind: str = "train",
                     global_batch: int = 256) -> ProbabilitySpace:
    return ProbabilitySpace.make(
        deployment_dimensions(cfg, mesh, shape_kind, global_batch))


def deployment_from_configuration(
        config: Configuration, cfg: ModelConfig, mesh,
        shape_kind: str = "train", global_batch: int = 256,
        seq_len: int = 4096) -> DeploymentConfig:
    """Materialize a sampled point of Ω as a DeploymentConfig."""
    dep = default_deployment(cfg, mesh, shape_kind=shape_kind,
                             global_batch=global_batch, seq_len=seq_len)
    updates = {}
    d = config.as_dict()
    if "remat" in d:
        updates["remat"] = d["remat"]
    if "microbatches" in d:
        updates["microbatches"] = int(d["microbatches"])
    if "attn_q_chunk" in d:
        updates["attn_q_chunk"] = int(d["attn_q_chunk"])
    if "attn_kv_chunk" in d:
        updates["attn_kv_chunk"] = int(d["attn_kv_chunk"])
    if "band_skip" in d:
        updates["band_skip"] = bool(d["band_skip"])
    if "moe_capacity_factor" in d:
        updates["moe_capacity_factor"] = float(d["moe_capacity_factor"])
    if "mlstm_chunk" in d:
        updates["mlstm_chunk"] = int(d["mlstm_chunk"])
    if "param_cast" in d:
        updates["cast_params_once"] = d["param_cast"] == "once"
    dep = replace(dep, **updates)
    if d.get("embed_rule") == "none":
        dep = dep.with_rule("embed", None)
    elif d.get("embed_rule") == "data":
        dep = dep.with_rule("embed", "data")
    moe_shard = d.get("moe_shard")
    if moe_shard == "replicate":
        dep = dep.with_rule("experts", None).with_rule("moe_mlp", None)
    elif moe_shard == "expert_parallel":
        dep = dep.with_rule("experts", "model").with_rule("moe_mlp", None)
    elif moe_shard == "hidden_tp":
        dep = dep.with_rule("experts", None).with_rule("moe_mlp", "model")
    return dep
