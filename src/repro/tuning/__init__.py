"""The paper's technique as a first-class framework feature: TPU deployment-
configuration search through Discovery Spaces."""

from .deployment import (deployment_dimensions, deployment_from_configuration,
                         deployment_space)
from .experiments import DryrunRooflineExperiment, WalltimeExperiment

__all__ = ["deployment_dimensions", "deployment_from_configuration",
           "deployment_space", "DryrunRooflineExperiment",
           "WalltimeExperiment"]
