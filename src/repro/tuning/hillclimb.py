"""§Perf hillclimbing driven by the framework's own Discovery Space search.

This is the paper's technique eating its own dogfood: the deployment space
of a (arch × shape) cell is a Discovery Space; the experiment is the dry-run
roofline measurement; the optimizers are the paper's optimizer suite; the
sample store is persistent, so successive hillclimb sessions (and different
optimizers) transparently reuse each other's compilations — incremental
sampling exactly as in paper Fig. 7, but over *compile minutes* instead of
cloud dollars.

``hillclimb_cell`` records:
  1. the paper-faithful BASELINE (default deployment) measurement,
  2. every (configuration → roofline terms) sample in the common context,
  3. the best configuration found and its terms,
returning a log suitable for EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import json
import os
import time
from typing import Optional

import numpy as np

from ..core import (ActionSpace, Configuration, DiscoverySpace, SampleStore)
from ..core.optimizers import OPTIMIZER_REGISTRY, run_optimizer
from ..core.rssc import rssc_transfer
from .deployment import deployment_space
from .experiments import DryrunRooflineExperiment

__all__ = ["baseline_configuration", "hillclimb_cell", "transfer_tuning"]


def baseline_configuration(space, cfg, mesh, shape) -> Configuration:
    """The default deployment expressed as a point of the deployment space."""
    from ..distributed.sharding import default_deployment

    dep = default_deployment(cfg, mesh, shape_kind=shape.kind,
                             global_batch=shape.global_batch,
                             seq_len=shape.seq_len)
    values = {}
    for dim in space.dimensions:
        if dim.name == "remat":
            values[dim.name] = dep.remat
        elif dim.name == "microbatches":
            m = dep.microbatches
            opts = [v for v in dim.values if v <= m]
            values[dim.name] = max(opts) if opts else dim.values[0]
        elif dim.name == "attn_q_chunk":
            values[dim.name] = dep.attn_q_chunk
        elif dim.name == "attn_kv_chunk":
            values[dim.name] = dep.attn_kv_chunk
        elif dim.name == "band_skip":
            values[dim.name] = dep.band_skip
        elif dim.name == "embed_rule":
            values[dim.name] = "data" if dep.rule("embed") == "data" else "none"
        elif dim.name == "moe_capacity_factor":
            values[dim.name] = dep.moe_capacity_factor
        elif dim.name == "moe_shard":
            if dep.rule("experts") == "model":
                values[dim.name] = "expert_parallel"
            elif dep.rule("moe_mlp") == "model":
                values[dim.name] = "hidden_tp"
            else:
                values[dim.name] = "replicate"
        elif dim.name == "mlstm_chunk":
            values[dim.name] = dep.mlstm_chunk
        elif dim.name == "param_cast":
            values[dim.name] = "once" if dep.cast_params_once \
                else "per_microbatch"
        else:  # pragma: no cover
            values[dim.name] = dim.values[0]
    return Configuration.make(values)


def hillclimb_cell(arch: str, shape_name: str, mesh, *,
                   optimizer: str = "tpe", trials: int = 14,
                   metric: str = "step_time_s",
                   store_path: Optional[str] = None,
                   hbm_limit: Optional[float] = None,
                   seed: int = 0, verbose: bool = True) -> dict:
    from ..configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    space = deployment_space(cfg, mesh, shape_kind=shape.kind,
                             global_batch=shape.global_batch)
    exp = DryrunRooflineExperiment(arch, shape_name, mesh,
                                   hbm_limit=hbm_limit)
    store = SampleStore(store_path or ":memory:")
    ds = DiscoverySpace(space=space, actions=ActionSpace.make([exp]),
                        store=store)

    # 1. paper-faithful baseline
    base_cfg = baseline_configuration(space, cfg, mesh, shape)
    t0 = time.time()
    base = ds.sample(base_cfg, operation_id=ds.begin_operation(
        "baseline", {"arch": arch, "shape": shape_name}))
    if verbose:
        print(f"[hillclimb] {arch} × {shape_name} baseline: "
              f"{metric}={base.value(metric):.4g}s "
              f"(compute={base.value('compute_s'):.4g} "
              f"memory={base.value('memory_s'):.4g} "
              f"collective={base.value('collective_s'):.4g}) "
              f"[{time.time() - t0:.0f}s]")

    # 2. search
    opt = OPTIMIZER_REGISTRY[optimizer](seed=seed)
    run = run_optimizer(opt, ds, metric, "min", max_trials=trials,
                        patience=max(trials // 2, 5),
                        rng=np.random.default_rng(seed))
    log = []
    for t in run.trials:
        entry = {"config": t.configuration.as_dict(), "action": t.action}
        if t.value is not None:
            s = ds.read_one(t.configuration)
            entry.update({metric: t.value,
                          "compute_s": s.value("compute_s"),
                          "memory_s": s.value("memory_s"),
                          "collective_s": s.value("collective_s"),
                          "roofline_fraction": s.value("roofline_fraction")})
        log.append(entry)
        if verbose and t.value is not None:
            print(f"  trial {entry['config']}: {metric}={t.value:.4g} "
                  f"({t.action})")
        elif verbose:
            print(f"  trial {entry['config']}: non-deployable")

    best = run.best
    best_sample = ds.read_one(best.configuration) if best else None
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "metric": metric,
        "baseline": {
            "config": base_cfg.as_dict(),
            metric: base.value(metric),
            "compute_s": base.value("compute_s"),
            "memory_s": base.value("memory_s"),
            "collective_s": base.value("collective_s"),
            "roofline_fraction": base.value("roofline_fraction"),
        },
        "best": None if best is None else {
            "config": best.configuration.as_dict(),
            metric: best.value,
            "compute_s": best_sample.value("compute_s"),
            "memory_s": best_sample.value("memory_s"),
            "collective_s": best_sample.value("collective_s"),
            "roofline_fraction": best_sample.value("roofline_fraction"),
        },
        "trials": log,
        "num_measured": run.num_measured,
        "num_reused": run.num_reused,
    }
    if best is not None and verbose:
        b, o = base.value(metric), best.value
        print(f"[hillclimb] best {metric}={o:.4g}s vs baseline {b:.4g}s "
              f"({100 * (1 - o / b):.1f}% better), reused "
              f"{run.num_reused}/{run.num_trials} samples")
    return result


def transfer_tuning(src_arch: str, dst_arch: str, shape_name: str, mesh, *,
                    store_path: Optional[str] = None, verbose: bool = True):
    """RSSC across architectures: reuse one arch's deployment-tuning samples
    to predict another's (identity mapping — the change is the experiment's
    arch parameter, i.e. the action space)."""
    from ..configs import SHAPES, get_config

    cfg_s = get_config(src_arch)
    cfg_d = get_config(dst_arch)
    shape = SHAPES[shape_name]
    store = SampleStore(store_path or ":memory:")
    space_s = deployment_space(cfg_s, mesh, shape.kind, shape.global_batch)
    space_d = deployment_space(cfg_d, mesh, shape.kind, shape.global_batch)
    if space_s.names != space_d.names:
        raise ValueError(f"deployment spaces differ: {space_s.names} vs "
                         f"{space_d.names} — pick same-family archs")
    ds_src = DiscoverySpace(space=space_s, actions=ActionSpace.make(
        [DryrunRooflineExperiment(src_arch, shape_name, mesh)]), store=store)
    ds_dst = DiscoverySpace(space=space_d, actions=ActionSpace.make(
        [DryrunRooflineExperiment(dst_arch, shape_name, mesh)]), store=store)
    res = rssc_transfer(ds_src, ds_dst, "step_time_s", mapping=None,
                        rng=np.random.default_rng(0), predict_remaining=True)
    if verbose:
        print(f"[transfer] {src_arch} → {dst_arch} ({shape_name}): "
              f"{res.summary()}")
    return res
