"""Native workload families: real in-repo workloads exposed as Discovery
Spaces (as opposed to the synthetic Table-III surfaces in
:mod:`repro.core.api.workloads`).

Each subpackage owns one workload family — a generator of *related*
configuration spaces plus the tiered connectors that measure them — and
registers its connector factories with the spec registry so the family is
reachable from JSON specs and the CLI.
"""
