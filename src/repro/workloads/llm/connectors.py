"""Tiered measurement connectors for the LLM deployment-space family.

Both tiers are phased through the actuation lifecycle
(:mod:`repro.core.connector`) and observe the same headline metrics
(``step_time_s``, ``tokens_per_s``) so their values live on one scale and a
space measured at the fast tier can seed §IV transfer into a slow-tier
sibling:

* :class:`LLMDryrunConnector` — the fast tier: scores a configuration with
  the analytic roofline cost model
  (:func:`~repro.roofline.estimate.estimate_deployment` — the closed-form
  counterpart of :class:`~repro.tuning.experiments.DryrunRooflineConnector`'s
  compiled-HLO path, same :class:`~repro.roofline.hw.HWSpec` constants, same
  max-of-terms step time).  Thousands of points per second, so a whole
  family member is measurable exhaustively.  A configuration whose HBM
  residency exceeds the chip is the paper's "non-deployable point":
  terminal :class:`~repro.core.actions.MeasurementError` at parse.
* :class:`LLMWalltimeConnector` — the slow tier: provisions the real model
  (smoke-scaled config) with the configuration's kernel variant and compute
  dtype, compiles the jitted train/serve step, and times it on the local
  devices.  A configuration whose mesh split wants more chips than the host
  has — or whose kernel fails to compile — is non-deployable here even when
  the cost model likes it, which is exactly the disagreement tiering exists
  to surface.

Identity: the per-member knobs (arch, kind, seq_len, devices, hw) live in
the connector *parameterization*, not in Ω — so two family members with
identical dimensions but different sequence lengths are distinct Discovery
Spaces in the catalog (the paper's FT-TRANS pattern), while the per-point
knobs (mesh split, sharding, batch, kernel, precision) are the dimensions
the search walks.  All phase timing runs on the injectable clock.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence, Union

from ...core.actions import MeasurementError
from ...core.clock import SYSTEM_CLOCK, Clock
from ...core.connector import Deployment, ExperimentConnector
from ...core.entities import Configuration
from ...launch.mesh import parse_mesh_split
from ...roofline.hw import HWSpec, HW_V4_LIKE, HW_V5E

__all__ = ["LLMDryrunConnector", "LLMWalltimeConnector", "resolve_hw",
           "KERNEL_IMPLS"]

_HW_BY_NAME = {hw.name: hw for hw in (HW_V5E, HW_V4_LIKE)}

#: kernel dimension value → repo attention implementation
KERNEL_IMPLS = {"ref": "ref", "xla": "xla", "flash": "pallas"}


def resolve_hw(hw: Union[str, HWSpec]) -> HWSpec:
    """Accept an :class:`HWSpec` or its JSON-friendly name."""
    if isinstance(hw, HWSpec):
        return hw
    if hw not in _HW_BY_NAME:
        raise ValueError(f"unknown hardware {hw!r} "
                         f"(known: {sorted(_HW_BY_NAME)})")
    return _HW_BY_NAME[hw]


def _decode(configuration: Configuration, devices: int) -> dict:
    """Validate and unpack a family configuration.  A mesh split that does
    not multiply out to the member's topology is the configuration's fault:
    terminal, never retried."""
    d = configuration.as_dict()
    data, model = parse_mesh_split(str(d["mesh"]))
    if data * model != devices:
        raise MeasurementError(
            f"non-deployable: mesh {d['mesh']} needs {data * model} chips "
            f"on a {devices}-chip topology")
    return {"data": data, "model": model,
            "sharding": str(d["sharding"]), "batch": int(d["batch"]),
            "kernel": str(d["kernel"]), "precision": str(d["precision"])}


class LLMDryrunConnector(ExperimentConnector):
    """Fast-tier analytic roofline scoring (see module docstring)."""

    name = "llm-dryrun"
    version = "1"

    def __init__(self, arch: str, seq_len: int, devices: int,
                 kind: str = "train", hw: Union[str, HWSpec] = HW_V5E,
                 hbm_fraction: float = 1.0, clock: Clock = SYSTEM_CLOCK):
        self.arch = arch
        self.seq_len = int(seq_len)
        self.devices = int(devices)
        self.kind = kind
        self.hw = resolve_hw(hw)
        self.hbm_fraction = float(hbm_fraction)
        self.clock = clock

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return {"arch": self.arch, "kind": self.kind, "seq": self.seq_len,
                "devices": self.devices, "hw": self.hw.name}

    @property
    def observed_properties(self) -> Sequence[str]:
        return ("step_time_s", "compute_s", "memory_s", "collective_s",
                "bytes_per_device", "hbm_resident_bytes", "tokens_per_s",
                "cost_per_1m_tokens")

    def provision(self, configuration: Configuration) -> Deployment:
        from ...configs import get_config  # deferred: pulls the model zoo
        decoded = _decode(configuration, self.devices)
        return Deployment(
            ident=f"llm-dryrun-{configuration.digest[:12]}",
            configuration=configuration, created_at=self.clock.time(),
            handle=(get_config(self.arch), decoded))

    def run(self, deployment: Deployment) -> Any:
        from ...roofline.estimate import estimate_deployment
        cfg, decoded = deployment.handle
        return estimate_deployment(
            cfg, seq_len=self.seq_len, batch_per_replica=decoded["batch"],
            data=decoded["data"], model=decoded["model"], kind=self.kind,
            sharding=decoded["sharding"], kernel=decoded["kernel"],
            precision=decoded["precision"], hw=self.hw)

    def parse(self, raw: Any) -> Mapping[str, float]:
        if not raw.fits_hbm(self.hbm_fraction):
            raise MeasurementError(
                f"over HBM: {raw.hbm_resident_bytes / 1e9:.1f} GB resident "
                f"> {self.hw.hbm_bytes * self.hbm_fraction / 1e9:.1f} GB")
        return raw.properties()


class LLMWalltimeConnector(ExperimentConnector):
    """Slow-tier timed microbench of the real model (see module docstring).

    ``devices`` defaults to 1 — the honest local topology; larger splits in
    Ω fail provisioning as non-deployable on this host.  ``smoke`` (default)
    uses the architecture's reduced config so the compile fits CI budgets.
    """

    name = "llm-walltime"
    version = "1"

    def __init__(self, arch: str, seq_len: int, devices: int = 1,
                 kind: str = "train", repeats: int = 3, smoke: bool = True,
                 clock: Clock = SYSTEM_CLOCK):
        self.arch = arch
        self.seq_len = int(seq_len)
        self.devices = int(devices)
        self.kind = kind
        self.repeats = int(repeats)
        self.smoke = bool(smoke)
        self.clock = clock

    @property
    def parameterization(self) -> Mapping[str, Any]:
        return {"arch": self.arch, "kind": self.kind, "seq": self.seq_len,
                "devices": self.devices, "repeats": self.repeats,
                "smoke": self.smoke}

    @property
    def observed_properties(self) -> Sequence[str]:
        return ("step_time_s", "tokens_per_s")

    def provision(self, configuration: Configuration) -> Deployment:
        import jax
        import jax.numpy as jnp
        import numpy as np

        from ...configs import get_config
        from ...models.attention import AttnOptions
        from ...models.blocks import ModelOptions
        from ...models.common import DTypePolicy
        from ...models.model import LMModel
        from ...roofline.estimate import PRECISION_BYTES

        decoded = _decode(configuration, self.devices)
        if self.devices > len(jax.devices()):
            raise MeasurementError(
                f"non-deployable: topology wants {self.devices} chips, "
                f"host has {len(jax.devices())}")
        if decoded["kernel"] not in KERNEL_IMPLS:
            raise MeasurementError(
                f"non-deployable: unknown kernel {decoded['kernel']!r}")
        cfg = get_config(self.arch, smoke=self.smoke)
        compute = (jnp.bfloat16 if decoded["precision"] == "bf16"
                   else jnp.float32)
        assert decoded["precision"] in PRECISION_BYTES
        chunk = max(16, min(self.seq_len, 128))
        model = LMModel(cfg, ModelOptions(
            attn=AttnOptions(impl=KERNEL_IMPLS[decoded["kernel"]],
                             q_chunk=chunk, kv_chunk=chunk, interpret=True),
            policy=DTypePolicy(param_dtype=jnp.float32,
                               compute_dtype=compute)))
        batch, seq = decoded["batch"], self.seq_len
        rng = np.random.default_rng(0)
        b = {}
        if cfg.uses_tokens:
            b["tokens"] = rng.integers(0, cfg.vocab_size, (batch, seq))
        else:
            b["embeds"] = rng.normal(
                size=(batch, seq, cfg.frontend_dim)).astype("float32")
        if self.kind == "train":
            b["labels"] = rng.integers(0, cfg.vocab_size, (batch, seq))
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params = model.init(jax.random.PRNGKey(0))

        if self.kind == "train":
            @jax.jit
            def step(params, batch):
                loss, _ = model.loss(params, batch)
                return loss
        else:
            # prefill/decode microbench: the forward pass over seq_len (the
            # decode-shaped single-token step needs a served cache; the
            # prefill-shaped forward is the slow-tier proxy for both)
            @jax.jit
            def step(params, batch):
                out = model.forward(params, batch)
                return out[0] if isinstance(out, tuple) else out

        try:
            jax.block_until_ready(step(params, b))  # compile
        except Exception as e:
            raise MeasurementError(f"non-deployable: {type(e).__name__}: {e}")
        return Deployment(
            ident=f"llm-walltime-{configuration.digest[:12]}",
            configuration=configuration, created_at=self.clock.time(),
            handle=(step, params, b), meta={"batch": batch, "seq": seq})

    def run(self, deployment: Deployment) -> Any:
        import jax
        step, params, b = deployment.handle
        try:
            times = []
            for _ in range(self.repeats):
                t0 = self.clock.monotonic()
                jax.block_until_ready(step(params, b))
                times.append(self.clock.monotonic() - t0)
        except Exception as e:
            raise MeasurementError(f"non-deployable: {e}")
        return min(times), deployment.meta

    def parse(self, raw: Any) -> Mapping[str, float]:
        best, meta = raw
        # a virtual clock can legitimately observe zero elapsed time
        best = max(best, 1e-9)
        return {"step_time_s": best,
                "tokens_per_s": meta["batch"] * meta["seq"] / best}
