"""The LLM deployment-space family: one generator, many related spaces.

A :class:`DeploymentSpaceFamily` turns any :mod:`repro.configs` model into
Discovery Spaces over the deployment knobs a serving/training team actually
searches:

    mesh shape × sharding strategy × per-replica batch × kernel variant
    × precision

parameterized by the *member knobs* — sequence length and device topology.
Every member of a family shares the same five dimension names and semantics
while the member knobs move, which is exactly the "related spaces" setup the
paper's §IV transfer machinery is built for:

* **seq-shift** (same topology, different sequence length): identical Ω —
  the FT-TRANS pattern, distinct spaces because the member knobs live in the
  experiment parameterization, related by an exact dimension match.
* **topology-shift** (different device count): the ``mesh`` dimension's
  labels change (``"1x4","2x2","4x1"`` → ``"1x8","2x4","8x1"``) but keep
  their cardinality and semantic order (TP-heavy → balanced → DP-heavy), so
  the catalog bridges them by positional rename inference (§IV-1).
* **tier-shift** (same member, dryrun → walltime): same Ω, different action
  space — the cheap tier's exhaustive measurements seed the expensive one.

The family also emits the catalog identity block (:meth:`family_meta`) that
marks its members as siblings, and a ready :class:`InvestigationSpec`
(:meth:`investigation_spec`) so a member is runnable from JSON via
``python -m repro.core.api run``.
"""

from __future__ import annotations

from typing import Optional, Union

from ...core.api.spec import (ConnectorSpec, InvestigationSpec, OptimizerSpec,
                              BudgetSpec, TransferSpec)
from ...core.discovery import DiscoverySpace
from ...core.entities import Dimension
from ...core.space import ProbabilitySpace
from ...launch.mesh import mesh_split_options
from ...roofline.hw import HWSpec, HW_V5E
from .connectors import LLMDryrunConnector, LLMWalltimeConnector, resolve_hw

__all__ = ["DeploymentSpaceFamily", "FAMILY_NAME"]

#: Catalog family identifier for spaces generated here.
FAMILY_NAME = "llm-deployment"

_TIERS = ("dryrun", "walltime")


class DeploymentSpaceFamily:
    """Generator of related deployment Discovery Spaces for one model.

    The constructor fixes the *family*: the model architecture, the workload
    kind, and the per-point value sets.  Member methods take the *member
    knobs* (``seq_len``, ``devices``) and yield that member's dimensions,
    probability space, connector, meta block, Discovery Space, or runnable
    investigation spec.
    """

    def __init__(self, arch: str, kind: str = "train",
                 batches: tuple = (1, 2, 4, 8),
                 shardings: tuple = ("replicate", "fsdp"),
                 kernels: tuple = ("ref", "xla", "flash"),
                 precisions: tuple = ("bf16", "fp32"),
                 hw: Union[str, HWSpec] = HW_V5E):
        from ...configs import get_config
        try:
            get_config(arch)  # includes extras like nano-100m
        except KeyError as e:
            raise ValueError(str(e))
        if kind not in ("train", "prefill", "decode"):
            raise ValueError(f"unknown workload kind {kind!r}")
        self.arch = arch
        self.kind = kind
        self.batches = tuple(int(b) for b in batches)
        self.shardings = tuple(shardings)
        self.kernels = tuple(kernels)
        self.precisions = tuple(precisions)
        self.hw = resolve_hw(hw)

    # ------------------------------------------------------------ the space

    def dimensions(self, devices: int) -> list:
        """The five deployment dimensions of the ``devices``-chip member.

        ``mesh`` values come from :func:`mesh_split_options`, which keeps
        cardinality and semantic order constant across power-of-two
        topologies ≥ 4 chips — the invariant topology-shift transfer relies
        on.  ``batch`` is per-replica and discrete (quantities, never
        positionally renamed); the rest are categorical.
        """
        return [
            Dimension.categorical("mesh", mesh_split_options(devices)),
            Dimension.categorical("sharding", self.shardings),
            Dimension.discrete("batch", self.batches),
            Dimension.categorical("kernel", self.kernels),
            Dimension.categorical("precision", self.precisions),
        ]

    def space(self, devices: int) -> ProbabilitySpace:
        """Ω of the ``devices``-chip member (uniform P)."""
        return ProbabilitySpace.make(self.dimensions(devices))

    # --------------------------------------------------------------- identity

    def family_meta(self, seq_len: int, devices: int, tier: str) -> dict:
        """The catalog meta block of one member.

        ``family`` is the sibling-identity block
        (:attr:`~repro.core.api.catalog.CatalogEntry.family` — equal across
        every member of this generator, whatever the member knobs); the
        member knobs ride alongside for human inspection and reporting.
        """
        if tier not in _TIERS:
            raise ValueError(f"unknown tier {tier!r} (known: {_TIERS})")
        return {
            "family": {"name": FAMILY_NAME, "arch": self.arch,
                       "kind": self.kind},
            "member": {"seq_len": int(seq_len), "devices": int(devices),
                       "tier": tier, "hw": self.hw.name},
        }

    # ------------------------------------------------------------ measurement

    def connector(self, seq_len: int, devices: int, tier: str = "dryrun",
                  **kwargs):
        """The member's measurement connector at the given tier."""
        if tier == "dryrun":
            return LLMDryrunConnector(self.arch, seq_len=seq_len,
                                      devices=devices, kind=self.kind,
                                      hw=self.hw, **kwargs)
        if tier == "walltime":
            return LLMWalltimeConnector(self.arch, seq_len=seq_len,
                                        devices=devices, kind=self.kind,
                                        **kwargs)
        raise ValueError(f"unknown tier {tier!r} (known: {_TIERS})")

    def member(self, seq_len: int, devices: int, tier: str = "dryrun",
               store=None, **kwargs) -> DiscoverySpace:
        """One member as a ready :class:`DiscoverySpace` (programmatic path;
        the spec path goes through :meth:`investigation_spec`).  ``kwargs``
        reach the connector (e.g. ``clock=``, ``hbm_fraction=``)."""
        from ...core.actions import ActionSpace
        from ...core.connector import LifecycleExperiment
        experiment = LifecycleExperiment(
            self.connector(seq_len, devices, tier, **kwargs))
        return DiscoverySpace(
            space=self.space(devices),
            actions=ActionSpace.make([experiment]),
            store=store,
            meta=self.family_meta(seq_len, devices, tier),
        )

    # ------------------------------------------------------------------ spec

    def connector_spec(self, seq_len: int, devices: int,
                       tier: str = "dryrun", **params) -> ConnectorSpec:
        """The member's measurement as a JSON-able :class:`ConnectorSpec`
        (factory reference + plain-JSON params — ``hw`` travels by name)."""
        if tier == "dryrun":
            p = {"arch": self.arch, "seq_len": int(seq_len),
                 "devices": int(devices), "kind": self.kind,
                 "hw": self.hw.name}
            p.update(params)
            return ConnectorSpec(factory="llm-dryrun", params=p)
        if tier == "walltime":
            p = {"arch": self.arch, "seq_len": int(seq_len),
                 "devices": int(devices), "kind": self.kind}
            p.update(params)
            return ConnectorSpec(factory="llm-walltime", params=p)
        raise ValueError(f"unknown tier {tier!r} (known: {_TIERS})")

    def investigation_spec(self, seq_len: int, devices: int,
                           tier: str = "dryrun",
                           metric: str = "step_time_s",
                           name: Optional[str] = None,
                           optimizer: str = "random", seed: int = 0,
                           max_trials: int = 30, patience: int = 10,
                           transfer: Optional[TransferSpec] = None,
                           store: Optional[str] = None,
                           **connector_params) -> InvestigationSpec:
        """A runnable declarative description of one member's search —
        everything :mod:`repro.core.api.cli` needs to execute it from JSON.
        """
        return InvestigationSpec(
            name=name or (f"{FAMILY_NAME}-{self.arch}-{self.kind}"
                          f"-s{seq_len}-d{devices}-{tier}"),
            space=self.space(devices),
            metric=metric,
            connectors=(self.connector_spec(seq_len, devices, tier,
                                            **connector_params),),
            optimizers=(OptimizerSpec(optimizer, seed=seed),),
            budget=BudgetSpec(max_trials=max_trials, patience=patience),
            transfer=transfer if transfer is not None else TransferSpec(),
            store=store,
            meta=self.family_meta(seq_len, devices, tier),
        )
