"""Self-measuring LLM deployment spaces (see :mod:`.family`)."""

from __future__ import annotations

from .connectors import (KERNEL_IMPLS, LLMDryrunConnector,
                         LLMWalltimeConnector, resolve_hw)
from .family import FAMILY_NAME, DeploymentSpaceFamily

__all__ = ["DeploymentSpaceFamily", "FAMILY_NAME", "LLMDryrunConnector",
           "LLMWalltimeConnector", "KERNEL_IMPLS", "resolve_hw"]
