"""Hardware constants for roofline terms (task-specified TPU v5e numbers)."""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["HWSpec", "HW_V5E", "HW_V4_LIKE"]


@dataclass(frozen=True)
class HWSpec:
    name: str
    peak_flops_bf16: float      # per chip, FLOP/s
    hbm_bw: float               # per chip, B/s
    ici_link_bw: float          # per link, B/s
    ici_links: int = 4          # usable links per chip in a 2-D torus
    hbm_bytes: float = 16e9
    price_per_chip_h: float = 1.2   # on-demand $/chip-hour (cost modeling)


HW_V5E = HWSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    ici_links=4,
    hbm_bytes=16e9,
    price_per_chip_h=1.2,
)

# A v4-like point used by the RSSC hardware-transfer experiment: same roofline
# structure, different constants.
HW_V4_LIKE = HWSpec(
    name="tpu-v4-like",
    peak_flops_bf16=275e12,
    hbm_bw=1228e9,
    ici_link_bw=45e9,
    ici_links=6,
    hbm_bytes=32e9,
    price_per_chip_h=3.2,
)
