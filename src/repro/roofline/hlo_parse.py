"""While-aware analyzer for optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while/scan body exactly ONCE
(verified empirically in tests/test_roofline.py) — useless for scan-over-
layers models where >95% of FLOPs live inside loops.  This module parses the
HLO text, recovers loop trip counts from the loop-condition comparison
constants, and accumulates per-device:

  * dot FLOPs              (2 · |output| · |contracting dims|, × trips)
  * collective bytes/kind  (result sizes × trips, with replica-group sizes)
  * approximate HBM traffic (op output + dot/fusion operand bytes, × trips)

recursively through ``fusion(..., calls=%c)`` and ``while(...,
condition=%c, body=%b)``.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

__all__ = ["HloAnalysis", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*?)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<type>.*?)\s*"
    r"(?P<opcode>[\w\-]+)\((?P<rest>.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_TRAFFIC = {"parameter", "get-tuple-element", "tuple", "bitcast",
                 "constant", "iota", "after-all", "partition-id",
                 "replica-id"}


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) across all array shapes in a type string."""
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    return _dims(m.group(2)) if m else []


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str


@dataclass
class _CompStats:
    flops: float = 0.0
    traffic: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    group_sizes: Dict[str, int] = field(default_factory=dict)


@dataclass
class HloAnalysis:
    flops: float
    traffic_bytes: float
    collectives: Dict[str, float]
    collective_counts: Dict[str, float]
    group_sizes: Dict[str, int]
    num_whiles: int
    trip_counts: List[int]


def _parse_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if current is None:
            m = _COMP_HDR_RE.match(line)
            if m and stripped.endswith("{") and "->" in line:
                current = m.group(1)
                comps[current] = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = comps[current]
            continue
        if stripped == "}":
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            comps[current].append(_Op(m.group("name"), m.group("type"),
                                      m.group("opcode"), m.group("rest")))
    return comps


def _dus_update_bytes(comp_ops: List[_Op]) -> Optional[int]:
    """In-place dynamic-update-slice fusions write only the update slice.

    Returns the update-operand byte count when the fusion contains a DUS
    whose buffer shape matches the fusion output (XLA aliases these buffers
    in place; any trailing whole-buffer ``convert`` is an XLA:CPU
    bf16-emulation artifact that native-bf16 TPUs do not pay)."""
    if not comp_ops:
        return None
    symtab = {op.name: op.type_str for op in comp_ops}
    root = comp_ops[-1]
    root_dims = _first_shape_dims(root.type_str)
    for op in comp_ops:
        if op.opcode != "dynamic-update-slice":
            continue
        if _first_shape_dims(op.type_str) != root_dims:
            continue
        operands = _OPERAND_RE.findall(op.rest)
        if len(operands) < 2:
            continue
        nbytes = _shape_elems_bytes(symtab.get(operands[1], ""))[1]
        if nbytes:
            return nbytes
    return None


def _group_size(rest: str, total_devices: int) -> int:
    m = _GROUPS_BRACE_RE.search(rest)
    if m:
        return len(_dims(m.group(1)))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return total_devices


def analyze_hlo(text: str, total_devices: int = 1) -> HloAnalysis:
    comps = _parse_computations(text)
    trip_counts: List[int] = []
    memo: Dict[str, _CompStats] = {}

    def shape_of(comp_ops: List[_Op]) -> Dict[str, str]:
        return {op.name: op.type_str for op in comp_ops}

    def trip_count_of(cond_name: str) -> int:
        ops = comps.get(cond_name, [])
        consts = []
        for op in ops:
            if op.opcode == "constant" or "constant(" in op.rest:
                consts.extend(int(c) for c in _CONST_RE.findall(
                    op.type_str + " " + op.opcode + "(" + op.rest))
            consts.extend(int(c) for c in _CONST_RE.findall(op.rest))
        # the loop bound is compared against the induction variable; take the
        # largest s32 constant in the condition computation
        return max(consts) if consts else 1

    def analyze(name: str) -> _CompStats:
        if name in memo:
            return memo[name]
        stats = _CompStats()
        memo[name] = stats  # break cycles defensively
        ops = comps.get(name, [])
        symtab = shape_of(ops)
        for op in ops:
            out_elems, out_bytes = _shape_elems_bytes(op.type_str)
            opcode = op.opcode
            if opcode == "dot":
                operands = _OPERAND_RE.findall(op.rest)
                lhs_shape = _first_shape_dims(symtab.get(operands[0], "")) \
                    if operands else []
                mc = _LHS_CONTRACT_RE.search(op.rest)
                contract = 1
                if mc and lhs_shape:
                    for idx in _dims(mc.group(1)):
                        if idx < len(lhs_shape):
                            contract *= lhs_shape[idx]
                stats.flops += 2.0 * out_elems * contract
                # dot operands stream from memory
                for o in operands[:2]:
                    stats.traffic += _shape_elems_bytes(symtab.get(o, ""))[1]
                stats.traffic += out_bytes
            elif opcode == "fusion":
                written = out_bytes
                mcalls = _CALLS_RE.search(op.rest)
                if mcalls:
                    callee = mcalls.group(1)
                    inner = analyze(callee)
                    stats.flops += inner.flops
                    for k, v in inner.collectives.items():
                        stats.collectives[k] = stats.collectives.get(k, 0) + v
                    for k, v in inner.collective_counts.items():
                        stats.collective_counts[k] = \
                            stats.collective_counts.get(k, 0) + v
                    for k, g in inner.group_sizes.items():
                        stats.group_sizes[k] = max(stats.group_sizes.get(k, 1), g)
                    # in-place dynamic-update-slice fusions write only the
                    # update slice, not the whole aliased buffer
                    dus = _dus_update_bytes(comps.get(callee, []))
                    if dus is not None:
                        written = dus
                # fusion boundary traffic: bytes actually written.  Operands
                # are NOT summed — a dynamic-slice fusion lists the whole
                # stacked scan parameter as operand but reads one slice per
                # trip; producer outputs were counted where produced.
                stats.traffic += written
            elif opcode == "dynamic-update-slice":
                operands = _OPERAND_RE.findall(op.rest)
                upd = symtab.get(operands[1], "") if len(operands) > 1 else ""
                stats.traffic += _shape_elems_bytes(upd)[1] or out_bytes
            elif opcode == "while":
                mcond = _COND_RE.search(op.rest)
                mbody = _BODY_RE.search(op.rest)
                trips = trip_count_of(mcond.group(1)) if mcond else 1
                trip_counts.append(trips)
                if mbody:
                    inner = analyze(mbody.group(1))
                    stats.flops += trips * inner.flops
                    stats.traffic += trips * inner.traffic
                    for k, v in inner.collectives.items():
                        stats.collectives[k] = \
                            stats.collectives.get(k, 0) + trips * v
                    for k, v in inner.collective_counts.items():
                        stats.collective_counts[k] = \
                            stats.collective_counts.get(k, 0) + trips * v
                    for k, g in inner.group_sizes.items():
                        stats.group_sizes[k] = max(stats.group_sizes.get(k, 1), g)
            elif any(opcode.startswith(c) for c in COLLECTIVES):
                if opcode.endswith("-done"):
                    continue
                kind = next(c for c in COLLECTIVES if opcode.startswith(c))
                stats.collectives[kind] = stats.collectives.get(kind, 0) + out_bytes
                stats.collective_counts[kind] = \
                    stats.collective_counts.get(kind, 0) + 1
                g = _group_size(op.rest, total_devices)
                stats.group_sizes[kind] = max(stats.group_sizes.get(kind, 1), g)
                stats.traffic += out_bytes
            elif opcode in ("call", "conditional", "custom-call", "async-start"):
                callees = _CALLS_RE.findall(op.rest) + \
                    re.findall(r"to_apply=%?([\w.\-]+)", op.rest) + \
                    re.findall(r"(?:true|false)_computation=%?([\w.\-]+)", op.rest)
                for callee in callees:
                    inner = analyze(callee)
                    stats.flops += inner.flops
                    stats.traffic += inner.traffic
                    for k, v in inner.collectives.items():
                        stats.collectives[k] = stats.collectives.get(k, 0) + v
                    for k, v in inner.collective_counts.items():
                        stats.collective_counts[k] = \
                            stats.collective_counts.get(k, 0) + v
                    for k, g in inner.group_sizes.items():
                        stats.group_sizes[k] = max(stats.group_sizes.get(k, 1), g)
            elif opcode in _SKIP_TRAFFIC:
                continue
            else:
                # copies, converts, reduces, dynamic slices at computation level
                stats.traffic += out_bytes
            # group sizes float up
        return stats

    entry = analyze("__entry__") if "__entry__" in comps else _CompStats()
    return HloAnalysis(
        flops=entry.flops,
        traffic_bytes=entry.traffic,
        collectives=dict(entry.collectives),
        collective_counts=dict(entry.collective_counts),
        group_sizes=dict(entry.group_sizes),
        num_whiles=len(trip_counts),
        trip_counts=trip_counts,
    )
