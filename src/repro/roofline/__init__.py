"""Roofline accounting from compiled dry-run artifacts."""

from .analysis import (RooflineReport, analyze_compiled, collective_bytes,
                       roofline_terms, xla_cost_analysis)
from .hw import HW_V5E, HWSpec

__all__ = ["RooflineReport", "analyze_compiled", "collective_bytes",
           "roofline_terms", "xla_cost_analysis", "HW_V5E", "HWSpec"]
