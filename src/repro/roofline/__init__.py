"""Roofline accounting from compiled dry-run artifacts."""

from .analysis import (RooflineReport, analyze_compiled, collective_bytes,
                       roofline_terms)
from .hw import HW_V5E, HWSpec

__all__ = ["RooflineReport", "analyze_compiled", "collective_bytes",
           "roofline_terms", "HW_V5E", "HWSpec"]
