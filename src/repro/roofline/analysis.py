"""Roofline terms from compiled XLA artifacts.

* ``compute_s``    = HLO_FLOPs / peak_FLOP/s                 (per chip)
* ``memory_s``     = HLO_bytes / HBM_bw                      (per chip)
* ``collective_s`` = Σ_kind ring_factor·bytes / (link_bw × links)

HLO_FLOPs / HLO_bytes: XLA's ``compiled.cost_analysis()`` counts while/scan
bodies exactly ONCE (verified in tests/test_roofline.py), which misses >95%
of the work in scan-over-layers models.  We therefore parse the optimized
(post-SPMD) HLO text with a while-aware analyzer (``hlo_parse.py``) that
scales dot FLOPs, HBM traffic, and collective bytes by recovered loop trip
counts.  Both the raw cost_analysis numbers and the trip-corrected numbers
are reported; the roofline terms use the corrected ones.

Collective bytes are NOT in cost_analysis at all — they come from the parser
(summed result sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, × trips), converted to per-device ICI
traffic with per-kind ring factors and the instruction's replica-group size.
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from .hlo_parse import HloAnalysis, analyze_hlo
from .hw import HWSpec, HW_V5E

__all__ = ["collective_bytes", "roofline_terms", "RooflineReport",
           "analyze_compiled"]


def collective_bytes(hlo_text: str, total_devices: int = 1) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind (trip-corrected)."""
    return dict(analyze_hlo(hlo_text, total_devices).collectives)


def _ring_factor(kind: str, group: int) -> float:
    """Per-device ICI traffic of one collective as a fraction of the
    instruction's RESULT size, ring algorithm over `group` devices."""
    if group <= 1:
        return 0.0
    if kind == "all-gather":
        return (group - 1) / group          # result = gathered tensor
    if kind == "reduce-scatter":
        return (group - 1)                  # result = scattered shard
    if kind == "all-reduce":
        return 2 * (group - 1) / group      # RS + AG over the full tensor
    if kind == "all-to-all":
        return (group - 1) / group
    if kind == "collective-permute":
        return 1.0
    return 1.0


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                 # per-device, trip-corrected
    hlo_bytes: float                 # per-device HBM traffic, trip-corrected
    raw_flops: float                 # cost_analysis (scan bodies once)
    raw_bytes: float
    collective: Dict[str, float]     # per-device result bytes by kind
    collective_counts: Dict[str, float]
    group_sizes: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float               # 6·N·D (or 6·N_active·D) GLOBAL
    useful_ratio: float              # model_flops / (hlo_flops · chips)
    bytes_per_device: Optional[float] = None
    num_whiles: int = 0
    hw: str = "tpu-v5e"

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time: max of the three terms (perfect
        overlap) — the optimistic bound."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOP/s at the modeled step time vs. cluster peak."""
        if self.step_time_s <= 0:
            return 0.0
        achieved = self.model_flops / self.step_time_s
        return achieved / (self.chips * HW_V5E.peak_flops_bf16)

    def summary(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": float(f"{self.compute_s:.5g}"),
            "memory_s": float(f"{self.memory_s:.5g}"),
            "collective_s": float(f"{self.collective_s:.5g}"),
            "dominant": self.dominant,
            "useful_ratio": round(min(self.useful_ratio, 99.0), 4),
            "roofline_fraction": round(self.roofline_fraction, 4),
            "bytes_per_device": self.bytes_per_device,
        }


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collectives: Dict[str, float],
                   group_sizes: Dict[str, int],
                   hw: HWSpec = HW_V5E):
    compute_s = hlo_flops / hw.peak_flops_bf16
    memory_s = hlo_bytes / hw.hbm_bw
    coll_bytes = 0.0
    for kind, nbytes in collectives.items():
        group = group_sizes.get(kind, 1)
        coll_bytes += nbytes * _ring_factor(kind, group)
    collective_s = coll_bytes / (hw.ici_link_bw * hw.ici_links)
    return compute_s, memory_s, collective_s


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized across jaxlib versions.

    Older jaxlib returns a one-element list of dicts (one per partition),
    newer jaxlib returns the dict directly; either way callers get a dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze_compiled(compiled, arch: str, shape: str, mesh_desc: str,
                     chips: int, mesh_groups: Dict[str, int],
                     model_flops: float, hw: HWSpec = HW_V5E,
                     hlo_text: Optional[str] = None) -> RooflineReport:
    cost = xla_cost_analysis(compiled)
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hlo = analyze_hlo(text, total_devices=chips)
    # trip-corrected numbers can only add work relative to raw
    hlo_flops = max(hlo.flops, raw_flops)
    hlo_bytes = max(hlo.traffic_bytes, 0.0)

    compute_s, memory_s, collective_s = roofline_terms(
        hlo_flops, hlo_bytes, hlo.collectives, hlo.group_sizes, hw)

    bytes_per_device = None
    try:
        mem = compiled.memory_analysis()
        args = getattr(mem, "argument_size_in_bytes", 0)
        out = getattr(mem, "output_size_in_bytes", 0)
        tmp = getattr(mem, "temp_size_in_bytes", 0)
        alias = getattr(mem, "alias_size_in_bytes", 0)
        bytes_per_device = float(args + out + tmp - alias)
    except Exception:  # pragma: no cover
        pass

    useful = model_flops / max(hlo_flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        raw_flops=raw_flops, raw_bytes=raw_bytes,
        collective=hlo.collectives, collective_counts=hlo.collective_counts,
        group_sizes=hlo.group_sizes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=model_flops, useful_ratio=useful,
        bytes_per_device=bytes_per_device, num_whiles=hlo.num_whiles,
        hw=hw.name,
    )
