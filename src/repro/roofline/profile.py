"""Top-contributor profiling over compiled HLO (the dry-run 'profiler').

Given compiled HLO text, attribute trip-scaled FLOPs and HBM traffic to
individual ops, so §Perf iterations can target the dominant roofline term's
largest contributors (the CPU-container analogue of reading an XProf trace).
"""

from __future__ import annotations

import re
from typing import List, Tuple

from . import hlo_parse as hp

__all__ = ["top_traffic", "top_flops"]


def _multipliers(comps):
    """(trip multipliers, fusion-internal computation names).

    Fusion-internal ops stay in registers — they are excluded from traffic
    attribution (only the fusion boundary moves HBM bytes)."""
    mult = {"__entry__": 1.0}
    fusion_internal = set()

    def walk(name, m):
        for op in comps.get(name, []):
            if op.opcode in ("fusion", "call"):
                mc = hp._CALLS_RE.search(op.rest)
                if mc:
                    mult[mc.group(1)] = mult.get(mc.group(1), 0) + m
                    if op.opcode == "fusion":
                        fusion_internal.add(mc.group(1))
                    walk(mc.group(1), m)
            elif op.opcode == "while":
                mb = hp._BODY_RE.search(op.rest)
                mcnd = hp._COND_RE.search(op.rest)
                trips = 1
                if mcnd:
                    consts = []
                    for o in comps.get(mcnd.group(1), []):
                        consts += [int(c) for c in hp._CONST_RE.findall(
                            o.type_str + " " + o.opcode + "(" + o.rest)]
                    trips = max(consts) if consts else 1
                if mb:
                    mult[mb.group(1)] = mult.get(mb.group(1), 0) + m * trips
                    walk(mb.group(1), m * trips)

    walk("__entry__", 1.0)
    return mult, fusion_internal


def _op_traffic(op, symtab, comps) -> float:
    if op.opcode in hp._SKIP_TRAFFIC:
        return 0.0
    _, ob = hp._shape_elems_bytes(op.type_str)
    if op.opcode == "fusion":
        mc = hp._CALLS_RE.search(op.rest)
        dus = hp._dus_update_bytes(comps.get(mc.group(1), [])) if mc else None
        return float(dus if dus is not None else ob)
    if op.opcode == "dynamic-update-slice":
        opr = hp._OPERAND_RE.findall(op.rest)
        if len(opr) > 1:
            return float(hp._shape_elems_bytes(symtab.get(opr[1], ""))[1] or ob)
    if op.opcode == "dot":
        opr = hp._OPERAND_RE.findall(op.rest)
        extra = sum(hp._shape_elems_bytes(symtab.get(o, ""))[1]
                    for o in opr[:2])
        return float(ob + extra)
    if op.opcode == "while":
        return 0.0  # attributed to body ops
    return float(ob)


def top_traffic(hlo_text: str, k: int = 12) -> List[Tuple[float, str, str, str]]:
    """[(bytes_total, opcode, computation, op metadata)] sorted desc."""
    comps = hp._parse_computations(hlo_text)
    mult, fusion_internal = _multipliers(comps)
    rows = []
    for name, ops in comps.items():
        if name == "__entry__" or name in fusion_internal:
            continue
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        symtab = {op.name: op.type_str for op in ops}
        for op in ops:
            b = _op_traffic(op, symtab, comps)
            if b <= 0:
                continue
            meta = re.search(r'op_name="([^"]+)"', op.rest)
            rows.append((b * m, op.opcode, name,
                         (meta.group(1)[-90:] if meta else op.name)))
    rows.sort(key=lambda r: -r[0])
    return rows[:k]


def top_flops(hlo_text: str, k: int = 12) -> List[Tuple[float, str, str]]:
    comps = hp._parse_computations(hlo_text)
    mult, _fusion_internal = _multipliers(comps)
    rows = []
    for name, ops in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        symtab = {op.name: op.type_str for op in ops}
        for op in ops:
            if op.opcode != "dot":
                continue
            out_elems, _ = hp._shape_elems_bytes(op.type_str)
            opr = hp._OPERAND_RE.findall(op.rest)
            lhs = hp._first_shape_dims(symtab.get(opr[0], "")) if opr else []
            mc = hp._LHS_CONTRACT_RE.search(op.rest)
            contract = 1
            if mc and lhs:
                for idx in hp._dims(mc.group(1)):
                    if idx < len(lhs):
                        contract *= lhs[idx]
            meta = re.search(r'op_name="([^"]+)"', op.rest)
            rows.append((2.0 * out_elems * contract * m, name,
                         (meta.group(1)[-90:] if meta else op.name)))
    rows.sort(key=lambda r: -r[0])
    return rows[:k]
