"""Closed-form roofline estimation for LLM deployment configurations.

The analytic counterpart of :func:`repro.roofline.analysis.analyze_compiled`:
where that path prices a *compiled* HLO module (per-instruction FLOP/byte
counts), this one prices a deployment configuration directly from the
architecture's analytic parameter counts — no device, no lowering, no
compile.  That makes it the fast measurement tier of the LLM workload family
(:mod:`repro.workloads.llm`): thousands of (mesh × sharding × batch × kernel
× precision) points per second, sharing the same :class:`~repro.roofline.hw.
HWSpec` constants and the same max-of-terms roofline semantics as the
measured path, so values from the two tiers live on one scale.

Cost model, per device per step:

* **compute** — ``2·N_active·D`` matmul FLOPs (the
  :func:`~repro.launch.dryrun.model_flops_for` convention: embedding-table
  lookups excluded, ×3 for the backward pass) plus the explicit attention
  score/apply FLOPs that N·D misses at long sequence, against the precision-
  scaled peak.
* **memory** — weight streaming (sharded over the model axis), optimizer
  update traffic (fp32, additionally sharded over data under ``fsdp``),
  residual-stream activation traffic, KV-cache reads for serve kinds, and
  attention score traffic scaled by the kernel variant's materialization
  passes (``ref`` spills full score tiles, ``xla`` chunks them, ``flash``
  keeps them on-chip).
* **collective** — ring all-reduces of TP activations per layer, and the
  data-parallel gradient exchange (all-reduce when replicated, reduce-scatter
  + param all-gather under ``fsdp``), over the ICI links.

The estimate also carries an HBM *residency* footprint (params + optimizer
states + gradients + KV cache + live activations); a configuration whose
footprint exceeds the chip's HBM is the paper's "non-deployable point" and
is rejected by the measuring connector, not here — the estimator itself is
judgement-free arithmetic.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..models.config import ModelConfig
from .hw import HWSpec, HW_V5E

__all__ = ["RooflineEstimate", "estimate_deployment",
           "PRECISION_BYTES", "KERNEL_SCORE_PASSES"]

#: compute-dtype width per supported precision dimension value
PRECISION_BYTES = {"bf16": 2.0, "fp32": 4.0}

#: attention score-matrix HBM materialization passes per kernel variant:
#: ``ref`` writes and re-reads the full S×S_kv scores around the softmax,
#: ``xla`` chunks them (one spill pass), ``flash`` streams tiles on-chip
#: and only pays for the running max/sum statistics.
KERNEL_SCORE_PASSES = {"ref": 4.0, "xla": 2.0, "flash": 0.25}


def _ring(group: int, factor: float = 2.0) -> float:
    """Per-device wire-byte multiplier of a ring collective over ``group``
    devices: all-reduce moves ``2(g-1)/g`` × payload, all-gather and
    reduce-scatter ``(g-1)/g`` (pass ``factor=1.0``)."""
    if group <= 1:
        return 0.0
    return factor * (group - 1) / group


@dataclass(frozen=True)
class RooflineEstimate:
    """Analytic per-step roofline terms for one deployment configuration."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float        # HBM traffic per step, per device
    hbm_resident_bytes: float      # capacity footprint, per device
    tokens_per_step: float         # new tokens processed globally per step
    chips: int
    hw: HWSpec

    @property
    def step_time_s(self) -> float:
        """Max of the three terms (perfect overlap) — the same optimistic
        bound as :class:`~repro.roofline.analysis.RooflineReport`."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_per_step / self.step_time_s

    @property
    def cost_per_1m_tokens(self) -> float:
        """Fleet dollars per million new tokens at the hardware's on-demand
        chip-hour price."""
        per_s = self.chips * self.hw.price_per_chip_h / 3600.0
        return per_s / self.tokens_per_s * 1e6

    def fits_hbm(self, fraction: float = 1.0) -> bool:
        return self.hbm_resident_bytes <= self.hw.hbm_bytes * fraction

    def properties(self) -> dict:
        """The measurement-record view (what a connector's parse returns)."""
        return {
            "step_time_s": self.step_time_s,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bytes_per_device": self.bytes_per_device,
            "hbm_resident_bytes": self.hbm_resident_bytes,
            "tokens_per_s": self.tokens_per_s,
            "cost_per_1m_tokens": self.cost_per_1m_tokens,
        }


def estimate_deployment(
    cfg: ModelConfig,
    *,
    seq_len: int,
    batch_per_replica: int,
    data: int = 1,
    model: int = 1,
    kind: str = "train",
    sharding: str = "replicate",
    kernel: str = "xla",
    precision: str = "bf16",
    hw: HWSpec = HW_V5E,
) -> RooflineEstimate:
    """Estimate the per-step roofline of ``cfg`` deployed on a
    ``data × model`` mesh (see module docstring for the cost model).

    ``batch_per_replica`` is the batch per data-parallel replica (the global
    batch is ``batch_per_replica × data``); ``kind`` follows the repo's
    shape kinds (``train`` = loss step over ``seq_len``, ``prefill`` =
    forward over ``seq_len``, ``decode`` = one new token over a ``seq_len``
    KV cache); ``sharding`` ∈ {replicate, fsdp} places parameters and
    optimizer state; ``kernel`` ∈ {ref, xla, flash} and ``precision`` ∈
    {bf16, fp32} select the attention variant and compute dtype.
    """
    if kind not in ("train", "prefill", "decode"):
        raise ValueError(f"unknown kind {kind!r}")
    if sharding not in ("replicate", "fsdp"):
        raise ValueError(f"unknown sharding {sharding!r}")
    if kernel not in KERNEL_SCORE_PASSES:
        raise ValueError(f"unknown kernel {kernel!r} "
                         f"(known: {sorted(KERNEL_SCORE_PASSES)})")
    if precision not in PRECISION_BYTES:
        raise ValueError(f"unknown precision {precision!r} "
                         f"(known: {sorted(PRECISION_BYTES)})")

    chips = data * model
    bytes_c = PRECISION_BYTES[precision]
    # bf16 runs the MXU at full rate; fp32 at half
    peak = hw.peak_flops_bf16 * 2.0 / bytes_c
    train = kind == "train"

    d_model = cfg.d_model
    heads, kv_heads, head_dim = (cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim)
    layers = cfg.num_layers
    kv_layers = sum(stage.repeat
                    * sum(1 for s in stage.superblock if s.has_kv_cache)
                    for stage in cfg.stages)
    n_total = float(cfg.param_count())
    n_matmul = float(cfg.active_param_count())
    if cfg.uses_tokens:  # embedding lookups are gathers, not matmuls
        n_matmul -= cfg.vocab_size * d_model

    # -- tokens ----------------------------------------------------------
    kv_len = seq_len
    new_tokens_per_replica = (batch_per_replica if kind == "decode"
                              else batch_per_replica * seq_len)
    tokens_global = float(new_tokens_per_replica * data)
    # activation rows live on every device of a model group (TP shards
    # features, not tokens)
    tokens_local = float(new_tokens_per_replica)

    # -- compute ---------------------------------------------------------
    fwd_factor = 3.0 if train else 1.0
    flops = fwd_factor * 2.0 * n_matmul * tokens_global
    # attention score+apply FLOPs (4·T·span·d_attn per kv layer; causal
    # masking halves the visible span for train/prefill)
    span = kv_len * (1.0 if kind == "decode" else 0.5)
    flops += fwd_factor * kv_layers * 4.0 * tokens_global * span \
        * (heads * head_dim)
    flops_per_device = flops / chips
    compute_s = flops_per_device / peak

    # -- memory traffic --------------------------------------------------
    param_shard = model * (data if (train and sharding == "fsdp") else 1)
    weight_stream = n_total * bytes_c / model
    traffic = weight_stream * (3.0 if train else 1.0)  # fwd + bwd + grads
    if train:
        # fp32 master params + two Adam moments, read and written
        traffic += 6.0 * n_total * 4.0 / param_shard
    # residual-stream activations: ~16 reads/writes of the hidden state per
    # layer forward, doubled for the backward pass
    traffic += layers * tokens_local * d_model * bytes_c \
        * 16.0 * (2.0 if train else 1.0)
    # attention score materialization, kernel-dependent (bwd recompute ×2.5)
    q_rows = 1.0 if kind == "decode" else float(seq_len)
    score_bytes = batch_per_replica * (heads / model) * q_rows * kv_len \
        * bytes_c
    traffic += kv_layers * KERNEL_SCORE_PASSES[kernel] * score_bytes \
        * (2.5 if train else 1.0)
    kv_cache_bytes = (batch_per_replica * kv_len * 2.0 * kv_heads * head_dim
                      * bytes_c * kv_layers / model)
    if kind != "train":
        traffic += kv_cache_bytes  # streamed once per serve step
    memory_s = traffic / hw.hbm_bw

    # -- collectives -----------------------------------------------------
    wire = 0.0
    if model > 1:
        # two TP activation all-reduces per layer (mixer out, FFN out)
        payload = tokens_local * d_model * bytes_c
        wire += 2.0 * layers * _ring(model) * payload \
            * (2.0 if train else 1.0)
    if train and data > 1:
        grads = n_total * 4.0 / model
        if sharding == "fsdp":
            wire += _ring(data, 1.0) * grads                    # reduce-scatter
            wire += _ring(data, 1.0) * n_total * bytes_c / model  # all-gather
        else:
            wire += _ring(data) * grads                         # all-reduce
    collective_s = wire / (hw.ici_link_bw * hw.ici_links)

    # -- HBM residency ---------------------------------------------------
    if train:
        # fp32 master + 2 moments (sharded per `sharding`) + gradients
        resident = 12.0 * n_total / param_shard \
            + 4.0 * n_total / param_shard
        resident += 2.0 * layers * tokens_local * d_model * bytes_c  # stashes
    else:
        resident = n_total * bytes_c / model
        resident += 4.0 * tokens_local * d_model * bytes_c
        resident += kv_cache_bytes
    return RooflineEstimate(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        flops_per_device=flops_per_device,
        bytes_per_device=traffic,
        hbm_resident_bytes=resident,
        tokens_per_step=tokens_global,
        chips=chips,
        hw=hw,
    )
