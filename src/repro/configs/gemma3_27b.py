"""gemma3-27b — 5:1 local:global, 128k [hf:google/gemma-3-1b-pt; unverified].

62 layers: 10 superblocks of (5 × local window-1024 + 1 global) + 2 trailing
local layers.  head_dim fixed at 128 (q width ≠ d_model).  The 5:1 pattern
makes the KV cache ~6x cheaper at 32k, but global layers are full attention
over the whole context => treated as full-attention for long_500k (skipped;
see DESIGN.md).
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

LOCAL = LayerSpec(kind="attn", window=1024)
GLOBAL = LayerSpec(kind="attn", window=None)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    stages=(
        Stage(superblock=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL), repeat=10),
        Stage(superblock=(LOCAL, LOCAL), repeat=1),
    ),
    notes="global layers full-attention: long_500k skipped",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke",
        family="dense",
        num_layers=8,
        d_model=96,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=192,
        vocab_size=512,
        stages=(
            Stage(superblock=(LayerSpec(kind="attn", window=16),) * 5
                  + (GLOBAL,), repeat=1),
            Stage(superblock=(LayerSpec(kind="attn", window=16),) * 2, repeat=1),
        ),
    )
