"""recurrentgemma-9b — RG-LRU + local attention, 1:2 [arXiv:2402.19427; unverified].

Griffin-style hybrid: pattern (recurrent, recurrent, local-attention) — one
attention per two RG-LRU blocks; local attention window 2048.  Sub-quadratic
sequence mixing => eligible for the long_500k shape.
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

REC = LayerSpec(kind="rglru")
LOCAL = LayerSpec(kind="attn", window=2048)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    stages=(
        Stage(superblock=(REC, REC, LOCAL), repeat=12),
        Stage(superblock=(REC, REC), repeat=1),
    ),
    lru_dim=4096,
    conv_width=4,
    sub_quadratic=True,
    notes="kv=1: KV projections replicated across the model axis",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        num_layers=5,
        d_model=96,
        num_heads=4,
        num_kv_heads=1,
        d_ff=192,
        vocab_size=512,
        stages=(
            Stage(superblock=(REC, REC, LayerSpec(kind="attn", window=16)), repeat=1),
            Stage(superblock=(REC, REC), repeat=1),
        ),
        lru_dim=96,
        conv_width=4,
        sub_quadratic=True,
    )
