"""nano-100m — a ~100M-parameter dense decoder for the end-to-end CPU
training example (not part of the assigned architecture pool).

≈ 42M embedding/head + 78M block parameters ≈ 120M total.
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

ATTN = LayerSpec(kind="attn", window=None)

CONFIG = ModelConfig(
    name="nano-100m",
    family="dense",
    num_layers=12,
    d_model=640,
    num_heads=10,
    num_kv_heads=2,
    d_ff=2560,
    vocab_size=32768,
    stages=(Stage(superblock=(ATTN,), repeat=12),),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nano-100m-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        stages=(Stage(superblock=(ATTN,), repeat=2),),
    )
