"""deepseek-67b — llama-arch dense, 95 layers [arXiv:2401.02954; hf].

The deepest assigned model: the scan-over-layers stress test for dry-run
compile size (one superblock traced, 95 repeats).
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

ATTN = LayerSpec(kind="attn", window=None)

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    stages=(Stage(superblock=(ATTN,), repeat=95),),
    notes="pure full attention: long_500k skipped",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke",
        family="dense",
        num_layers=5,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        stages=(Stage(superblock=(ATTN,), repeat=5),),
    )
