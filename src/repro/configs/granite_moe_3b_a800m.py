"""granite-moe-3b-a800m — 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

Fine-grained MoE: tiny experts (d_ff 512), many of them (40), top-8 routing.
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

MOE = LayerSpec(kind="moe")

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    stages=(Stage(superblock=(MOE,), repeat=32),),
    num_experts=40,
    experts_per_token=8,
    moe_d_ff=512,
    notes="40 experts do not divide a 16-way model axis: experts replicated, "
          "expert hidden dim TP-sharded instead (see sharding rules)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke",
        family="moe",
        num_layers=3,
        d_model=96,
        num_heads=8,
        num_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        stages=(Stage(superblock=(MOE,), repeat=3),),
        num_experts=5,
        experts_per_token=2,
        moe_d_ff=64,
    )
