"""Architecture registry: one module per assigned architecture.

``get_config(arch_id, smoke=False)`` resolves ``--arch <id>`` CLI selections.
"""

from . import (chatglm3_6b, deepseek_67b, gemma3_27b, granite_moe_3b_a800m,
               hubert_xlarge, internvl2_76b, llama4_scout_17b_a16e,
               nano_100m, recurrentgemma_9b, stablelm_12b, xlstm_125m)
from .shapes import SHAPES, ShapeSpec, all_cells, cell_applicability

# extra (non-assigned) configs usable via --arch but excluded from the
# 40-cell dry-run matrix
_EXTRA_MODULES = {"nano-100m": nano_100m}

_MODULES = {
    "internvl2-76b": internvl2_76b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "hubert-xlarge": hubert_xlarge,
    "gemma3-27b": gemma3_27b,
    "stablelm-12b": stablelm_12b,
    "chatglm3-6b": chatglm3_6b,
    "deepseek-67b": deepseek_67b,
    "xlstm-125m": xlstm_125m,
}

ARCHITECTURES = {name: mod.CONFIG for name, mod in _MODULES.items()}


def get_config(arch_id: str, smoke: bool = False):
    registry = {**_MODULES, **_EXTRA_MODULES}
    if arch_id not in registry:
        raise KeyError(f"unknown architecture {arch_id!r}; "
                       f"available: {sorted(registry)}")
    mod = registry[arch_id]
    return mod.smoke() if smoke else mod.CONFIG


__all__ = ["ARCHITECTURES", "get_config", "SHAPES", "ShapeSpec",
           "all_cells", "cell_applicability"]
