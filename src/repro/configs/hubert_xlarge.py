"""hubert-xlarge — encoder-only, same arch as wav2vec2 [arXiv:2106.07447; unverified].

Audio: bidirectional transformer encoder over precomputed conv frame features
(frontend STUB provides (B, S, 512) frame embeddings).  vocab 504 = masked
k-means-unit prediction head.  Encoder-only => no decode shapes.
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

ATTN = LayerSpec(kind="attn", window=None)

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    stages=(Stage(superblock=(ATTN,), repeat=48),),
    causal=False,
    mlp_gated=False,
    frontend="frame",
    frontend_dim=512,
    notes="encoder-only: decode_32k and long_500k skipped",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke",
        family="audio",
        num_layers=4,
        d_model=96,
        num_heads=4,
        num_kv_heads=4,
        d_ff=192,
        vocab_size=64,
        stages=(Stage(superblock=(ATTN,), repeat=4),),
        causal=False,
        mlp_gated=False,
        frontend="frame",
        frontend_dim=48,
    )
