"""chatglm3-6b — RoPE 2d, GQA kv=2 [arXiv:2406.12793; hf].

ChatGLM's 2d RoPE is realized as partial rotary (rotary over half the head
dims, the standard GLM practice) — ``rotary_fraction=0.5``.
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

ATTN = LayerSpec(kind="attn", window=None)

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    stages=(Stage(superblock=(ATTN,), repeat=28),),
    rotary_fraction=0.5,
    notes="kv=2 < 16-way model axis: KV projections replicated; "
          "pure full attention: long_500k skipped",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke",
        family="dense",
        num_layers=4,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        stages=(Stage(superblock=(ATTN,), repeat=4),),
        rotary_fraction=0.5,
    )
