"""stablelm-12b — dense GQA decoder [hf:stabilityai/stablelm-2-1_6b; hf]."""

from repro.models.config import LayerSpec, ModelConfig, Stage

ATTN = LayerSpec(kind="attn", window=None)

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    stages=(Stage(superblock=(ATTN,), repeat=40),),
    notes="pure full attention: long_500k skipped",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b-smoke",
        family="dense",
        num_layers=4,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        stages=(Stage(superblock=(ATTN,), repeat=4),),
    )
