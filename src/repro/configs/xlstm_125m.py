"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

xLSTM[1:1]: alternating (mLSTM, sLSTM) superblocks, 12 layers, d_model 768,
4 heads.  d_ff=0 in the assignment: blocks carry their own projections
(mLSTM pf=2, sLSTM pf=4/3).  Pure recurrent state => long_500k eligible.
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

M = LayerSpec(kind="mlstm")
S = LayerSpec(kind="slstm")

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    stages=(Stage(superblock=(M, S), repeat=6),),
    sub_quadratic=True,
    notes="sLSTM has no parallel form (nonlinear recurrence): lowers as "
          "lax.scan over time — see DESIGN.md hardware-adaptation notes",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        family="ssm",
        num_layers=4,
        d_model=96,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        stages=(Stage(superblock=(M, S), repeat=2),),
        sub_quadratic=True,
    )
