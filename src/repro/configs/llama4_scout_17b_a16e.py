"""llama4-scout-17b-a16e — MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

16 routed experts, top-1, plus an always-on shared expert (Llama-4 routing).
~17B active parameters.  Text backbone only (early-fusion frontend not
exercised by the LM shape set).
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

MOE = LayerSpec(kind="moe")

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    stages=(Stage(superblock=(MOE,), repeat=48),),
    num_experts=16,
    experts_per_token=1,
    moe_d_ff=8192,
    shared_expert=True,
    notes="EP: 16 experts shard exactly over a 16-way model axis",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e-smoke",
        family="moe",
        num_layers=3,
        d_model=96,
        num_heads=8,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        stages=(Stage(superblock=(MOE,), repeat=3),),
        num_experts=4,
        experts_per_token=1,
        moe_d_ff=128,
        shared_expert=True,
    )
