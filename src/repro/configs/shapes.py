"""Assigned input shapes and (architecture × shape) cell applicability.

All ten architectures share the LM shape set:

  * train_4k    — seq 4,096,  global batch 256  (training step)
  * prefill_32k — seq 32,768, global batch 32   (inference prefill)
  * decode_32k  — seq 32,768, global batch 128  (one token, 32k KV cache)
  * long_500k   — seq 524,288, global batch 1   (long-context decode)

decode/long shapes lower ``serve_step`` (one new token over a KV cache of
seq_len), not ``train_step``.  long_500k requires sub-quadratic sequence
mixing; decode shapes require a decoder.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "custom_shape", "cell_applicability",
           "all_cells"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def custom_shape(seq_len: int, global_batch: int, kind: str = "train",
                 name: Optional[str] = None) -> ShapeSpec:
    """An off-matrix :class:`ShapeSpec` (the LLM deployment-space family
    sweeps sequence lengths the fixed 40-cell table does not cover)."""
    if kind not in ("train", "prefill", "decode"):
        raise ValueError(f"unknown shape kind {kind!r}")
    if seq_len < 1 or global_batch < 1:
        raise ValueError(
            f"seq_len and global_batch must be >= 1, "
            f"got {seq_len} / {global_batch}")
    return ShapeSpec(name or f"{kind}_{seq_len}", seq_len, global_batch, kind)


def cell_applicability(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped)."""
    if cfg.is_encoder_only and shape.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention layers: 512k context needs sub-quadratic mixing"
    return True, ""


def all_cells(architectures: dict) -> list:
    """[(arch_id, shape_name, runnable, reason)] for the full 40-cell table."""
    out = []
    for arch_id, cfg in architectures.items():
        for shape_name, shape in SHAPES.items():
            ok, reason = cell_applicability(cfg, shape)
            out.append((arch_id, shape_name, ok, reason))
    return out
