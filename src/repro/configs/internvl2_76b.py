"""internvl2-76b — InternViT + InternLM2 [arXiv:2404.16821; unverified].

VLM: the transformer BACKBONE (InternLM2, llama-arch decoder) only; the ViT
frontend is a STUB — ``input_specs`` provides precomputed patch embeddings
(frontend_dim 3200, InternViT-6B feature width) projected into d_model.
"""

from repro.models.config import LayerSpec, ModelConfig, Stage

ATTN = LayerSpec(kind="attn", window=None)

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    stages=(Stage(superblock=(ATTN,), repeat=80),),
    frontend="patch",
    frontend_dim=3200,
    notes="pure full attention: long_500k skipped",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke",
        family="vlm",
        num_layers=4,
        d_model=128,
        num_heads=8,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        stages=(Stage(superblock=(ATTN,), repeat=4),),
        frontend="patch",
        frontend_dim=96,
    )
