"""GPipe-style pipeline parallelism over a mesh axis (optional PP).

The default deployment is FSDP×TP; PP becomes attractive when per-layer
weights exceed what TP can hold or when cross-pod bandwidth makes FSDP
all-gathers dominant.  This module provides a minimal-but-real GPipe
schedule built on ``shard_map`` + ``ppermute``:

* the model's stages are split into S pipeline stages along the ``stage``
  mesh axis (each device group holds its stage's layers only);
* a microbatched forward runs the classic skewed schedule: at tick t, stage
  s processes microbatch t−s; activations move s→s+1 via ``ppermute``;
* bubble fraction = (S−1)/(M+S−1) with M microbatches (reported by
  :func:`bubble_fraction` and visible in the §Roofline analysis when PP is
  selected as a deployment dimension).

This is deliberately the simplest correct schedule (GPipe); the deployment
space exposes ``pp_microbatches`` so the search machinery can trade bubble
vs. activation memory.  Exercised by tests on a small (stage,) mesh.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .._compat.jaxshims import pcast, shard_map

__all__ = ["pipeline_forward", "bubble_fraction"]


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipeline_forward(stage_fn: Callable, num_stages: int,
                     num_microbatches: int, mesh: Mesh,
                     stage_axis: str = "stage"):
    """Build a pipelined forward.

    ``stage_fn(stage_params, x)`` applies ONE stage's layers to a microbatch
    activation ``x``; ``stage_params`` is the per-stage parameter slice
    (leading axis of size num_stages, sharded over the stage axis).

    Returns ``f(stage_params, x_microbatched)`` where ``x_microbatched`` has
    shape (num_microbatches·mb, ...) and is returned fully processed by all
    stages.
    """
    S, M = num_stages, num_microbatches

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(stage_axis), P(None)),
        out_specs=P(None),
    )
    def run(stage_params, xs):
        # stage_params: (1, ...) slice for this device's stage
        params_here = jax.tree.map(lambda a: a[0], stage_params)
        sid = jax.lax.axis_index(stage_axis)
        mb = xs.shape[0] // M
        micro = xs.reshape(M, mb, *xs.shape[1:])

        # skewed schedule: T = M + S - 1 ticks
        T = M + S - 1
        buf = jnp.zeros_like(micro[0])          # activation entering this stage
        outs = jnp.zeros_like(micro)            # completed microbatches (stage S-1)
        # carries become stage-varying inside the loop; mark them upfront
        buf = pcast(buf, (stage_axis,), to="varying")
        outs = pcast(outs, (stage_axis,), to="varying")

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            take = jnp.clip(t, 0, M - 1)
            fresh = jax.lax.dynamic_index_in_dim(micro, take, 0, keepdims=False)
            x_in = jnp.where(sid == 0, fresh, buf)
            active = (t - sid >= 0) & (t - sid < M)
            y = stage_fn(params_here, x_in)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch t-(S-1)
            done_idx = jnp.clip(t - (S - 1), 0, M - 1)
            record = (sid == S - 1) & (t - (S - 1) >= 0) & (t - (S - 1) < M)
            sel = (jnp.arange(M) == done_idx)[:, None, None] & record
            outs = jnp.where(sel, y[None], outs)
            # pass activations forward around the ring (stage s -> s+1)
            buf_next = jax.lax.ppermute(
                y, stage_axis, [(i, (i + 1) % S) for i in range(S)])
            return (buf_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(T))
        # only stage S-1 wrote real data, every other stage holds zeros —
        # a psum broadcasts the result to all stages
        outs = jax.lax.psum(outs, stage_axis)
        return outs.reshape(xs.shape)

    return run
