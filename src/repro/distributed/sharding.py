"""Logical-axis sharding rules → PartitionSpecs, and the DeploymentConfig.

Every parameter in the model carries logical axis names (see
``models/common.py``); the rule table below maps logical names to mesh axes.
The rule table is PART OF THE DEPLOYMENT CONFIGURATION — i.e. it is a
dimension of the deployment Discovery Space and searchable by the paper's
machinery (see ``tuning/deployment.py``).

Default strategy (2-D "FSDP × TP", MaxText-style):
  * ``embed``  → ``data``   (ZeRO-3: parameters+optimizer sharded over DP)
  * ``heads`` / ``mlp`` / ``vocab`` / ``lru`` → ``model`` (tensor parallel)
  * batch     → (``pod``, ``data``); pod axis is pure DP over DCN
  * divisibility fallbacks per architecture (e.g. kv_heads=1 replicates KV;
    40 experts don't divide a 16-way model axis → experts replicated and the
    expert hidden dim TP-sharded instead).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.attention import AttnOptions
from ..models.blocks import ModelOptions
from ..models.common import DTypePolicy
from ..models.config import ModelConfig
from ..models.moe import MoEOptions
from ..models.rglru import RGLRUOptions
from ..models.xlstm import XLSTMOptions

__all__ = ["DeploymentConfig", "default_deployment", "param_specs",
           "batch_specs", "cache_specs", "named_sharding_tree"]

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


@dataclass(frozen=True)
class DeploymentConfig:
    """The deployment configuration — every field is a potential Discovery
    Space dimension."""

    rules: Tuple[Tuple[str, Optional[str]], ...]
    batch_axes: Tuple[str, ...] = ("data",)
    seq_axis: Optional[str] = None       # sequence sharding for prefill (SP)
    remat: str = "dots"                  # none | full | dots
    microbatches: int = 1
    attn_impl: str = "xla"
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 512
    band_skip: bool = True
    moe_impl: str = "capacity"
    moe_capacity_factor: float = 1.25
    mlstm_chunk: int = 128
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    grad_compression: str = "none"       # none | int8_ef
    # cast fp32 params to compute dtype ONCE per step instead of inside
    # every microbatch (beyond-paper optimization found in §Perf: cuts
    # weight-stream traffic ~2.5× at microbatches=16)
    cast_params_once: bool = False
    # force query-head sharding inside attention even when heads don't
    # divide the model axis (GSPMD pads) — §Perf beyond-paper change that
    # un-replicates attention for llama4's 40 heads on a 16-way axis
    attn_shard_heads: Optional[str] = None

    # -- derived ---------------------------------------------------------------

    def rule(self, logical: Optional[str]) -> Optional[str]:
        if logical is None:
            return None
        for name, axis in self.rules:
            if name == logical:
                return axis
        return None

    def with_rule(self, logical: str, axis: Optional[str]) -> "DeploymentConfig":
        new = tuple((n, axis if n == logical else a) for n, a in self.rules)
        if logical not in [n for n, _ in self.rules]:
            new = new + ((logical, axis),)
        return replace(self, rules=new)

    def model_options(self) -> ModelOptions:
        return ModelOptions(
            attn=AttnOptions(impl=self.attn_impl, q_chunk=self.attn_q_chunk,
                             kv_chunk=self.attn_kv_chunk,
                             band_skip=self.band_skip, interpret=True,
                             shard_heads=self.attn_shard_heads,
                             shard_batch=tuple(self.batch_axes)),
            moe=MoEOptions(impl=self.moe_impl,
                           capacity_factor=self.moe_capacity_factor),
            rglru=RGLRUOptions(impl="xla"),
            xlstm=XLSTMOptions(chunk=self.mlstm_chunk),
            remat=self.remat,
            policy=DTypePolicy(param_dtype=_DTYPES[self.param_dtype],
                               compute_dtype=_DTYPES[self.compute_dtype]),
            act_sharding=(tuple(self.batch_axes), self.seq_axis),
        )

    def spec_for(self, logical_axes: Sequence[Optional[str]]) -> P:
        return P(*[self.rule(a) for a in logical_axes])


def default_deployment(cfg: ModelConfig, mesh: Mesh,
                       shape_kind: str = "train",
                       global_batch: int = 256, seq_len: int = 4096,
                       hbm_budget: float = 10e9) -> DeploymentConfig:
    """Architecture- and mesh-aware default deployment (the paper-faithful
    baseline configuration; the starting point of every deployment search).

    Microbatch count is chosen so the stacked per-layer activation residuals
    (carry bf16 + the fp32 copy XLA:CPU keeps for emulated-bf16 modules —
    6 B/elem worst case) fit the HBM budget alongside params+optimizer.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axis_sizes.get("model", 1)
    data_n = axis_sizes.get("data", 1)
    dp = data_n * axis_sizes.get("pod", 1)

    def fits(n: int) -> bool:
        return n % model_n == 0

    rules = {
        "layers": None,
        "embed": "data" if cfg.d_model % data_n == 0 else None,
        "heads": "model" if fits(cfg.num_heads) else None,
        "kv_heads": "model" if fits(cfg.num_kv_heads) else None,
        "head_dim": None,
        "mlp": "model" if (cfg.d_ff == 0 or fits(cfg.d_ff)) else None,
        "mlp_in": None,
        "vocab": "model" if fits(cfg.vocab_size) else None,
        "experts": "model" if (cfg.num_experts and fits(cfg.num_experts)) else None,
        "experts_router": None,
        "moe_mlp": None,
        "lru": "model" if fits(cfg.resolved_lru_dim) else None,
        "lru_in": None,
        "heads_gate": None,
        "frontend": None,
    }
    # MoE fallback: if experts can't shard, TP the expert hidden dim.
    if cfg.num_experts and rules["experts"] is None:
        f = cfg.moe_d_ff or cfg.d_ff
        rules["moe_mlp"] = "model" if fits(f) else None
    # xLSTM blocks put their projections on 'mlp': 2d/4d/f widths
    if cfg.family == "ssm":
        rules["mlp"] = "model" if fits(2 * cfg.d_model) else None

    # batch axes: only mesh axes whose combined size divides the global
    # batch (long_500k has global_batch=1: batch replicated, parallelism
    # comes from the model axis alone)
    batch_axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in axis_sizes and global_batch % (prod * axis_sizes[a]) == 0:
            batch_axes.append(a)
            prod *= axis_sizes[a]
    batch_axes = tuple(batch_axes)

    microbatches = 1
    if shape_kind == "train":
        local_batch = max(global_batch // dp, 1)
        tokens_local = local_batch * seq_len
        # stacked residual-stream carries: L × tokens × d × 6 B (bf16+fp32)
        resid = cfg.num_layers * tokens_local * cfg.d_model * 6
        microbatches = 1
        while resid / microbatches > hbm_budget and microbatches < local_batch:
            microbatches *= 2
        microbatches = min(microbatches, local_batch)

    return DeploymentConfig(
        rules=tuple(sorted(rules.items())),
        batch_axes=batch_axes,
        microbatches=microbatches,
    )


# ---------------------------------------------------------------------------
# spec trees
# ---------------------------------------------------------------------------


def param_specs(logical_tree, deployment: DeploymentConfig):
    """Map the model's logical-axes tree to a PartitionSpec tree."""
    if isinstance(logical_tree, tuple):
        return deployment.spec_for(logical_tree)
    return {k: param_specs(v, deployment) for k, v in logical_tree.items()}


def batch_specs(cfg: ModelConfig, deployment: DeploymentConfig,
                kind: str = "train") -> dict:
    """PartitionSpecs for a training/prefill/decode input batch."""
    b = P(deployment.batch_axes if len(deployment.batch_axes) != 1
          else deployment.batch_axes[0])
    bt = tuple(deployment.batch_axes)
    s = deployment.seq_axis
    out = {}
    if cfg.uses_tokens:
        out["tokens"] = P(bt, s)
    else:
        out["embeds"] = P(bt, s, None)
    if kind == "train":
        out["labels"] = P(bt, s)
    return out


def _cache_leaf_specs(kind: str, cfg: ModelConfig, deployment: DeploymentConfig,
                      stacked: bool = True):
    bt = tuple(deployment.batch_axes)
    kv_axis = deployment.rule("kv_heads")
    cache_seq_axis = None
    if kv_axis is None:
        # heads won't shard: split the cache length instead (flash-decode
        # style split-KV) so decode attention parallelizes over the model axis
        cache_seq_axis = deployment.rule("heads") or "model"
    lru = deployment.rule("lru")
    mlp = deployment.rule("mlp")
    if kind in ("attn", "moe"):
        spec = {"k": P(bt, cache_seq_axis, kv_axis, None),
                "v": P(bt, cache_seq_axis, kv_axis, None)}
    elif kind == "rglru":
        spec = {"h": P(bt, lru), "conv": P(bt, None, lru)}
    elif kind == "mlstm":
        h = deployment.rule("heads")
        spec = {"C": P(bt, h, None, None), "n": P(bt, h, None), "m": P(bt, h)}
    elif kind == "slstm":
        spec = {k: P(bt, None) for k in ("c", "n", "m", "h")}
    else:
        raise ValueError(kind)
    if stacked:
        spec = jax.tree.map(lambda p: P(None, *p), spec,
                            is_leaf=lambda x: isinstance(x, P))
    return spec


def cache_specs(cfg: ModelConfig, deployment: DeploymentConfig) -> dict:
    """PartitionSpec tree matching ``LMModel.init_cache`` structure."""
    out = {}
    for si, stage in enumerate(cfg.stages):
        stage_spec = {}
        for i, spec in enumerate(stage.superblock):
            stage_spec[f"l{i}"] = _cache_leaf_specs(spec.kind, cfg, deployment)
        out[f"stage{si}"] = stage_spec
    return out


def named_sharding_tree(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
