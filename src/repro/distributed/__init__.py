"""Distributed runtime: sharding rules, compressed collectives, pipelining."""

from .sharding import (DeploymentConfig, batch_specs, cache_specs,
                       default_deployment, param_specs)

__all__ = ["DeploymentConfig", "batch_specs", "cache_specs",
           "default_deployment", "param_specs"]
