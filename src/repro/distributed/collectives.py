"""Compressed cross-pod gradient collectives with error feedback.

At multi-pod scale the pod axis rides on DCN (data-center network), ~10-25
GB/s per host vs 200 GB/s aggregate ICI — the cross-pod gradient all-reduce
is the scaling bottleneck.  Standard mitigation: quantize the cross-pod
reduction to int8 with per-tensor scales and keep an *error-feedback* buffer
so quantization error is re-injected next step (Seide et al. 2014; 1-bit
Adam lineage) — unbiased long-run updates at 4× less DCN traffic than bf16.

``compressed_psum`` is built on ``shard_map`` over the pod axis and is
numerically validated in tests (convergence of error feedback, exactness
for representable values).  The intra-pod (ICI) reductions stay full
precision — only the slow axis is compressed.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .._compat.jaxshims import shard_map

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum",
           "compressed_grad_sync"]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    error: Optional[jax.Array] = None):
    """int8-compressed psum over ``axis_name`` with error feedback.

    Must be called inside shard_map/pmap with ``axis_name`` bound.  Returns
    (mean-reduced x (fp32), new error-feedback buffer).

    The quantization scale is SHARED across the group (pmax of local amax —
    one tiny fp32 collective) so that summing int8 payloads and multiplying
    once by the shared scale is exact per member; each member's residual
    goes into its own error-feedback buffer.
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(jax.lax.pmax(amax, axis_name), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_error = xf - q.astype(jnp.float32) * scale
    # int8 payloads summed in int32 (no overflow for <= 2^23 members)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = qsum.astype(jnp.float32) * scale / n
    return mean, new_error


def compressed_grad_sync(grads, error_buffers, mesh: Mesh,
                         pod_axis: str = "pod"):
    """Apply compressed_psum across the pod axis to a gradient pytree.

    Gradients are assumed already reduced within each pod (pjit does that);
    this syncs pod-level partial means over the slow DCN axis.  Everything
    else (params etc.) is untouched.  Returns (synced grads, new errors).
    """
    flat, treedef = jax.tree.flatten(grads)
    err_flat = (jax.tree.leaves(error_buffers)
                if error_buffers is not None else [None] * len(flat))

    in_specs = tuple(P() for _ in flat)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(in_specs, in_specs),
        out_specs=(in_specs, in_specs),
    )
    def sync(gs, errs):
        outs, new_errs = [], []
        for g, e in zip(gs, errs):
            m, ne = compressed_psum(g, pod_axis, e)
            outs.append(m.astype(g.dtype))
            new_errs.append(ne)
        return tuple(outs), tuple(new_errs)

    err_in = tuple(jnp.zeros_like(g, jnp.float32) if e is None else e
                   for g, e in zip(flat, err_flat))
    outs, new_errs = sync(tuple(flat), err_in)
    return treedef.unflatten(list(outs)), treedef.unflatten(list(new_errs))
