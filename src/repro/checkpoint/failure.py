"""Fault tolerance: node-failure handling, elastic re-meshing, stragglers.

At 1000+ node scale the failure model is: (a) hard node loss (host gone —
detected by the coordinator via missed heartbeats), (b) stragglers (host
alive but slow), (c) data poisoning / bad shards.  The policies here are
deterministic functions so every surviving host computes the SAME plan
without extra coordination:

* :class:`FailureManager` — heartbeat registry; declares hosts dead after
  ``timeout`` and produces an :class:`ElasticPlan`.
* :func:`elastic_remesh` — given surviving device count, pick the largest
  (data × model) mesh that (1) keeps the model axis intact (TP degree is a
  property of the checkpoint layout we want to preserve) and (2) maximizes
  used devices.  Training resumes from the last checkpoint — the checkpoint
  format is mesh-independent (see checkpoint.py) so resharding is just a
  device_put with the new mesh's shardings.
* :class:`StragglerPolicy` — per-step deadline policy: a host that misses
  the deadline k times in a window is treated as failed (escalate to
  elastic re-mesh); individual slow *steps* are absorbed by the async
  dispatch queue depth.

On this single-host container the manager is exercised by tests that
simulate heartbeats and by the train launcher's restart path (kill/resume).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["ElasticPlan", "FailureManager", "StragglerPolicy", "elastic_remesh"]


@dataclass(frozen=True)
class ElasticPlan:
    """What the cluster should look like after a failure."""

    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_hosts: Tuple[int, ...]
    devices_used: int
    devices_idle: int
    resume_step: int


def elastic_remesh(total_devices: int, model_axis: int,
                   axis_names: Sequence[str] = ("data", "model"),
                   pod_axis: int = 1) -> Tuple[Tuple[int, ...], int]:
    """Largest (pod ×) data × model mesh with the model axis preserved.

    Returns (mesh_shape, idle_devices).  The model axis is preserved because
    changing TP degree changes per-device parameter layouts; the data axis
    (pure DP/FSDP) can shrink freely — batch is re-balanced by the
    deterministic data pipeline.
    """
    if total_devices < model_axis:
        raise ValueError(
            f"cannot keep model axis {model_axis} with {total_devices} devices")
    groups = total_devices // (model_axis * pod_axis)
    if groups < 1:
        pod_axis = 1
        groups = total_devices // model_axis
    used = groups * model_axis * pod_axis
    if pod_axis > 1:
        return (pod_axis, groups, model_axis), total_devices - used
    return (groups, model_axis), total_devices - used


class FailureManager:
    """Heartbeat-based failure detection + deterministic elastic planning."""

    def __init__(self, hosts: Sequence[int], devices_per_host: int,
                 model_axis: int, timeout: float = 60.0):
        self.devices_per_host = devices_per_host
        self.model_axis = model_axis
        self.timeout = timeout
        self._last_seen: Dict[int, float] = {h: time.time() for h in hosts}
        self._dead: set = set()

    def heartbeat(self, host: int, now: Optional[float] = None) -> None:
        if host in self._dead:
            return  # dead hosts must rejoin via admit()
        self._last_seen[host] = now if now is not None else time.time()

    def admit(self, host: int, now: Optional[float] = None) -> None:
        """Scale-up / rejoin path."""
        self._dead.discard(host)
        self._last_seen[host] = now if now is not None else time.time()

    def check(self, now: Optional[float] = None) -> List[int]:
        """Returns newly-dead hosts."""
        now = now if now is not None else time.time()
        newly = []
        for host, seen in self._last_seen.items():
            if host not in self._dead and now - seen > self.timeout:
                self._dead.add(host)
                newly.append(host)
        return newly

    @property
    def alive(self) -> List[int]:
        return sorted(h for h in self._last_seen if h not in self._dead)

    def plan(self, resume_step: int) -> ElasticPlan:
        total = len(self.alive) * self.devices_per_host
        shape, idle = elastic_remesh(total, self.model_axis)
        names = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
        return ElasticPlan(
            mesh_shape=shape,
            axis_names=names,
            dropped_hosts=tuple(sorted(self._dead)),
            devices_used=total - idle,
            devices_idle=idle,
            resume_step=resume_step,
        )


@dataclass
class StragglerPolicy:
    """Deadline-based straggler escalation.

    ``observe(host, step_time)`` returns True when the host should be
    treated as failed (k misses within the last ``window`` observations).
    """

    deadline_s: float
    misses_to_fail: int = 3
    window: int = 10
    _history: Dict[int, List[bool]] = field(default_factory=dict)

    def observe(self, host: int, step_time_s: float) -> bool:
        h = self._history.setdefault(host, [])
        h.append(step_time_s > self.deadline_s)
        del h[:-self.window]
        return sum(h) >= self.misses_to_fail

    def reset(self, host: int) -> None:
        self._history.pop(host, None)
