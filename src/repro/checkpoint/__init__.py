"""Checkpointing and fault tolerance."""

from .checkpoint import (CheckpointManager, load_checkpoint, save_checkpoint)
from .failure import ElasticPlan, FailureManager, StragglerPolicy

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint",
           "ElasticPlan", "FailureManager", "StragglerPolicy"]
