"""Mesh-independent checkpointing: msgpack + zstd, async save, resharding load.

Layout: a checkpoint is a directory with
  * ``manifest.json``      — step, flat key list, shapes/dtypes, metadata,
    and the compression ``codec`` (``zstd`` when the ``zstandard`` package is
    available, stdlib ``zlib`` otherwise — loaders dispatch on the manifest,
    so checkpoints move between environments with either codec)
  * ``arrays.msgpack.zst`` — flat {path: raw bytes} (host-gathered numpy)

Arrays are stored UNSHARDED (gathered to host), keyed by tree path — so a
checkpoint written from a 16×16 mesh restores onto 2×16×16, onto the
post-failure 14×16 elastic mesh, or onto one CPU, by simply device_put-ing
with the target sharding (``load_checkpoint(..., shardings=...)``).

``CheckpointManager`` adds: atomic writes (tmp dir + rename), retention,
async save (background thread; ``wait()`` joins), and latest-step discovery
for restart-after-failure.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zlib

try:
    import zstandard
except ModuleNotFoundError:
    zstandard = None

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager",
           "compress_payload", "decompress_payload"]

_ARRAYS_FILE = "arrays.msgpack.zst"


def compress_payload(raw: bytes) -> "tuple[bytes, str]":
    """Compress a checkpoint payload; returns (blob, codec name)."""
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw), "zstd"
    return zlib.compress(raw, level=6), "zlib"


def decompress_payload(blob: bytes, codec: str = "zstd") -> bytes:
    """Invert :func:`compress_payload` given the manifest's codec tag."""
    if codec == "zstd":
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd; install 'zstandard' to load it")
        return zstandard.ZstdDecompressor().decompress(blob)
    if codec == "zlib":
        return zlib.decompress(blob)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def _unflatten_like(template, flat: dict):
    leaves_p = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, _ in leaves_p:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, tree, metadata: Optional[dict] = None,
                    ) -> str:
    """Write checkpoint atomically.  Returns the checkpoint path."""
    flat = _flatten(tree)
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "metadata": metadata or {}, "arrays": {}}
    payload = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        manifest["arrays"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        payload[key] = arr.tobytes()
    raw = msgpack.packb(payload, use_bin_type=True)
    blob, manifest["codec"] = compress_payload(raw)
    with open(os.path.join(tmp, _ARRAYS_FILE), "wb") as f:
        f.write(blob)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def load_checkpoint(directory: str, template, step: Optional[int] = None,
                    shardings=None):
    """Load a checkpoint (latest if ``step`` is None), optionally placing
    each array with the given sharding tree (resharding on load)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(path, _ARRAYS_FILE), "rb") as f:
        raw = decompress_payload(f.read(), manifest.get("codec", "zstd"))
    payload = msgpack.unpackb(raw, raw=False)
    flat = {}
    for key, info in manifest["arrays"].items():
        arr = np.frombuffer(payload[key], dtype=np.dtype(info["dtype"]))
        flat[key] = arr.reshape(info["shape"])
    tree = _unflatten_like(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree, manifest


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                continue
    return max(steps) if steps else None


class CheckpointManager:
    """Retention + async save + restart discovery."""

    def __init__(self, directory: str, keep: int = 3, save_every: int = 100):
        self.directory = directory
        self.keep = keep
        self.save_every = save_every
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree, metadata: Optional[dict] = None,
             async_: bool = True) -> None:
        # materialize on host BEFORE handing to the thread (donated buffers
        # may be reused by the next step otherwise)
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self.wait()

        def work():
            save_checkpoint(self.directory, step, host_tree, metadata)
            self._retain()

        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, template, shardings=None):
        self.wait()
        return load_checkpoint(self.directory, template, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def _retain(self) -> None:
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
