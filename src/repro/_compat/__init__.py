"""Compatibility shims for optional third-party dependencies.

The reproduction targets a hermetic container: everything needed to run the
tier-1 suite must either be baked into the image or degrade gracefully.
Modules here provide small, behavior-compatible fallbacks that are only used
when the real dependency is absent (see ``tests/conftest.py`` and
``repro.checkpoint.checkpoint``); with a full ``pip install -e .[test]`` the
real libraries win.
"""
