"""Shims over JAX API drift, so one codebase spans jaxlib generations.

* ``shard_map`` — promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map`` in newer releases; we resolve whichever exists.
* ``pcast`` — ``jax.lax.pcast`` exists only in releases with the
  varying-manual-axes (vma) checker.  On older releases values inside
  ``shard_map`` are device-varying by construction and there is nothing to
  mark, so the shim is the identity there.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "pcast"]

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pre-promotion releases
    from jax.experimental.shard_map import shard_map  # type: ignore[no-redef]


def pcast(x, axes, to: str = "varying"):
    fn = getattr(jax.lax, "pcast", None)
    if fn is None:
        return x
    return fn(x, axes, to=to)
