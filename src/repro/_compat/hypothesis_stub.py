"""A minimal, API-compatible fallback for the ``hypothesis`` library.

Loaded by ``tests/conftest.py`` (as ``sys.modules['hypothesis']``) ONLY when
the real library is not installed, so the property-based tests still collect
and exercise their invariants offline.  It implements the subset the suite
uses — ``given``/``settings``/``assume`` and the ``strategies`` combinators
``integers``, ``booleans``, ``floats``, ``sampled_from``, ``just``,
``one_of``, ``tuples``, ``lists``, ``text`` — with deterministic
pseudo-random example generation (seeded per test) instead of the real
library's coverage-guided search and shrinking.

It is NOT hypothesis: no shrinking, no example database, no health checks.
On failure it prints the falsifying example and re-raises the original
error.  Install ``hypothesis`` (see ``pyproject.toml`` extras) to get the
real engine; nothing here is imported when it is available.
"""

from __future__ import annotations

import functools
import inspect
import random
import string
import sys
import types
from typing import Any, Callable, Optional, Sequence

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck",
           "UnsatisfiedAssumption"]

_MAX_DRAW_ATTEMPTS = 8  # retries for filtered/unique draws before giving up


class UnsatisfiedAssumption(Exception):
    """Raised by :func:`assume` to discard the current example."""


def assume(condition: Any) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class HealthCheck:
    """Placeholder namespace so ``suppress_health_check`` lists type-check."""

    function_scoped_fixture = "function_scoped_fixture"
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls) -> list:
        return [cls.function_scoped_fixture, cls.too_slow, cls.filter_too_much]


class settings:
    """Decorator + profile registry mirroring ``hypothesis.settings``.

    Only ``max_examples`` and ``deadline`` are honored (``deadline`` is
    accepted and ignored — the stub never times examples out, which is
    exactly the CPU-safe behavior the suite's profiles ask for).
    """

    _profiles: dict = {"default": {"max_examples": 25, "deadline": None}}
    _current: str = "default"

    def __init__(self, parent: Optional["settings"] = None, **kwargs: Any):
        self._kwargs = dict(parent._kwargs) if isinstance(parent, settings) else {}
        self._kwargs.update(kwargs)

    def __call__(self, fn: Callable) -> Callable:
        fn._stub_settings = dict(self._kwargs)
        return fn

    @classmethod
    def register_profile(cls, name: str, parent: Optional["settings"] = None,
                         **kwargs: Any) -> None:
        base = dict(cls._profiles.get("default", {}))
        if isinstance(parent, settings):
            base.update(parent._kwargs)
        base.update(kwargs)
        cls._profiles[name] = base

    @classmethod
    def load_profile(cls, name: str) -> None:
        if name not in cls._profiles:
            raise KeyError(f"unknown settings profile {name!r}")
        cls._current = name

    @classmethod
    def current(cls) -> dict:
        return cls._profiles[cls._current]


class SearchStrategy:
    """A value generator.  ``draw(rnd)`` returns one example."""

    def __init__(self, draw_fn: Callable[[random.Random], Any], label: str = "strategy"):
        self._draw = draw_fn
        self.label = label

    def draw(self, rnd: random.Random) -> Any:
        return self._draw(rnd)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rnd: fn(self.draw(rnd)), f"{self.label}.map")

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rnd: random.Random) -> Any:
            for _ in range(_MAX_DRAW_ATTEMPTS * 16):
                value = self.draw(rnd)
                if pred(value):
                    return value
            raise UnsatisfiedAssumption(f"filter on {self.label} rejected everything")
        return SearchStrategy(draw, f"{self.label}.filter")

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{self.label}>"


def _integers(min_value: int = 0, max_value: int = 2 ** 31 - 1) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.randint(min_value, max_value),
                          f"integers({min_value},{max_value})")


def _booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.random() < 0.5, "booleans()")


def _floats(min_value: float = 0.0, max_value: float = 1.0,
            allow_nan: bool = False, allow_infinity: bool = False) -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.uniform(min_value, max_value),
                          f"floats({min_value},{max_value})")


def _sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rnd: elements[rnd.randrange(len(elements))],
                          f"sampled_from(<{len(elements)}>)")


def _just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rnd: value, "just")


def _one_of(*strategies_: SearchStrategy) -> SearchStrategy:
    opts = list(strategies_)
    return SearchStrategy(lambda rnd: opts[rnd.randrange(len(opts))].draw(rnd),
                          "one_of")


def _tuples(*strategies_: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rnd: tuple(s.draw(rnd) for s in strategies_),
                          "tuples")


def _lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 8,
           unique_by: Optional[Callable[[Any], Any]] = None,
           unique: bool = False) -> SearchStrategy:
    if unique and unique_by is None:
        unique_by = lambda x: x

    def draw(rnd: random.Random) -> list:
        size = rnd.randint(min_size, max_size)
        out: list = []
        keys: set = set()
        attempts = 0
        while len(out) < size and attempts < max(1, size) * _MAX_DRAW_ATTEMPTS * 4:
            attempts += 1
            value = elements.draw(rnd)
            if unique_by is not None:
                key = unique_by(value)
                if key in keys:
                    continue
                keys.add(key)
            out.append(value)
        if len(out) < min_size:
            raise UnsatisfiedAssumption("could not draw enough unique elements")
        return out

    return SearchStrategy(draw, f"lists(min={min_size},max={max_size})")


def _text(alphabet: str = string.ascii_letters + string.digits,
          min_size: int = 0, max_size: int = 16) -> SearchStrategy:
    def draw(rnd: random.Random) -> str:
        size = rnd.randint(min_size, max_size)
        return "".join(rnd.choice(alphabet) for _ in range(size))
    return SearchStrategy(draw, "text")


def _dictionaries(keys: SearchStrategy, values: SearchStrategy,
                  min_size: int = 0, max_size: int = 8) -> SearchStrategy:
    pairs = _lists(_tuples(keys, values), min_size=min_size, max_size=max_size,
                   unique_by=lambda kv: kv[0])
    return pairs.map(dict)


# The ``hypothesis.strategies`` facade, importable both as an attribute and
# as a registered submodule (conftest puts it in sys.modules).
strategies = types.ModuleType("hypothesis.strategies")
strategies.SearchStrategy = SearchStrategy
strategies.integers = _integers
strategies.booleans = _booleans
strategies.floats = _floats
strategies.sampled_from = _sampled_from
strategies.just = _just
strategies.one_of = _one_of
strategies.tuples = _tuples
strategies.lists = _lists
strategies.text = _text
strategies.dictionaries = _dictionaries


def given(*args: Any, **strategy_kwargs: Any) -> Callable:
    """Run the wrapped test over deterministically generated examples."""
    if args:
        raise TypeError("the hypothesis stub supports keyword strategies only; "
                        "write @given(x=st.integers()) instead of @given(st.integers())")

    def decorate(fn: Callable) -> Callable:
        local = getattr(fn, "_stub_settings", {})

        @functools.wraps(fn)
        def wrapper(*wargs: Any, **wkwargs: Any) -> None:
            conf = dict(settings.current())
            conf.update(local)
            max_examples = int(conf.get("max_examples", 25))
            # Deterministic per-test stream: same examples every run.
            rnd = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            ran = 0
            attempts = 0
            while ran < max_examples and attempts < max_examples * 8:
                attempts += 1
                try:
                    drawn = {k: s.draw(rnd) for k, s in strategy_kwargs.items()}
                    fn(*wargs, **{**wkwargs, **drawn})
                except UnsatisfiedAssumption:
                    continue
                except BaseException:
                    example = {k: repr(v)[:200] for k, v in drawn.items()}
                    print(f"Falsifying example ({fn.__qualname__}): {example}",
                          file=sys.stderr)
                    raise
                ran += 1
            if ran == 0:
                raise UnsatisfiedAssumption(
                    f"{fn.__qualname__}: no example satisfied the assumptions")

        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (real hypothesis rewrites the signature the same way).
        params = [p for name, p in inspect.signature(fn).parameters.items()
                  if name not in strategy_kwargs]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        wrapper.hypothesis_stub = True
        return wrapper

    return decorate
