"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM is computed in *chunkwise-parallel* form — the TPU-native adaptation:
within a chunk the (L×L) decay-weighted attention runs on the MXU; across
chunks a (dk×dv) matrix state is carried by ``lax.scan``.  All gating runs in
log-space with running stabilizers (the xLSTM paper's m_t), so exp-gates
never overflow.  Memory is O(S·L) instead of O(S²) and decode is a pure O(1)
state update — which is what qualifies xlstm for the ``long_500k`` shape.

sLSTM has true nonlinear recurrence (block-diagonal recurrent weights) and is
inherently sequential: it lowers as ``lax.scan`` over time.  There is no
parallel form — noted in DESIGN.md; on TPU the per-step work is a small
per-head matvec, so this layer is latency- not throughput-bound.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import ParamDef
from .config import ModelConfig

__all__ = ["mlstm_defs", "mlstm_apply", "mlstm_decode", "init_mlstm_state",
           "slstm_defs", "slstm_apply", "slstm_decode", "init_slstm_state",
           "XLSTMOptions"]


@dataclass(frozen=True)
class XLSTMOptions:
    chunk: int = 128  # mLSTM chunk length (deployment-searchable)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = 2 * d                       # projection factor 2 (xLSTM paper)
    return {
        "w_up": ParamDef((d, di), ("embed", "mlp")),
        "w_z": ParamDef((d, di), ("embed", "mlp")),
        "w_q": ParamDef((di, di), ("mlp", "mlp_in"), scale=0.5),
        "w_k": ParamDef((di, di), ("mlp", "mlp_in"), scale=0.5),
        "w_v": ParamDef((di, di), ("mlp", "mlp_in"), scale=0.5),
        "w_i": ParamDef((di, cfg.num_heads), ("mlp", "heads_gate")),
        "b_i": ParamDef((cfg.num_heads,), ("heads_gate",), init="zeros"),
        "w_f": ParamDef((di, cfg.num_heads), ("mlp", "heads_gate")),
        "b_f": ParamDef((cfg.num_heads,), ("heads_gate",), init="ones"),
        "w_down": ParamDef((di, d), ("mlp", "embed"), init="scaled"),
    }


def _mlstm_qkv_gates(params, x, cfg: ModelConfig):
    """x: (B,S,d) -> q,k,v (B,S,H,dh) and log-gates (B,S,H) fp32."""
    cdt = x.dtype
    B, S, _ = x.shape
    H = cfg.num_heads
    u = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(cdt))
    z = jnp.einsum("bsd,de->bse", x, params["w_z"].astype(cdt))
    di = u.shape[-1]
    dh = di // H
    q = jnp.einsum("bse,ef->bsf", u, params["w_q"].astype(cdt)).reshape(B, S, H, dh)
    k = jnp.einsum("bse,ef->bsf", u, params["w_k"].astype(cdt)).reshape(B, S, H, dh)
    v = jnp.einsum("bse,ef->bsf", u, params["w_v"].astype(cdt)).reshape(B, S, H, dh)
    uf = u.astype(jnp.float32)
    log_i = uf @ params["w_i"].astype(jnp.float32) + params["b_i"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        uf @ params["w_f"].astype(jnp.float32) + params["b_f"].astype(jnp.float32))
    k = k * (dh ** -0.5)
    return q, k, v, log_i, log_f, z


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    H = cfg.num_heads
    dh = (2 * cfg.d_model) // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, state, chunk: int):
    """Chunkwise-parallel mLSTM.  q/k/v: (B,S,H,dh); log gates: (B,S,H).
    Returns (h: (B,S,H,dh) fp32, final state)."""
    B, S, H, dh = q.shape
    L = min(chunk, S)
    if S % L:
        raise ValueError(f"seq {S} must divide mLSTM chunk {L}")
    nc = S // L

    def split(x):  # (B,S,...) -> (nc, B, L, ...)
        return jnp.moveaxis(x.reshape(B, nc, L, *x.shape[2:]), 1, 0)

    qs, ks, vs = split(q.astype(jnp.float32)), split(k.astype(jnp.float32)), \
        split(v.astype(jnp.float32))
    lis, lfs = split(log_i), split(log_f)

    tri = jnp.tril(jnp.ones((L, L), bool))            # s <= t
    tri_strict = jnp.tril(jnp.ones((L, L), bool), -1)

    def body(carry, xs):
        C, n, m = carry                                # (B,H,dh,dh) (B,H,dh) (B,H)
        qc, kc, vc, lic, lfc = xs                      # (B,L,H,dh) / (B,L,H)
        b = jnp.cumsum(lfc, axis=1)                    # (B,L,H) cumulative log-f
        # intra-chunk log weights: w(t,s) = b_t - b_s + li_s  (s <= t)
        lw = b[:, :, None, :] - b[:, None, :, :] + lic[:, None, :, :]
        lw = jnp.where(tri[None, :, :, None], lw, -jnp.inf)  # (B,t,s,H)
        g = jnp.max(lw, axis=2)                        # (B,L,H) running intra max
        m_inter = b + m[:, None, :]                    # (B,L,H)
        m_t = jnp.maximum(m_inter, g)
        m_t = jnp.maximum(m_t, -1e30)

        # inter-chunk contribution
        scale_inter = jnp.exp(m_inter - m_t)           # (B,L,H)
        h_inter = jnp.einsum("blhd,bhde->blhe", qc, C) * scale_inter[..., None]
        n_inter = jnp.einsum("blhd,bhd->blh", qc, n) * scale_inter

        # intra-chunk contribution
        w = jnp.exp(lw - m_t[:, :, None, :])           # (B,t,s,H)
        scores = jnp.einsum("blhd,bshd->blsh", qc, kc) * w
        h_intra = jnp.einsum("blsh,bshe->blhe", scores, vc)
        # normalizer: qn_t = q_t·n_t = Σ_s w(t,s)·(q_t·k_s)
        qn = n_inter + scores.sum(axis=2)
        h_num = h_inter + h_intra
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
        h = h_num / denom[..., None]

        # state update to end of chunk
        b_L = b[:, -1, :]                              # (B,H)
        m_state_cand = jnp.max(lic + b_L[:, None, :] - b, axis=1)  # (B,H)
        m_new = jnp.maximum(m + b_L, m_state_cand)
        m_new = jnp.maximum(m_new, -1e30)
        decay_old = jnp.exp(m + b_L - m_new)           # (B,H)
        wk = jnp.exp(lic + b_L[:, None, :] - b - m_new[:, None, :])  # (B,L,H)
        C_new = C * decay_old[..., None, None] + \
            jnp.einsum("blh,blhd,blhe->bhde", wk, kc, vc)
        n_new = n * decay_old[..., None] + jnp.einsum("blh,blhd->bhd", wk, kc)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(body, (state["C"], state["n"], state["m"]),
                                 (qs, ks, vs, lis, lfs))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dh)
    return h, {"C": C, "n": n, "m": m}


def mlstm_apply(params, x: jax.Array, cfg: ModelConfig, opts: XLSTMOptions) -> jax.Array:
    B, S, d = x.shape
    q, k, v, log_i, log_f, z = _mlstm_qkv_gates(params, x, cfg)
    state = init_mlstm_state(cfg, B, x.dtype)
    h, _ = _mlstm_chunk_scan(q, k, v, log_i, log_f, state, opts.chunk)
    h = h.reshape(B, S, -1).astype(x.dtype)
    out = h * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", out, params["w_down"].astype(x.dtype))


def mlstm_decode(params, x: jax.Array, state: dict, cfg: ModelConfig,
                 opts: XLSTMOptions):
    """One-token recurrent update (O(dh²) per head)."""
    B = x.shape[0]
    q, k, v, log_i, log_f, z = _mlstm_qkv_gates(params, x, cfg)
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]            # (B,H,dh)
    li, lf = log_i[:, 0], log_f[:, 0]                 # (B,H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    m_new = jnp.maximum(m_new, -1e30)
    f_s = jnp.exp(lf + m - m_new)
    i_s = jnp.exp(li - m_new)
    C_new = C * f_s[..., None, None] + \
        i_s[..., None, None] * (k1[..., :, None] * v1[..., None, :])
    n_new = n * f_s[..., None] + i_s[..., None] * k1
    h_num = jnp.einsum("bhd,bhde->bhe", q1, C_new)
    qn = jnp.einsum("bhd,bhd->bh", q1, n_new)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    h = (h_num / denom[..., None]).reshape(B, 1, -1).astype(x.dtype)
    out = h * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", out, params["w_down"].astype(x.dtype))
    return y, {"C": C_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    f = max(1, (4 * d) // 3)        # projection factor 4/3 (xLSTM paper)
    return {
        "w_zifo": ParamDef((d, 4 * d), ("embed", "mlp")),
        "r_zifo": ParamDef((H, dh, 4 * dh), ("heads", "head_dim", "mlp_in"), scale=0.5),
        "b_zifo": ParamDef((4 * d,), ("mlp",), init="zeros"),
        "w_up": ParamDef((d, f), ("embed", "mlp")),
        "w_down": ParamDef((f, d), ("mlp", "embed"), init="scaled"),
    }


def init_slstm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_step(params, cfg: ModelConfig, wx_t, state):
    """wx_t: (B, 4d) precomputed input projection at time t."""
    B = wx_t.shape[0]
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    h_prev = state["h"]                                # (B,d) fp32
    hh = h_prev.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh,
                     params["r_zifo"].astype(jnp.float32)).reshape(B, 4 * d)
    pre = wx_t.astype(jnp.float32) + rec + params["b_zifo"].astype(jnp.float32)
    z, i_t, f_t, o_t = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + state["m"], i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    c_new = f_s * state["c"] + i_s * z
    n_new = f_s * state["n"] + i_s
    h_tilde = c_new / jnp.maximum(n_new, 1e-6)
    h_new = jax.nn.sigmoid(o_t) * h_tilde
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_apply(params, x: jax.Array, cfg: ModelConfig, opts: XLSTMOptions) -> jax.Array:
    B, S, d = x.shape
    cdt = x.dtype
    wx = jnp.einsum("bsd,de->bse", x, params["w_zifo"].astype(cdt))

    def step(state, wx_t):
        new = _slstm_step(params, cfg, wx_t, state)
        return new, new["h"]

    state0 = init_slstm_state(cfg, B, cdt)
    _, hs = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(cdt)                  # (B,S,d)
    up = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, params["w_up"].astype(cdt)))
    return jnp.einsum("bsf,fd->bsd", up, params["w_down"].astype(cdt))


def slstm_decode(params, x: jax.Array, state: dict, cfg: ModelConfig,
                 opts: XLSTMOptions):
    cdt = x.dtype
    wx = jnp.einsum("bsd,de->bse", x, params["w_zifo"].astype(cdt))
    new = _slstm_step(params, cfg, wx[:, 0], state)
    h = new["h"][:, None, :].astype(cdt)
    up = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, params["w_up"].astype(cdt)))
    y = jnp.einsum("bsf,fd->bsd", up, params["w_down"].astype(cdt))
    return y, new
