"""Feed-forward layers: gated (SwiGLU) and vanilla (GELU) MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ParamDef
from .config import ModelConfig

__all__ = ["mlp_defs", "mlp_apply"]


def mlp_defs(cfg: ModelConfig, d_ff: int = 0) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.mlp_gated:
        return {
            "w_gate": ParamDef((d, f), ("embed", "mlp")),
            "w_up": ParamDef((d, f), ("embed", "mlp")),
            "w_down": ParamDef((f, d), ("mlp", "embed"), init="scaled"),
        }
    return {
        "w_up": ParamDef((d, f), ("embed", "mlp")),
        "w_down": ParamDef((f, d), ("mlp", "embed"), init="scaled"),
    }


def mlp_apply(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    cdt = x.dtype
    if cfg.mlp_gated:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(cdt))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(cdt))
        h = jax.nn.silu(g) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(cdt))
        h = jax.nn.gelu(u)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(cdt))
