"""RG-LRU recurrent mixer block (RecurrentGemma / Griffin).

The temporal-mixing half of a recurrent layer:
``x -> {gate branch: linear -> GeLU} ⊙ {recurrent branch: linear -> conv1d(W) -> RG-LRU} -> out proj``

The RG-LRU recurrence itself lives in the kernels package (`ops.rglru`):
associative scan on the XLA path, blocked Pallas scan on TPU.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import ParamDef
from .config import ModelConfig

__all__ = ["rglru_defs", "rglru_apply", "rglru_decode", "init_rglru_state",
           "RGLRUOptions"]


@dataclass(frozen=True)
class RGLRUOptions:
    impl: str = "xla"        # ref | xla | pallas
    block_d: int = 256
    interpret: bool = True


def rglru_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    r = cfg.resolved_lru_dim
    w = cfg.conv_width
    return {
        "w_gate_branch": ParamDef((d, r), ("embed", "lru")),
        "w_rec_branch": ParamDef((d, r), ("embed", "lru")),
        "conv_w": ParamDef((w, r), (None, "lru"), init="scaled"),
        "conv_b": ParamDef((r,), ("lru",), init="zeros"),
        "log_lambda": ParamDef((r,), ("lru",), init="lru_lambda"),
        "w_gate_a": ParamDef((r, r), ("lru", "lru_in"), scale=0.5),
        "w_gate_x": ParamDef((r, r), ("lru", "lru_in"), scale=0.5),
        "w_out": ParamDef((r, d), ("lru", "embed"), init="scaled"),
    }


def _causal_conv(u: jax.Array, conv_w: jax.Array, conv_b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  u: (B,S,R); conv_w: (W,R).
    ``state``: (B, W-1, R) trailing inputs from the previous segment.
    Returns (out (B,S,R), new_state (B,W-1,R))."""
    W = conv_w.shape[0]
    B, S, R = u.shape
    if state is None:
        state = jnp.zeros((B, W - 1, R), u.dtype)
    ext = jnp.concatenate([state.astype(u.dtype), u], axis=1)  # (B, S+W-1, R)
    out = jnp.zeros_like(u)
    for i in range(W):
        out = out + ext[:, i:i + S, :] * conv_w[i][None, None, :].astype(u.dtype)
    out = out + conv_b[None, None, :].astype(u.dtype)
    new_state = ext[:, S:, :] if W > 1 else state
    return out, new_state


def _mix(params, u: jax.Array, opts: RGLRUOptions, h0, conv_state):
    """Shared recurrent-branch computation. u: (B,S,R) post-projection."""
    conv_out, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"],
                                      conv_state)
    gate_a = jnp.einsum("bsr,rq->bsq", conv_out, params["w_gate_a"].astype(u.dtype))
    gate_x = jnp.einsum("bsr,rq->bsq", conv_out, params["w_gate_x"].astype(u.dtype))
    h, h_last = ops.rglru(conv_out, params["log_lambda"], gate_a, gate_x, h0,
                          impl=opts.impl, block_d=opts.block_d,
                          interpret=opts.interpret)
    return h, h_last, new_conv


def rglru_apply(params, x: jax.Array, cfg: ModelConfig, opts: RGLRUOptions) -> jax.Array:
    """Full-sequence mixer.  x: (B,S,d) -> (B,S,d)."""
    cdt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, params["w_gate_branch"].astype(cdt)))
    u = jnp.einsum("bsd,dr->bsr", x, params["w_rec_branch"].astype(cdt))
    h, _, _ = _mix(params, u, opts, None, None)
    return jnp.einsum("bsr,rd->bsd", gate * h, params["w_out"].astype(cdt))


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    r = cfg.resolved_lru_dim
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), dtype),
    }


def rglru_decode(params, x: jax.Array, state: dict, cfg: ModelConfig,
                 opts: RGLRUOptions):
    """One-token step.  x: (B,1,d).  Returns (y, new_state)."""
    cdt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, params["w_gate_branch"].astype(cdt)))
    u = jnp.einsum("bsd,dr->bsr", x, params["w_rec_branch"].astype(cdt))
    h, h_last, new_conv = _mix(params, u, opts, state["h"], state["conv"])
    y = jnp.einsum("bsr,rd->bsd", gate * h, params["w_out"].astype(cdt))
    return y, {"h": h_last, "conv": new_conv}
