"""Mixture-of-Experts FFN with top-k routing.

Dispatch strategies (deployment-searchable):

* ``capacity`` — sort-based static-capacity dispatch (default).  Tokens are
  ranked within their expert group; tokens past the per-expert capacity
  ``C = ceil(T·k/E · capacity_factor)`` are dropped (standard TPU MoE
  practice — static shapes, no data-dependent memory).  Expert compute is a
  stacked einsum over the (E, C, d) buffer, sharded over experts (EP) when
  E divides the model axis, else over the expert hidden dim (TP).
* ``dense``    — every expert computes every token, masked combine.  The
  oracle used in tests; O(E/k) wasteful, never deployed.
* ``gmm``      — grouped matmul over the sorted token matrix (Pallas kernel
  or its XLA twin), skipping capacity padding.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import ParamDef
from .config import ModelConfig

__all__ = ["moe_defs", "moe_apply", "MoEOptions"]


@dataclass(frozen=True)
class MoEOptions:
    impl: str = "capacity"      # capacity | dense | gmm
    capacity_factor: float = 1.25
    min_capacity: int = 4       # capacity floor (matters for tiny token counts)
    gmm_impl: str = "xla"       # xla | pallas (inner grouped-matmul kernel)
    interpret: bool = True


def moe_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    defs = {
        "router": ParamDef((d, e), ("embed", "experts_router")),
        "w_gate": ParamDef((e, d, f), ("experts", "embed", "moe_mlp")),
        "w_up": ParamDef((e, d, f), ("experts", "embed", "moe_mlp")),
        "w_down": ParamDef((e, f, d), ("experts", "moe_mlp", "embed"), init="scaled"),
    }
    if cfg.shared_expert:
        defs["shared"] = {
            "w_gate": ParamDef((d, f), ("embed", "mlp")),
            "w_up": ParamDef((d, f), ("embed", "mlp")),
            "w_down": ParamDef((f, d), ("mlp", "embed"), init="scaled"),
        }
    return defs


def _router(params, xf: jax.Array, cfg: ModelConfig):
    """xf: (T, d) fp32.  Returns top-k (T,k) expert ids, combine weights, and
    the router aux loss (load-balancing, Switch-style)."""
    logits = xf @ params["router"].astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.experts_per_token
    weights, experts = jax.lax.top_k(probs, k)                  # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: E * sum_e (fraction routed to e) * (mean prob of e)
    E = cfg.num_experts
    onehot = jax.nn.one_hot(experts[:, 0], E)                   # top-1 fraction
    aux = E * jnp.mean(onehot.mean(0) * probs.mean(0)) * E
    return experts, weights, aux


def moe_apply(params, x: jax.Array, cfg: ModelConfig, opts: MoEOptions):
    """x: (B,S,d) -> (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    experts, weights, aux = _router(params, xt.astype(jnp.float32), cfg)
    if opts.impl == "dense":
        y = _dense_moe(params, xt, experts, weights, cfg)
    elif opts.impl == "capacity":
        y = _capacity_moe(params, xt, experts, weights, cfg, opts)
    elif opts.impl == "gmm":
        y = _gmm_moe(params, xt, experts, weights, cfg, opts)
    else:
        raise ValueError(f"unknown moe impl {opts.impl!r}")
    if cfg.shared_expert:
        sp = params["shared"]
        cdt = x.dtype
        g = jnp.einsum("td,df->tf", xt, sp["w_gate"].astype(cdt))
        u = jnp.einsum("td,df->tf", xt, sp["w_up"].astype(cdt))
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u,
                           sp["w_down"].astype(cdt))
    return y.reshape(B, S, d).astype(x.dtype), aux


def _expert_ffn(params, xs: jax.Array, cdt, opts: "MoEOptions" = None) -> jax.Array:
    """xs: (E, C, d) -> (E, C, d) through each expert's gated MLP.
    Uses the stacked grouped-matmul primitive (Pallas kernel on TPU)."""
    gi = opts.gmm_impl if opts is not None else "xla"
    interp = opts.interpret if opts is not None else True
    g = ops.gmm_stacked(xs, params["w_gate"], impl=gi, interpret=interp)
    u = ops.gmm_stacked(xs, params["w_up"], impl=gi, interpret=interp)
    return ops.gmm_stacked((jax.nn.silu(g.astype(jnp.float32)) *
                            u.astype(jnp.float32)).astype(cdt),
                           params["w_down"], impl=gi, interpret=interp)


def _dense_moe(params, xt, experts, weights, cfg):
    """Oracle: all experts on all tokens, masked combine."""
    cdt = xt.dtype
    E = cfg.num_experts
    ys = _expert_ffn(params, jnp.broadcast_to(xt, (E,) + xt.shape), cdt)  # (E,T,d)
    combine = jnp.zeros((xt.shape[0], E), jnp.float32)
    for i in range(cfg.experts_per_token):
        combine += jax.nn.one_hot(experts[:, i], E) * weights[:, i:i + 1]
    return jnp.einsum("te,etd->td", combine.astype(cdt), ys)


def _capacity_moe(params, xt, experts, weights, cfg, opts):
    """Sort-based static-capacity dispatch."""
    cdt = xt.dtype
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = max(opts.min_capacity, math.ceil(T * k / E * opts.capacity_factor))
    C = min(C, T)  # never more capacity than tokens

    flat_e = experts.reshape(T * k)                      # expert id per slot
    flat_w = weights.reshape(T * k)
    token_src = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e, stable=True)             # group by expert
    es, ws, src = flat_e[order], flat_w[order], token_src[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[es]                 # rank within expert
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)

    # scatter tokens into the (E, C, d) dispatch buffer
    buf = jnp.zeros((E, C, d), cdt)
    rows = xt[src] * keep[:, None].astype(cdt)
    buf = buf.at[es, pos_c].add(rows)                    # unique (es,pos) when kept

    ys = _expert_ffn(params, buf, cdt, opts)             # (E, C, d)

    y_tok = ys[es, pos_c] * (ws * keep)[:, None].astype(cdt)
    out = jnp.zeros((T, d), cdt).at[src].add(y_tok)
    return out


def _gmm_moe(params, xt, experts, weights, cfg, opts):
    """Grouped-matmul dispatch over sorted tokens (no capacity padding)."""
    cdt = xt.dtype
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    flat_e = experts.reshape(T * k)
    flat_w = weights.reshape(T * k)
    token_src = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    es, ws, src = flat_e[order], flat_w[order], token_src[order]
    group_sizes = jnp.bincount(flat_e, length=E)

    xs = xt[src]                                          # (T·k, d) sorted
    gi = opts.gmm_impl
    g = ops.gmm(xs, params["w_gate"], group_sizes, impl=gi, interpret=opts.interpret)
    u = ops.gmm(xs, params["w_up"], group_sizes, impl=gi, interpret=opts.interpret)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(cdt)
    y = ops.gmm(h, params["w_down"], group_sizes, impl=gi, interpret=opts.interpret)
    y = y * ws[:, None].astype(cdt)
    return jnp.zeros((T, d), cdt).at[src].add(y)
