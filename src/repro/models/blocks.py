"""Superblock machinery: layer dispatch + scan-over-stacked-parameters.

A stage is ``repeat`` copies of a superblock (tuple of LayerSpecs).  The
superblock body is traced once and scanned over parameters stacked on a
leading ``layers`` axis — HLO size is O(superblock), not O(depth), which is
what keeps 95-layer × 512-device dry-run compiles tractable and is the
standard production pattern (MaxText does the same).

Rematerialization policy is applied to the scan body and is a
deployment-configuration dimension.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mlp as mlp_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import xlstm as xlstm_mod
from .attention import AttnOptions
from .common import DTypePolicy, ParamDef, rms_norm, stack_defs
from .config import LayerSpec, ModelConfig, Stage
from .moe import MoEOptions
from .rglru import RGLRUOptions
from .xlstm import XLSTMOptions

__all__ = ["ModelOptions", "layer_defs", "superblock_defs", "stage_defs",
           "stage_apply", "stage_prefill", "stage_decode", "stage_init_cache"]


@dataclass(frozen=True)
class ModelOptions:
    """Every compute-level knob, all deployment-searchable."""

    attn: AttnOptions = AttnOptions()
    moe: MoEOptions = MoEOptions()
    rglru: RGLRUOptions = RGLRUOptions()
    xlstm: XLSTMOptions = XLSTMOptions()
    remat: str = "dots"          # none | full | dots
    aux_loss_weight: float = 0.01
    policy: DTypePolicy = DTypePolicy()
    # activation sharding constraint for the residual stream (batch_axes,
    # seq_axis); None disables (single-device tests).  Without this, 2-D
    # (FSDP×TP) weight sharding makes XLA replicate the batch — the classic
    # propagation failure; constraining the residual stream at every layer
    # boundary is the standard fix (MaxText does the same).
    act_sharding: Optional[tuple] = None


def constrain_acts(x: jax.Array, opts: "ModelOptions") -> jax.Array:
    """Pin the residual stream to (batch→DP axes, seq→SP axis, d→None)."""
    if opts.act_sharding is None:
        return x
    from jax.sharding import PartitionSpec as P
    batch_axes, seq_axis = opts.act_sharding
    return jax.lax.with_sharding_constraint(
        x, P(tuple(batch_axes), seq_axis, None))


# ---------------------------------------------------------------------------
# per-layer defs / apply / decode / state
# ---------------------------------------------------------------------------


def _norm_def(cfg: ModelConfig) -> ParamDef:
    return ParamDef((cfg.d_model,), ("embed",), init="zeros")


def layer_defs(cfg: ModelConfig, spec: LayerSpec) -> dict:
    if spec.kind == "attn":
        return {"norm1": _norm_def(cfg), "attn": attn_mod.attention_defs(cfg),
                "norm2": _norm_def(cfg), "mlp": mlp_mod.mlp_defs(cfg)}
    if spec.kind == "moe":
        return {"norm1": _norm_def(cfg), "attn": attn_mod.attention_defs(cfg),
                "norm2": _norm_def(cfg), "moe": moe_mod.moe_defs(cfg)}
    if spec.kind == "rglru":
        return {"norm1": _norm_def(cfg), "mix": rglru_mod.rglru_defs(cfg),
                "norm2": _norm_def(cfg), "mlp": mlp_mod.mlp_defs(cfg)}
    if spec.kind == "mlstm":
        return {"norm1": _norm_def(cfg), "mlstm": xlstm_mod.mlstm_defs(cfg)}
    if spec.kind == "slstm":
        return {"norm1": _norm_def(cfg), "slstm": xlstm_mod.slstm_defs(cfg)}
    raise ValueError(spec.kind)


def layer_apply(spec: LayerSpec, p: dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, opts: ModelOptions):
    """Full-sequence layer.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    if spec.kind in ("attn", "moe"):
        h = attn_mod.attention_apply(p["attn"], rms_norm(x, p["norm1"], eps),
                                     cfg, positions, spec.window, opts.attn)
        x = x + h
        if spec.kind == "attn":
            x = x + mlp_mod.mlp_apply(p["mlp"], rms_norm(x, p["norm2"], eps), cfg)
        else:
            y, aux = moe_mod.moe_apply(p["moe"], rms_norm(x, p["norm2"], eps),
                                       cfg, opts.moe)
            x = x + y
    elif spec.kind == "rglru":
        x = x + rglru_mod.rglru_apply(p["mix"], rms_norm(x, p["norm1"], eps),
                                      cfg, opts.rglru)
        x = x + mlp_mod.mlp_apply(p["mlp"], rms_norm(x, p["norm2"], eps), cfg)
    elif spec.kind == "mlstm":
        x = x + xlstm_mod.mlstm_apply(p["mlstm"], rms_norm(x, p["norm1"], eps),
                                      cfg, opts.xlstm)
    elif spec.kind == "slstm":
        x = x + xlstm_mod.slstm_apply(p["slstm"], rms_norm(x, p["norm1"], eps),
                                      cfg, opts.xlstm)
    return x, aux


def layer_init_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     capacity: int, dtype) -> dict:
    if spec.kind in ("attn", "moe"):
        return attn_mod.init_kv_cache(cfg, batch, capacity, spec.window, dtype)
    if spec.kind == "rglru":
        return rglru_mod.init_rglru_state(cfg, batch, dtype)
    if spec.kind == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch, dtype)
    if spec.kind == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch, dtype)
    raise ValueError(spec.kind)


def layer_prefill(spec: LayerSpec, p: dict, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array, capacity: int, opts: ModelOptions):
    """Full-sequence layer that also emits its decode cache/state."""
    eps = cfg.norm_eps
    if spec.kind in ("attn", "moe"):
        h, cache = attn_mod.prefill_kv_cache(
            p["attn"], rms_norm(x, p["norm1"], eps), cfg, positions,
            spec.window, capacity, opts.attn)
        x = x + h
        if spec.kind == "attn":
            x = x + mlp_mod.mlp_apply(p["mlp"], rms_norm(x, p["norm2"], eps), cfg)
        else:
            y, _ = moe_mod.moe_apply(p["moe"], rms_norm(x, p["norm2"], eps),
                                     cfg, opts.moe)
            x = x + y
        return x, cache
    if spec.kind == "rglru":
        xin = rms_norm(x, p["norm1"], eps)
        cdt = x.dtype
        gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", xin,
                                      p["mix"]["w_gate_branch"].astype(cdt)))
        u = jnp.einsum("bsd,dr->bsr", xin, p["mix"]["w_rec_branch"].astype(cdt))
        h, h_last, conv_state = rglru_mod._mix(p["mix"], u, opts.rglru, None, None)
        x = x + jnp.einsum("bsr,rd->bsd", gate * h, p["mix"]["w_out"].astype(cdt))
        x = x + mlp_mod.mlp_apply(p["mlp"], rms_norm(x, p["norm2"], eps), cfg)
        return x, {"h": h_last, "conv": conv_state}
    if spec.kind == "mlstm":
        xin = rms_norm(x, p["norm1"], eps)
        q, k, v, li, lf, z = xlstm_mod._mlstm_qkv_gates(p["mlstm"], xin, cfg)
        state0 = xlstm_mod.init_mlstm_state(cfg, x.shape[0], x.dtype)
        h, state = xlstm_mod._mlstm_chunk_scan(q, k, v, li, lf, state0,
                                               opts.xlstm.chunk)
        h = h.reshape(x.shape[0], x.shape[1], -1).astype(x.dtype)
        out = h * jax.nn.silu(z)
        x = x + jnp.einsum("bse,ed->bsd", out,
                           p["mlstm"]["w_down"].astype(x.dtype))
        return x, state
    if spec.kind == "slstm":
        xin = rms_norm(x, p["norm1"], eps)
        cdt = x.dtype
        wx = jnp.einsum("bsd,de->bse", xin, p["slstm"]["w_zifo"].astype(cdt))

        def step(state, wx_t):
            new = xlstm_mod._slstm_step(p["slstm"], cfg, wx_t, state)
            return new, new["h"]

        state0 = xlstm_mod.init_slstm_state(cfg, x.shape[0], cdt)
        state, hs = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
        h = hs.swapaxes(0, 1).astype(cdt)
        up = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h,
                                    p["slstm"]["w_up"].astype(cdt)))
        x = x + jnp.einsum("bsf,fd->bsd", up, p["slstm"]["w_down"].astype(cdt))
        return x, state
    raise ValueError(spec.kind)


def layer_decode(spec: LayerSpec, p: dict, x: jax.Array, cache: dict, index,
                 cfg: ModelConfig, opts: ModelOptions):
    """One-token layer step.  Returns (x, new_cache)."""
    eps = cfg.norm_eps
    if spec.kind in ("attn", "moe"):
        h, cache = attn_mod.attention_decode(p["attn"], rms_norm(x, p["norm1"], eps),
                                             cache, index, cfg, spec.window,
                                             opts.attn)
        x = x + h
        if spec.kind == "attn":
            x = x + mlp_mod.mlp_apply(p["mlp"], rms_norm(x, p["norm2"], eps), cfg)
        else:
            y, _ = moe_mod.moe_apply(p["moe"], rms_norm(x, p["norm2"], eps),
                                     cfg, opts.moe)
            x = x + y
        return x, cache
    if spec.kind == "rglru":
        h, cache = rglru_mod.rglru_decode(p["mix"], rms_norm(x, p["norm1"], eps),
                                          cache, cfg, opts.rglru)
        x = x + h
        x = x + mlp_mod.mlp_apply(p["mlp"], rms_norm(x, p["norm2"], eps), cfg)
        return x, cache
    if spec.kind == "mlstm":
        h, cache = xlstm_mod.mlstm_decode(p["mlstm"], rms_norm(x, p["norm1"], eps),
                                          cache, cfg, opts.xlstm)
        return x + h, cache
    if spec.kind == "slstm":
        h, cache = xlstm_mod.slstm_decode(p["slstm"], rms_norm(x, p["norm1"], eps),
                                          cache, cfg, opts.xlstm)
        return x + h, cache
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# stage = scan over stacked superblocks
# ---------------------------------------------------------------------------


def superblock_defs(cfg: ModelConfig, stage: Stage) -> dict:
    return {f"l{i}": layer_defs(cfg, spec)
            for i, spec in enumerate(stage.superblock)}


def stage_defs(cfg: ModelConfig, stage: Stage) -> dict:
    return stack_defs(superblock_defs(cfg, stage), stage.repeat)


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if remat == "dots":
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(f"unknown remat policy {remat!r}")


def stage_apply(stage: Stage, params: dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, opts: ModelOptions):
    """Training/inference forward through a stage.  Returns (x, aux)."""

    def body(carry, layer_params):
        x, aux = carry
        for i, spec in enumerate(stage.superblock):
            x, a = layer_apply(spec, layer_params[f"l{i}"], x, cfg, positions, opts)
            x = constrain_acts(x, opts)
            aux = aux + a
        return (x, aux), None

    body = _maybe_remat(body, opts.remat)
    x = constrain_acts(x, opts)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params)
    return x, aux


def stage_init_cache(stage: Stage, cfg: ModelConfig, batch: int, capacity: int,
                     dtype) -> dict:
    out = {}
    for i, spec in enumerate(stage.superblock):
        single = layer_init_cache(spec, cfg, batch, capacity, dtype)
        out[f"l{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (stage.repeat,) + a.shape), single)
    return out


def stage_prefill(stage: Stage, params: dict, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array, capacity: int, opts: ModelOptions):
    """Forward + emit stacked caches.  Returns (x, caches)."""

    def body(x, layer_params):
        caches = {}
        for i, spec in enumerate(stage.superblock):
            x, c = layer_prefill(spec, layer_params[f"l{i}"], x, cfg, positions,
                                 capacity, opts)
            x = constrain_acts(x, opts)
            caches[f"l{i}"] = c
        return x, caches

    body = _maybe_remat(body, opts.remat)
    x = constrain_acts(x, opts)
    x, caches = jax.lax.scan(body, x, params)
    return x, caches


def stage_decode(stage: Stage, params: dict, caches: dict, x: jax.Array,
                 index, cfg: ModelConfig, opts: ModelOptions):
    """One-token step through a stage.  Returns (x, new_caches)."""

    def body(x, xs):
        layer_params, layer_caches = xs
        new = {}
        for i, spec in enumerate(stage.superblock):
            x, c = layer_decode(spec, layer_params[f"l{i}"], x,
                                layer_caches[f"l{i}"], index, cfg, opts)
            x = constrain_acts(x, opts)
            new[f"l{i}"] = c
        return x, new

    x, new_caches = jax.lax.scan(body, x, (params, caches))
    return x, new_caches
