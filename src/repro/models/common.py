"""Shared building blocks: parameter definitions (with logical sharding axes),
norms, rotary embeddings, and initialization.

Every parameter is declared as a :class:`ParamDef` carrying *logical axis
names*.  Initialization and PartitionSpec generation both traverse the same
def-tree, so the sharding rules can never drift from the parameter structure
— and the logical→mesh-axis rule table itself is part of the deployment
configuration, i.e. searchable by the Discovery Space machinery.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamDef", "init_tree", "spec_tree", "stack_defs", "rms_norm",
           "make_rope", "apply_rope", "DTypePolicy"]


@dataclass(frozen=True)
class DTypePolicy:
    """Mixed-precision policy (part of the deployment configuration)."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    logits_dtype: Any = jnp.float32


@dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor.

    ``logical_axes`` name each dimension; the distributed layer maps names to
    mesh axes (e.g. ``embed -> 'data'`` for FSDP, ``heads -> 'model'`` for TP).
    """

    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | scaled | lru_lambda
    scale: float = 1.0
    fan_axis: int = 0         # which axis is fan-in (shifted by stacking)

    def __post_init__(self):
        if len(self.shape) != len(self.logical_axes):
            raise ValueError(f"shape {self.shape} vs axes {self.logical_axes}")

    def initialize(self, key: jax.Array, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init in ("normal", "scaled"):
            fan_in = self.shape[self.fan_axis] if len(self.shape) > self.fan_axis else 1
            std = self.scale / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape) * std).astype(dtype)
        if self.init == "lru_lambda":
            # RG-LRU recurrence parameter: log(-log λ) with λ ∈ [0.9, 0.999]
            u = jax.random.uniform(key, self.shape, minval=0.9, maxval=0.999)
            return jnp.log(-jnp.log(u)).astype(dtype)
        raise ValueError(f"unknown init {self.init!r}")


def init_tree(defs: Mapping, key: jax.Array, dtype) -> dict:
    """Initialize a (nested) tree of ParamDefs into a matching array tree."""
    flat = []

    def _collect(d, path):
        if isinstance(d, ParamDef):
            flat.append((path, d))
        else:
            for k in sorted(d.keys()):
                _collect(d[k], path + (k,))

    _collect(defs, ())
    keys = jax.random.split(key, max(len(flat), 1))
    out: dict = {}
    for (path, pdef), k in zip(flat, keys):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = pdef.initialize(k, dtype)
    return out


def spec_tree(defs: Mapping) -> dict:
    """Mirror of the def-tree holding logical-axis tuples."""
    if isinstance(defs, ParamDef):
        return defs.logical_axes
    return {k: spec_tree(v) for k, v in defs.items()}


def stack_defs(defs: Mapping, repeat: int) -> dict:
    """Prepend a scanned 'layers' axis of size `repeat` to every def.

    The fan-in axis shifts with the stacking so per-layer init statistics
    are identical to the unstacked layer's."""
    if isinstance(defs, ParamDef):
        return ParamDef(
            shape=(repeat,) + defs.shape,
            logical_axes=("layers",) + defs.logical_axes,
            init=defs.init,
            scale=defs.scale,
            fan_axis=defs.fan_axis + 1,
        )
    return {k: stack_defs(v, repeat) for k, v in defs.items()}


# ---------------------------------------------------------------------------
# Norms & rotary position embeddings
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 statistics but WITHOUT materializing an fp32 copy of
    x: scan autodiff stacks any fp32 intermediate that depends on the carry
    as a per-layer residual — a (B,S,d) fp32 copy per layer doubles
    activation memory.  Computing only the (B,S,1) scale in fp32 keeps the
    stacked residual 1/d the size, at identical statistics precision (the
    final multiply rounds to compute dtype either way)."""
    dtype = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps)                     # (B, S, 1) fp32
    gamma32 = 1.0 + gamma.astype(jnp.float32)            # (d,)
    return x * (scale.astype(dtype)) * gamma32.astype(dtype)


def make_rope(positions: jax.Array, head_dim: int, theta: float = 10000.0,
              fraction: float = 1.0):
    """(sin, cos) tables for rotary embedding.

    ``fraction < 1`` applies rotary to the leading ``fraction·head_dim`` dims
    (ChatGLM-style 2d/partial rotary approximation); the rest pass through.
    """
    rot_dim = int(head_dim * fraction)
    rot_dim -= rot_dim % 2
    freq = theta ** (-jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, rot/2)
    return jnp.sin(angles), jnp.cos(angles), rot_dim


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array, rot_dim: int) -> jax.Array:
    """x: (B, S, H, D); sin/cos: (B, S, rot_dim//2) (positions always (B, S))."""
    if rot_dim == 0:
        return x
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    sin = sin[:, :, None, :].astype(jnp.float32)  # (B, S, 1, rot/2)
    cos = cos[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = x1f * cos - x2f * sin
    r2 = x2f * cos + x1f * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)
