"""LMModel: embed/frontend -> stages -> final norm -> head.

One composable model class covers all ten assigned architectures; the
architecture is entirely described by :class:`ModelConfig` (stage pattern +
dimensions) and the compute knobs by :class:`ModelOptions`.

API:
  * ``init(key)`` / ``param_defs()`` / ``logical_specs()``
  * ``forward(params, batch)``               — full-sequence logits
  * ``loss(params, batch)``                  — LM cross-entropy (+ MoE aux)
  * ``init_cache`` / ``prefill`` / ``decode_step`` — serving path
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .blocks import (ModelOptions, constrain_acts, stage_apply, stage_decode,
                     stage_defs, stage_init_cache, stage_prefill)
from .common import DTypePolicy, ParamDef, init_tree, rms_norm, spec_tree
from .config import ModelConfig

__all__ = ["LMModel", "ModelOptions"]


class LMModel:
    def __init__(self, cfg: ModelConfig, options: Optional[ModelOptions] = None):
        self.cfg = cfg
        self.options = options if options is not None else ModelOptions()

    # ------------------------------------------------------------- parameters

    def param_defs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        defs: dict = {}
        if cfg.uses_tokens:
            defs["embed"] = ParamDef((cfg.vocab_size, d), ("vocab", "embed"))
        else:
            defs["frontend"] = ParamDef((cfg.frontend_dim, d),
                                        ("frontend", "embed"))
        for si, stage in enumerate(cfg.stages):
            defs[f"stage{si}"] = stage_defs(cfg, stage)
        defs["final_norm"] = ParamDef((d,), ("embed",), init="zeros")
        defs["head"] = ParamDef((d, cfg.vocab_size), ("embed", "vocab"))
        return defs

    def init(self, key: jax.Array) -> dict:
        return init_tree(self.param_defs(), key, self.options.policy.param_dtype)

    def logical_specs(self) -> dict:
        return spec_tree(self.param_defs())

    # ------------------------------------------------------------ embeddings

    def _embed(self, params: dict, batch: dict) -> jax.Array:
        cdt = self.options.policy.compute_dtype
        if self.cfg.uses_tokens:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        else:
            x = jnp.einsum("bsf,fd->bsd",
                           batch["embeds"].astype(cdt),
                           params["frontend"].astype(cdt))
        return constrain_acts(x.astype(cdt), self.options)

    @staticmethod
    def _positions(batch: dict, seq: int, bsz: int) -> jax.Array:
        if "positions" in batch:
            return batch["positions"]
        return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (bsz, seq))

    # ---------------------------------------------------------------- forward

    def forward(self, params: dict, batch: dict):
        """Returns (logits (B,S,V) in logits_dtype, aux_loss scalar)."""
        cfg, opts = self.cfg, self.options
        x = self._embed(params, batch)
        B, S, _ = x.shape
        positions = self._positions(batch, S, B)
        aux = jnp.zeros((), jnp.float32)
        for si, stage in enumerate(cfg.stages):
            x, a = stage_apply(stage, params[f"stage{si}"], x, cfg, positions, opts)
            aux = aux + a
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x.astype(opts.policy.logits_dtype),
                            params["head"].astype(opts.policy.logits_dtype))
        return logits, aux

    def loss(self, params: dict, batch: dict):
        """LM cross-entropy.  batch: tokens/embeds + labels (B,S) int32;
        optional loss_mask (B,S).  Returns (loss, metrics dict)."""
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        label_logit = jnp.take_along_axis(logits, labels[..., None],
                                          axis=-1)[..., 0]
        nll = logz - label_logit
        mask = batch.get("loss_mask")
        if mask is not None:
            nll = nll * mask
            denom = jnp.maximum(mask.sum(), 1.0)
        else:
            denom = float(nll.size)
        ce = nll.sum() / denom
        total = ce + self.options.aux_loss_weight * aux
        return total, {"ce": ce, "aux": aux}

    # ---------------------------------------------------------------- serving

    def init_cache(self, batch_size: int, capacity: int) -> dict:
        dtype = self.options.policy.compute_dtype
        return {f"stage{si}": stage_init_cache(stage, self.cfg, batch_size,
                                               capacity, dtype)
                for si, stage in enumerate(self.cfg.stages)}

    def prefill(self, params: dict, batch: dict, capacity: int):
        """Full-sequence forward that also builds decode caches.
        Returns (last-position logits (B,V), caches)."""
        cfg, opts = self.cfg, self.options
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only: no decode path")
        x = self._embed(params, batch)
        B, S, _ = x.shape
        positions = self._positions(batch, S, B)
        caches = {}
        for si, stage in enumerate(cfg.stages):
            x, c = stage_prefill(stage, params[f"stage{si}"], x, cfg, positions,
                                 capacity, opts)
            caches[f"stage{si}"] = c
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        last = x[:, -1]
        logits = jnp.einsum("bd,dv->bv", last.astype(opts.policy.logits_dtype),
                            params["head"].astype(opts.policy.logits_dtype))
        return logits, caches

    def decode_step(self, params: dict, batch: dict, caches: dict, index):
        """One decode step.  batch: tokens (B,1) or embeds (B,1,F); ``index``
        is the absolute position of the new token (traced scalar).
        Returns (logits (B,V), new_caches)."""
        cfg, opts = self.cfg, self.options
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only: no decode path")
        x = self._embed(params, batch)
        new_caches = {}
        for si, stage in enumerate(cfg.stages):
            x, c = stage_decode(stage, params[f"stage{si}"], caches[f"stage{si}"],
                                x, index, cfg, opts)
            new_caches[f"stage{si}"] = c
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x.astype(opts.policy.logits_dtype),
                            params["head"].astype(opts.policy.logits_dtype))
        return logits[:, 0], new_caches
