"""Architecture configuration: one dataclass covering all assigned families.

A model is ``embed/frontend -> stages -> final norm -> head`` where each
:class:`Stage` is ``repeat`` copies of a *superblock* (a short sequence of
:class:`LayerSpec`), executed as ``lax.scan`` over stacked parameters.  This
keeps lowered HLO size independent of depth — a 95-layer model compiles the
superblock body once.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

__all__ = ["LayerSpec", "Stage", "ModelConfig"]


@dataclass(frozen=True)
class LayerSpec:
    """One layer unit inside a superblock.

    kind:
      * ``attn``   — attention mixer + FFN.  ``window`` None => global.
      * ``moe``    — attention mixer + MoE FFN.
      * ``rglru``  — RG-LRU recurrent mixer + FFN (RecurrentGemma).
      * ``mlstm``  — self-contained mLSTM block (matrix memory).
      * ``slstm``  — self-contained sLSTM block (scalar memory).
    """

    kind: str = "attn"
    window: Optional[int] = None   # sliding-window size for local attention

    def __post_init__(self):
        if self.kind not in ("attn", "moe", "rglru", "mlstm", "slstm"):
            raise ValueError(f"unknown layer kind {self.kind!r}")

    @property
    def has_recurrent_state(self) -> bool:
        return self.kind in ("rglru", "mlstm", "slstm")

    @property
    def has_kv_cache(self) -> bool:
        return self.kind in ("attn", "moe")


@dataclass(frozen=True)
class Stage:
    superblock: Tuple[LayerSpec, ...]
    repeat: int

    @property
    def num_layers(self) -> int:
        return len(self.superblock) * self.repeat


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    stages: Tuple[Stage, ...]
    head_dim: int = 0           # 0 => d_model // num_heads
    causal: bool = True         # False for encoder-only (hubert)
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False
    # recurrent
    lru_dim: int = 0            # 0 => d_model
    conv_width: int = 4
    # rotary
    rope_theta: float = 10000.0
    rotary_fraction: float = 1.0   # chatglm applies rotary to half the dims
    # frontend stub ('' => token ids; 'patch' / 'frame' => embeddings input)
    frontend: str = ""
    frontend_dim: int = 0
    # misc
    norm_eps: float = 1e-6
    mlp_gated: bool = True      # SwiGLU vs GELU-MLP
    sub_quadratic: bool = False # eligible for long_500k
    notes: str = ""

    def __post_init__(self):
        total = sum(s.num_layers for s in self.stages)
        if total != self.num_layers:
            raise ValueError(
                f"{self.name}: stages sum to {total} layers, expected {self.num_layers}"
            )
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads {self.num_heads} not divisible "
                             f"by kv heads {self.num_kv_heads}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_lru_dim(self) -> int:
        return self.lru_dim or self.d_model

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def uses_tokens(self) -> bool:
        return self.frontend == ""

    def scaled(self, **overrides) -> "ModelConfig":
        """Build a reduced config of the same family (smoke tests)."""
        from dataclasses import replace
        return replace(self, **overrides)

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D model FLOPs)."""
        d, hd = self.d_model, self.resolved_head_dim
        h, hkv = self.num_heads, self.num_kv_heads
        n = 0
        if self.uses_tokens:
            n += self.vocab_size * d          # embedding
        else:
            n += self.frontend_dim * d        # frontend projection
        n += d * self.vocab_size              # head
        for stage in self.stages:
            for spec in stage.superblock:
                if spec.kind in ("attn", "moe"):
                    attn = d * h * hd + 2 * d * hkv * hd + h * hd * d
                    n_ffn = 0
                    if spec.kind == "attn":
                        f = self.d_ff
                        n_ffn = (3 if self.mlp_gated else 2) * d * f
                    else:
                        f = self.moe_d_ff or self.d_ff
                        n_ffn = self.num_experts * 3 * d * f + d * self.num_experts
                        if self.shared_expert:
                            n_ffn += 3 * d * f
                    n += (attn + n_ffn + 2 * d) * stage.repeat
                elif spec.kind == "rglru":
                    r = self.resolved_lru_dim
                    mix = 2 * d * r + r * self.conv_width + 2 * r * (r // 8) + 2 * r + r * d
                    ffn = (3 if self.mlp_gated else 2) * d * self.d_ff
                    n += (mix + ffn + 2 * d) * stage.repeat
                elif spec.kind == "mlstm":
                    # up-proj x2 (pf=2), qkv on inner dim, gates, out
                    inner = 2 * d
                    n += (2 * d * inner + 3 * inner * inner // 1 + inner * d
                          + 2 * d) * stage.repeat // 1
                elif spec.kind == "slstm":
                    inner = d
                    n += (4 * d * inner + 4 * inner * (inner // max(self.num_heads, 1))
                          + (4 * d * inner) // 3 + 2 * d) * stage.repeat
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.num_experts == 0:
            return self.param_count()
        d = self.d_model
        f = self.moe_d_ff or self.d_ff
        total = self.param_count()
        inactive_experts = self.num_experts - self.experts_per_token
        moe_layers = sum(
            stage.repeat * sum(1 for s in stage.superblock if s.kind == "moe")
            for stage in self.stages
        )
        return total - moe_layers * inactive_experts * 3 * d * f
