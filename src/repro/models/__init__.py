"""Model substrate: composable LM architectures (dense / MoE / hybrid /
recurrent / encoder-only) defined as parameter-def trees + pure apply
functions, scanned over superblock patterns for O(1)-in-depth HLO."""

from .config import ModelConfig, LayerSpec, Stage
from .model import LMModel

__all__ = ["ModelConfig", "LayerSpec", "Stage", "LMModel"]
