"""GQA attention layer (mixer half of a transformer layer).

Supports: causal/global, sliding-window (local), bidirectional (encoder),
rotary embeddings with partial-rotary fraction, and single-token decode over
either a full KV cache or a ring-buffer window cache.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..kernels import ops
from .common import ParamDef, apply_rope, make_rope
from .config import ModelConfig

__all__ = ["attention_defs", "attention_apply", "attention_decode",
           "init_kv_cache", "AttnOptions"]


@dataclass(frozen=True)
class AttnOptions:
    """Deployment-searchable attention options."""

    impl: str = "xla"         # ref | xla | pallas
    q_chunk: int = 512
    kv_chunk: int = 512
    band_skip: bool = True
    interpret: bool = True    # pallas interpret mode (CPU container)
    # shard query heads over this mesh axis inside attention even when the
    # head count doesn't divide it (GSPMD pads) — rescues architectures like
    # llama4 (40 heads vs 16-way TP) from replicated attention compute
    shard_heads: Optional[str] = None
    shard_batch: tuple = ()


def _constrain_heads(x: jax.Array, opts: "AttnOptions") -> jax.Array:
    if opts.shard_heads is None:
        return x
    from jax.sharding import PartitionSpec as P
    bt = tuple(opts.shard_batch) or None
    return jax.lax.with_sharding_constraint(
        x, P(bt, None, opts.shard_heads, None))


def attention_defs(cfg: ModelConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed"), init="scaled"),
    }


def _project_qkv(params, x, cfg: ModelConfig, positions):
    """x: (B,S,d) -> q (B,S,H,hd), k/v (B,S,Hkv,hd), rope applied."""
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(cdt))
    sin, cos, rot_dim = make_rope(positions, cfg.resolved_head_dim,
                                  cfg.rope_theta, cfg.rotary_fraction)
    q = apply_rope(q, sin, cos, rot_dim)
    k = apply_rope(k, sin, cos, rot_dim)
    return q, k, v


def attention_apply(params, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
                    window: Optional[int], opts: AttnOptions) -> jax.Array:
    """Full-sequence attention.  x: (B,S,d); positions: (B,S)."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    q = _constrain_heads(q, opts)
    out = ops.attention(
        q, k, v, causal=cfg.causal, window=window, impl=opts.impl,
        q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
        band_skip=opts.band_skip, interpret=opts.interpret,
    )
    out = _constrain_heads(out, opts)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int,
                  window: Optional[int], dtype) -> dict:
    """KV cache for one attention layer.  Window layers use a ring buffer of
    capacity min(window, capacity) — this is what makes 5:1 local:global and
    1-attn:2-recurrent architectures cheap at long context."""
    c = min(window, capacity) if window is not None else capacity
    shape = (batch, c, cfg.num_kv_heads, cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(params, x: jax.Array, cache: dict, index,
                     cfg: ModelConfig, window: Optional[int],
                     opts: AttnOptions):
    """One-token decode.  x: (B,1,d); index: absolute position (traced scalar).

    Keys are stored post-rope, so the ring buffer needs no position metadata
    beyond ``index``.  Returns (out (B,1,d), new_cache).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), index, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)

    capacity = cache["k"].shape[1]
    ring = window is not None and capacity <= window
    slot = (index % capacity) if ring else index
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                           (0, slot, 0, 0))
    out = ops.decode_attention(q, k_cache, v_cache, index=index, window=window,
                               ring=ring, impl=opts.impl)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"k": k_cache, "v": v_cache}


def prefill_kv_cache(params, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
                     window: Optional[int], capacity: int, opts: AttnOptions):
    """Full-sequence attention that also returns the populated KV cache."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    out = ops.attention(q, k, v, causal=cfg.causal, window=window,
                        impl=opts.impl, q_chunk=opts.q_chunk,
                        kv_chunk=opts.kv_chunk, band_skip=opts.band_skip,
                        interpret=opts.interpret)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    S = x.shape[1]
    c = min(window, capacity) if window is not None else capacity
    if S >= c:
        k_cache, v_cache = k[:, S - c:], v[:, S - c:]
        if window is not None:
            # ring layout: position p lives at slot p % c
            shift = (S - c) % c
            k_cache = jnp.roll(k_cache, shift, axis=1)
            v_cache = jnp.roll(v_cache, shift, axis=1)
    else:
        pad = [(0, 0), (0, c - S), (0, 0), (0, 0)]
        k_cache, v_cache = jnp.pad(k, pad), jnp.pad(v, pad)
    return y, {"k": k_cache, "v": v_cache}
