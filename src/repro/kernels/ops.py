"""Jitted dispatch wrappers for the kernel package.

Every hot-spot has three interchangeable implementations selected by the
deployment configuration (and therefore searchable by the Discovery Space
machinery):

* ``ref``    — pure-jnp oracle (full materialization; tests/small shapes).
* ``xla``    — memory-bounded lax.scan implementations (production fallback,
               and what the CPU-only dry-run lowers).
* ``pallas`` — the TPU Pallas kernels with explicit VMEM BlockSpecs
               (validated on CPU via interpret=True).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref
from . import xla_attn as _xla_attn

__all__ = ["attention", "decode_attention", "rglru", "gmm"]


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              q_offset: int = 0, impl: str = "xla",
              q_chunk: int = 512, kv_chunk: int = 512,
              band_skip: bool = True, interpret: bool = True) -> jax.Array:
    """Full-sequence GQA attention.  q: (B,S,H,D); k/v: (B,S,Hkv,D)."""
    if impl == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)
    if impl == "xla":
        B, Sq, H, D = q.shape
        Sk = k.shape[1]
        cq, ck = min(q_chunk, Sq), min(kv_chunk, Sk)
        pad_q = (-Sq) % cq
        pad_k = (-Sk) % ck
        if pad_q or pad_k:
            qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
            kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
            out = _xla_attn.attention_banded(qp, kp, vp, causal, window,
                                             q_offset, cq, ck, band_skip, Sk)
            return out[:, :Sq]
        return _xla_attn.attention_banded(q, k, v, causal, window, q_offset,
                                          cq, ck, band_skip, None)
    if impl == "pallas":
        from . import flash_attention as _fa
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, block_q=q_chunk,
                                   block_kv=kv_chunk, interpret=interpret)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     index, window: Optional[int] = None, ring: bool = False,
                     impl: str = "xla") -> jax.Array:
    """One-token attention over a KV cache (all impls share the ref path —
    decode scores are O(S) and memory-light)."""
    return _ref.decode_attention_ref(q, k_cache, v_cache, index=index,
                                     window=window, ring=ring)


def rglru(x: jax.Array, log_a: jax.Array, gate_a: jax.Array, gate_x: jax.Array,
          h0: Optional[jax.Array] = None, *, impl: str = "xla",
          block_d: int = 256, interpret: bool = True):
    """RG-LRU linear recurrence.  x/gates: (B,S,D); returns ((B,S,D), (B,D))."""
    if impl == "ref":
        return _ref.rglru_ref(x, log_a, gate_a, gate_x, h0)
    if impl == "xla":
        return _rglru_assoc(x, log_a, gate_a, gate_x, h0)
    if impl == "pallas":
        from . import rglru_scan as _rg
        return _rg.rglru_pallas(x, log_a, gate_a, gate_x, h0,
                                block_d=block_d, interpret=interpret)
    raise ValueError(f"unknown rglru impl {impl!r}")


def _rglru_assoc(x, log_a, gate_a, gate_x, h0=None, c: float = 8.0):
    """Parallel (associative-scan) RG-LRU — the XLA production path:
    O(S log S) depth instead of O(S) sequential steps."""
    B, S, D = x.shape
    xf = x.astype(jnp.float32)
    a_exp = -c * jax.nn.softplus(log_a.astype(jnp.float32))[None, None, :] * \
        jax.nn.sigmoid(gate_a.astype(jnp.float32))
    a = jnp.exp(a_exp)
    gated_x = jax.nn.sigmoid(gate_x.astype(jnp.float32)) * xf
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def gmm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
        impl: str = "xla", block_m: int = 128, interpret: bool = True) -> jax.Array:
    """Grouped matmul: x (T,d) rows grouped contiguously; w (E,d,f)."""
    if impl in ("ref", "xla"):
        return _ref.gmm_ref(x, w, group_sizes)  # XLA path shares the oracle
    if impl == "pallas":
        from . import gmm as _gmm
        return _gmm.gmm_pallas(x, w, group_sizes, block_m=block_m,
                               interpret=interpret)
    raise ValueError(f"unknown gmm impl {impl!r}")


def gmm_stacked(xs: jax.Array, w: jax.Array, *, impl: str = "xla",
                block_m: int = 128, block_n: int = 128, block_k: int = 128,
                interpret: bool = True) -> jax.Array:
    """Static-capacity grouped matmul: xs (E,C,d) × w (E,d,f) -> (E,C,f).
    This is the production MoE expert-compute primitive on TPU."""
    if impl in ("ref", "xla"):
        return jnp.einsum("ecd,edf->ecf", xs, w.astype(xs.dtype))
    if impl == "pallas":
        from . import gmm as _gmm
        return _gmm.gmm_stacked_pallas(xs, w, block_m=block_m, block_n=block_n,
                                       block_k=block_k, interpret=interpret)
    raise ValueError(f"unknown gmm impl {impl!r}")
