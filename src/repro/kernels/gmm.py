"""Pallas TPU grouped matmul for MoE expert compute.

TPU adaptation note (see DESIGN.md): GPU MegaBlocks-style gmm handles
*dynamic* group boundaries with data-dependent tile→expert maps.  On TPU the
production MoE path (``moe.py`` 'capacity' dispatch) produces a *static*
uniform-capacity layout (E, C, d), so the kernel is a block-tiled batched
matmul over experts — every matmul dim MXU-aligned, accumulation over the
contraction dim in fp32 VMEM scratch:

  grid = (E, C/block_m, f/block_n, d/block_k)   (k innermost)

The dynamic-group-sizes variant stays on the XLA path (`ref.gmm_ref`), which
is also the oracle this kernel is tested against (with groups padded to
capacity).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gmm_stacked_pallas", "gmm_pallas"]


def _kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)      # (block_m, block_k)
    w = w_ref[0].astype(jnp.float32)      # (block_k, block_n)
    acc_scr[...] += jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def gmm_stacked_pallas(xs: jax.Array, w: jax.Array, *, block_m: int = 128,
                       block_n: int = 128, block_k: int = 128,
                       interpret: bool = True) -> jax.Array:
    """xs: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    E, C, d = xs.shape
    _, _, f = w.shape
    block_m = min(block_m, C)
    block_n = min(block_n, f)
    block_k = min(block_k, d)
    pad_m, pad_n, pad_k = (-C) % block_m, (-f) % block_n, (-d) % block_k
    if pad_m or pad_k:
        xs = jnp.pad(xs, ((0, 0), (0, pad_m), (0, pad_k)))
    if pad_n or pad_k:
        w = jnp.pad(w, ((0, 0), (0, pad_k), (0, pad_n)))
    Cp, dp, fp = C + pad_m, d + pad_k, f + pad_n
    nm, nn, nk = Cp // block_m, fp // block_n, dp // block_k

    kernel = functools.partial(_kernel, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(E, nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, block_m, block_k), lambda e, m, n, k: (e, m, k)),
            pl.BlockSpec((1, block_k, block_n), lambda e, m, n, k: (e, k, n)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n), lambda e, m, n, k: (e, m, n)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, fp), xs.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(xs, w)
    return out[:, :C, :f]


def gmm_pallas(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
               block_m: int = 128, interpret: bool = True) -> jax.Array:
    """Dynamic-group-size entry point: pads each group to the max group size
    into the stacked layout, runs the stacked kernel, then unpads.  (On TPU
    the capacity dispatch already produces the stacked layout directly —
    this wrapper exists for API parity with `ref.gmm_ref`.)"""
    T, d = x.shape
    E = w.shape[0]
    C = T  # worst case: everything in one group
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(T)
    in_group = (row[:, None] >= starts[None, :]) & (row[:, None] < ends[None, :])
    gid = jnp.argmax(in_group, axis=1)
    valid = in_group.any(axis=1)
    pos = row - starts[gid]
    xs = jnp.zeros((E, C, d), x.dtype).at[gid, pos].set(
        jnp.where(valid[:, None], x, 0))
    out_s = gmm_stacked_pallas(xs, w, block_m=block_m, interpret=interpret)
    out = out_s[gid, pos]
    return jnp.where(valid[:, None], out, 0).astype(x.dtype)
