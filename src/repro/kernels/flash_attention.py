"""Pallas TPU flash attention (GQA, causal/window) with explicit VMEM tiling.

Grid: ``(batch·heads, q_blocks, kv_blocks)`` — kv innermost, so the online
softmax state (m, l, acc) lives in VMEM scratch across kv iterations of one
q block (TPU grid steps execute sequentially per core, so scratch carries).
BlockSpecs stage (block_q × D) of Q and (block_kv × D) of K/V into VMEM per
step; blocks are sized so the working set
``(block_q + 2·block_kv)·D + block_q·block_kv`` fits VMEM with
MXU-aligned (multiples of 128) matmul dims.

GQA is handled in the K/V index map: query head ``h`` reads kv head
``h // (H/Hkv)`` — no repeated-KV materialization in HBM.

Validated against ``ref.attention_ref`` in interpret mode (this CPU
container); on real TPU hardware drop ``interpret=True``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF

__all__ = ["flash_attention"]


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            q_offset: int, kv_len: Optional[int], nk: int,
            block_q: int, block_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (block_q, D)
    k = k_ref[0].astype(jnp.float32)                  # (block_kv, D)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0) \
        + q_offset
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), bool)
    if kv_len is not None:
        mask &= k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
        if not causal:
            mask &= (k_pos - q_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = l_scr[...] * corr + p.sum(axis=-1)
    acc_new = acc_scr[...] * corr[:, None] + p @ v

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B,Sq,H,D); k/v: (B,Sk,Hkv,D).  Forward only (pair with the XLA
    custom-VJP path for training; the kernel targets serving/prefill)."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_kv
    kv_len = Sk if pad_k else None
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    nq, nk = Sq_p // block_q, Sk_p // block_kv

    # head-major flattening: q rows B·H, kv rows B·Hkv
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq_p, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk_p, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk_p, D)

    def kv_row(h, i, j):
        b = h // H
        hh = h % H
        return (b * Hkv + hh // G, j, 0)

    kernel = functools.partial(
        _kernel, scale=D ** -0.5, causal=causal, window=window,
        q_offset=q_offset, kv_len=kv_len, nk=nk, block_q=block_q,
        block_kv=block_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, D), kv_row),
            pl.BlockSpec((1, block_kv, D), kv_row),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, D), q.dtype),
        scratch_shapes=[
            # online-softmax state persists in VMEM across kv grid steps
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
            pltpu.VMEM((block_q, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, H, Sq_p, D).transpose(0, 2, 1, 3)
    return out[:, :Sq]
