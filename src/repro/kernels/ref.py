"""Pure-jnp reference oracles for every kernel in this package.

These are the ground truth that both the XLA-path implementations and the
Pallas TPU kernels are tested against (``tests/test_kernels.py`` sweeps
shapes/dtypes and asserts allclose).  They materialize full intermediates and
are only meant for small problem sizes.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "decode_attention_ref", "rglru_ref", "gmm_ref"]

NEG_INF = -1e30


def attention_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                   window: Optional[int]) -> jax.Array:
    """(Sq, Sk) boolean mask of allowed attention pairs."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
        if not causal:  # symmetric local window for encoders
            m &= (k_pos[None, :] - q_pos[:, None]) < window
    return m


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0, scale: Optional[float] = None) -> jax.Array:
    """Full-materialization GQA attention.

    q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D); H % Hkv == 0.
    ``q_offset``: absolute position of q[0] (Sk - Sq for a suffix query).
    Returns (B, Sq, H, D) in q.dtype; softmax in fp32.
    """
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scale = scale if scale is not None else D ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    mask = attention_mask(q_pos, k_pos, causal, window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                         index, window: Optional[int] = None,
                         ring: bool = False,
                         scale: Optional[float] = None) -> jax.Array:
    """One-token attention over a KV cache.

    q: (B, 1, H, D); caches: (B, C, Hkv, D).  ``index`` is the absolute
    position of the query token (traced scalar ok).  Valid cache entries are
    those with absolute position in [index - window + 1, index] (or [0,
    index] without a window).  ``ring=True`` means the cache is a ring buffer
    of capacity C holding positions index-C+1..index at slots pos % C.
    """
    B, _, H, D = q.shape
    _, C, Hkv, _ = k_cache.shape
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D) if Hkv * G == H else None
    qg = q[:, 0].reshape(B, Hkv, G, D)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    slot = jnp.arange(C)
    if ring:
        # slot s holds absolute position p with p % C == s, p in (index-C, index]
        pos = index - ((index - slot) % C)
        valid = pos >= 0
    else:
        pos = slot
        valid = pos <= index
    if window is not None:
        valid &= (index - pos) < window
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def rglru_ref(x: jax.Array, log_a: jax.Array, gate_a: jax.Array,
              gate_x: jax.Array, h0: Optional[jax.Array] = None,
              c: float = 8.0):
    """RG-LRU reference (RecurrentGemma / Griffin eq. 3-4), sequential scan.

    x, gate_a, gate_x: (B, S, D); log_a: (D,) — the Λ parameter.
    a_t = exp(-c · softplus(Λ) · σ(gate_a_t));
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (σ(gate_x_t) ⊙ x_t)
    Returns (h: (B, S, D), h_last: (B, D)); fp32 recurrence.
    """
    B, S, D = x.shape
    xf = x.astype(jnp.float32)
    log_a = log_a.astype(jnp.float32)
    a_exponent = -c * jax.nn.softplus(log_a)[None, None, :] * \
        jax.nn.sigmoid(gate_a.astype(jnp.float32))
    a = jnp.exp(a_exponent)                       # (B, S, D)
    gated_x = jax.nn.sigmoid(gate_x.astype(jnp.float32)) * xf
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    bx = beta * gated_x

    h = h0.astype(jnp.float32) if h0 is not None else jnp.zeros((B, D), jnp.float32)

    def step(h, inputs):
        a_t, bx_t = inputs
        h = a_t * h + bx_t
        return h, h

    h_last, hs = jax.lax.scan(step, h, (a.swapaxes(0, 1), bx.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(x.dtype), h_last


def gmm_ref(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Grouped matmul reference: rows of ``x`` are grouped contiguously by
    expert; row i uses ``w[g(i)]`` where g(i) is its group.

    x: (T, d); w: (E, d, f); group_sizes: (E,) ints summing to <= T (rows
    beyond the sum produce zeros).  Returns (T, f).
    """
    T = x.shape[0]
    E = w.shape[0]
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(T)
    # group id per row (E is small: one-hot interval membership)
    in_group = (row[:, None] >= starts[None, :]) & (row[:, None] < ends[None, :])
    gid = jnp.argmax(in_group, axis=1)
    valid = in_group.any(axis=1)
    w_per_row = w[gid]                                   # (T, d, f)
    out = jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                     w_per_row.astype(jnp.float32))
    return jnp.where(valid[:, None], out, 0.0).astype(x.dtype)
