"""Pallas TPU kernels for substrate hot-spots, with jit'd dispatch wrappers
(`ops.py`) and pure-jnp oracles (`ref.py`).

Kernels:
  * flash_attention — blocked online-softmax GQA attention (causal/window)
  * rglru_scan      — blocked diagonal linear recurrence with fused gates
  * gmm             — static-capacity grouped matmul (MoE expert compute)

The paper itself has no kernel-level contribution (it is a data-model /
infrastructure abstraction); these kernels are the perf-critical compute
layers of the *workloads* the Discovery Space machinery configures.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
