"""Banded, chunked flash attention on the XLA path (pure JAX, lax.scan).

This is the memory-bounded attention used by default on every backend: an
online-softmax sweep over (q-chunk, kv-chunk) *pairs*, where the pair list is
computed statically and fully-masked pairs are skipped — so causal attention
costs ~half the FLOPs of the naive path and sliding-window attention costs
O(S·W) instead of O(S²).  A custom VJP implements the flash-style backward
(recompute P per pair from saved LSE), so residual memory is O(S) not O(S²).

The Pallas TPU kernel (`flash_attention.py`) implements the same schedule
with explicit VMEM BlockSpecs; this module is its semantics twin on XLA and
the production fallback, and both are tested against `ref.attention_ref`.

Chunk sizes are deployment-configuration dimensions (searchable via the
Discovery Space machinery).
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .ref import NEG_INF

__all__ = ["attention_banded", "band_pairs"]


def band_pairs(nq: int, nk: int, q_chunk: int, kv_chunk: int, causal: bool,
               window: Optional[int], q_offset: int, skip: bool = True,
               kv_len: Optional[int] = None):
    """Static list of (qi, ki, is_first, is_last) covering all non-fully-masked
    chunk pairs, grouped by qi in ascending ki order.  ``kv_len``: number of
    valid (unpadded) keys."""
    pairs = []
    for qi in range(nq):
        q_lo = qi * q_chunk + q_offset
        q_hi = q_lo + q_chunk - 1
        cols = []
        for ki in range(nk):
            k_lo = ki * kv_chunk
            k_hi = k_lo + kv_chunk - 1
            if skip:
                if kv_len is not None and k_lo >= kv_len:
                    continue  # entirely padding
                if causal and k_lo > q_hi:
                    continue  # entirely in the future
                if window is not None and k_hi < q_lo - window + 1:
                    continue  # entirely beyond the lookback window
                if window is not None and not causal and k_lo > q_hi + window - 1:
                    continue  # symmetric window (encoder)
            cols.append(ki)
        if not cols:
            cols = [min(nk - 1, max(0, (q_lo // kv_chunk)))]
        for j, ki in enumerate(cols):
            pairs.append((qi, ki, j == 0, j == len(cols) - 1))
    return pairs


def _mask_for(q_pos, k_pos, causal: bool, window: Optional[int],
              kv_len: Optional[int] = None):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
        if not causal:
            m &= (k_pos[None, :] - q_pos[:, None]) < window
    return m


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9)
)
def attention_banded(q: jax.Array, k: jax.Array, v: jax.Array,
                     causal: bool = True, window: Optional[int] = None,
                     q_offset: int = 0, q_chunk: int = 512,
                     kv_chunk: int = 512, skip: bool = True,
                     kv_len: Optional[int] = None) -> jax.Array:
    """GQA attention, chunked + banded.  q: (B,Sq,H,D); k/v: (B,Sk,Hkv,D).
    ``kv_len``: number of valid keys (rest is padding)."""
    out, _ = _banded_fwd_impl(q, k, v, causal, window, q_offset, q_chunk,
                              kv_chunk, skip, kv_len)
    return out


def _chunks(x, n, c):
    B, S, H, D = x.shape
    return x.reshape(B, n, c, H, D)


def _banded_fwd_impl(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, skip, kv_len=None):
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    if Sq % q_chunk or Sk % kv_chunk:
        raise ValueError(f"seq lens ({Sq},{Sk}) must divide chunks "
                         f"({q_chunk},{kv_chunk})")
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = D ** -0.5

    pairs = band_pairs(nq, nk, q_chunk, kv_chunk, causal, window, q_offset,
                       skip, kv_len)
    qi_a = jnp.array([p[0] for p in pairs], jnp.int32)
    ki_a = jnp.array([p[1] for p in pairs], jnp.int32)
    first_a = jnp.array([p[2] for p in pairs], bool)
    last_a = jnp.array([p[3] for p in pairs], bool)

    qc_all = _chunks(q, nq, q_chunk).reshape(B, nq, q_chunk, Hkv, G, D)
    kc_all = _chunks(k, nk, kv_chunk)
    vc_all = _chunks(v, nk, kv_chunk)

    def body(carry, xs):
        m, l, acc, O, LSE = carry
        qi, ki, first, last = xs
        # reset accumulators at the first pair of each q chunk
        m = jnp.where(first, jnp.full_like(m, NEG_INF), m)
        l = jnp.where(first, jnp.zeros_like(l), l)
        acc = jnp.where(first, jnp.zeros_like(acc), acc)

        qc = jax.lax.dynamic_index_in_dim(qc_all, qi, 1, keepdims=False)
        kc = jax.lax.dynamic_index_in_dim(kc_all, ki, 1, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vc_all, ki, 1, keepdims=False)

        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset
        k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.where(_mask_for(q_pos, k_pos, causal, window,
                                kv_len)[None, None, None], s, NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where((s <= NEG_INF / 2), 0.0, p)
        corr = jnp.exp(jnp.where(m <= NEG_INF / 2, NEG_INF, m) - m_safe)
        corr = jnp.where(m <= NEG_INF / 2, 0.0, corr)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))

        # finalize (writes are overwritten until the true last pair of qi)
        l_den = jnp.maximum(l_new, 1e-30)
        o_chunk = (acc_new / l_den[..., None]).transpose(0, 3, 1, 2, 4)
        O = jax.lax.dynamic_update_index_in_dim(O, o_chunk.astype(O.dtype), qi, 1)
        lse_chunk = jnp.where(l_new > 0, m_safe + jnp.log(l_den), NEG_INF)
        LSE = jax.lax.dynamic_update_index_in_dim(LSE, lse_chunk, qi, 3)
        return (m_new, l_new, acc_new, O, LSE), None

    m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
    O0 = jnp.zeros((B, nq, q_chunk, Hkv, G, D), q.dtype)
    LSE0 = jnp.full((B, Hkv, G, nq, q_chunk), NEG_INF, jnp.float32)

    (_, _, _, O, LSE), _ = jax.lax.scan(
        body, (m0, l0, acc0, O0, LSE0), (qi_a, ki_a, first_a, last_a))
    out = O.reshape(B, Sq, H, D)
    lse = LSE.reshape(B, Hkv, G, Sq)
    return out, lse


def _banded_fwd(q, k, v, causal, window, q_offset, q_chunk, kv_chunk, skip,
                kv_len=None):
    out, lse = _banded_fwd_impl(q, k, v, causal, window, q_offset, q_chunk,
                                kv_chunk, skip, kv_len)
    return out, (q, k, v, out, lse)


def _banded_bwd(causal, window, q_offset, q_chunk, kv_chunk, skip, kv_len,
                res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = D ** -0.5

    pairs = band_pairs(nq, nk, q_chunk, kv_chunk, causal, window, q_offset,
                       skip, kv_len)
    qi_a = jnp.array([p[0] for p in pairs], jnp.int32)
    ki_a = jnp.array([p[1] for p in pairs], jnp.int32)

    qg = q.reshape(B, nq, q_chunk, Hkv, G, D)
    kg = k.reshape(B, nk, kv_chunk, Hkv, D)
    vg = v.reshape(B, nk, kv_chunk, Hkv, D)
    og = out.reshape(B, nq, q_chunk, Hkv, G, D)
    dog = dout.reshape(B, nq, q_chunk, Hkv, G, D)
    lseg = lse.reshape(B, Hkv, G, nq, q_chunk)
    # delta_i = rowsum(dO_i * O_i)  (B, Hkv, G, nq, q_chunk)
    delta = jnp.einsum("bnqhgd,bnqhgd->bhgnq", dog.astype(jnp.float32),
                       og.astype(jnp.float32))

    def body(carry, xs):
        dq, dk, dv = carry
        qi, ki = xs
        qc = jax.lax.dynamic_index_in_dim(qg, qi, 1, keepdims=False)
        kc = jax.lax.dynamic_index_in_dim(kg, ki, 1, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vg, ki, 1, keepdims=False)
        doc = jax.lax.dynamic_index_in_dim(dog, qi, 1, keepdims=False)
        lsec = jax.lax.dynamic_index_in_dim(lseg, qi, 3, keepdims=False)
        deltac = jax.lax.dynamic_index_in_dim(delta, qi, 3, keepdims=False)

        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset
        k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
        mask = _mask_for(q_pos, k_pos, causal, window, kv_len)[None, None, None]
        lse_safe = jnp.where(lsec <= NEG_INF / 2, 0.0, lsec)
        p = jnp.exp(s - lse_safe[..., None])
        p = jnp.where(mask & (lsec[..., None] > NEG_INF / 2), p, 0.0)

        dp = jnp.einsum("bqhgd,bkhd->bhgqk", doc.astype(jnp.float32),
                        vc.astype(jnp.float32))
        ds = p * (dp - deltac[..., None]) * scale

        dq_chunk = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kc.astype(jnp.float32))
        dk_chunk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qc.astype(jnp.float32))
        dv_chunk = jnp.einsum("bhgqk,bqhgd->bkhd", p, doc.astype(jnp.float32))

        dq_old = jax.lax.dynamic_index_in_dim(dq, qi, 1, keepdims=False)
        dq = jax.lax.dynamic_update_index_in_dim(dq, dq_old + dq_chunk, qi, 1)
        dk_old = jax.lax.dynamic_index_in_dim(dk, ki, 1, keepdims=False)
        dk = jax.lax.dynamic_update_index_in_dim(dk, dk_old + dk_chunk, ki, 1)
        dv_old = jax.lax.dynamic_index_in_dim(dv, ki, 1, keepdims=False)
        dv = jax.lax.dynamic_update_index_in_dim(dv, dv_old + dv_chunk, ki, 1)
        return (dq, dk, dv), None

    dq0 = jnp.zeros((B, nq, q_chunk, Hkv, G, D), jnp.float32)
    dk0 = jnp.zeros((B, nk, kv_chunk, Hkv, D), jnp.float32)
    dv0 = jnp.zeros((B, nk, kv_chunk, Hkv, D), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), (qi_a, ki_a))
    return (dq.reshape(B, Sq, H, D).astype(q.dtype),
            dk.reshape(B, Sk, Hkv, D).astype(k.dtype),
            dv.reshape(B, Sk, Hkv, D).astype(v.dtype))


attention_banded.defvjp(_banded_fwd, _banded_bwd)
