"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

Grid: ``(B, d_blocks, t_chunks)`` — time chunks innermost so the hidden
state carries across chunks in VMEM scratch; the feature dimension is tiled
into VPU-aligned ``block_d`` lanes (the recurrence is elementwise, so this is
a VPU kernel, not an MXU one — the matmuls around it live in the layer).

The gate nonlinearities (softplus/σ/exp) are fused *into* the scan kernel so
x, gate_a, gate_x stream HBM→VMEM exactly once — on TPU this recurrence is
purely memory-bound and the fusion is the whole perf story (≈4 reads + 1
write per element vs 7+ for the unfused XLA associative-scan path).

Within a chunk the recurrence is a sequential ``fori_loop`` over rows of the
VMEM block: a_t·h + b_t at VPU width ``block_d``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_pallas"]


def _kernel(x_ref, ga_ref, gx_ref, la_ref, h0_ref, h_out_ref, h_last_ref,
            h_scr, *, c: float, chunk_t: int, nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)        # (chunk_t, block_d)
    ga = ga_ref[0].astype(jnp.float32)
    gx = gx_ref[0].astype(jnp.float32)
    log_lam = la_ref[...].astype(jnp.float32)  # (block_d,)

    # fused gate math (read-once streaming)
    a_exp = -c * jax.nn.softplus(log_lam)[None, :] * jax.nn.sigmoid(ga)
    a = jnp.exp(a_exp)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (jax.nn.sigmoid(gx) * x)

    def step(i, h):
        h = a[i] * h + b[i]
        h_out_ref[0, i, :] = h.astype(h_out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == nt - 1)
    def _fin():
        h_last_ref[0] = h.astype(h_last_ref.dtype)


def rglru_pallas(x: jax.Array, log_a: jax.Array, gate_a: jax.Array,
                 gate_x: jax.Array, h0: Optional[jax.Array] = None, *,
                 block_d: int = 256, chunk_t: int = 128, c: float = 8.0,
                 interpret: bool = True):
    """x/gate_a/gate_x: (B,S,D); log_a: (D,).  Returns (h (B,S,D), h_last (B,D))."""
    B, S, D = x.shape
    block_d = min(block_d, D)
    chunk_t = min(chunk_t, S)
    if D % block_d or S % chunk_t:
        raise ValueError(f"(S={S}, D={D}) must divide (chunk_t={chunk_t}, "
                         f"block_d={block_d})")
    nd, nt = D // block_d, S // chunk_t
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)

    kernel = functools.partial(_kernel, c=c, chunk_t=chunk_t, nt=nt)
    h, h_last = pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, chunk_t, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, chunk_t, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, chunk_t, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((block_d,), lambda b, d, t: (d,)),
            pl.BlockSpec((1, block_d), lambda b, d, t: (b, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk_t, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, block_d), lambda b, d, t: (b, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(x, gate_a, gate_x, log_a, h0)
    return h, h_last
