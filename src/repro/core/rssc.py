"""Representative sub-space comparison — RSSC (paper §IV, Fig. 5).

Pipeline:
  ① source space A (well-sampled) and target space A* (unsampled) are defined,
    related by a per-dimension value mapping;
  ② cluster A's samples on the properties to transfer (silhouette k-means) and
    take cluster representatives → the representative sub-space {e}_a;
  ③ translate {e}_a through the mapping → {e}_a*;
  ④ *measure* {e}_a* in A* (real experiments — the only sampling cost;
    fanned out over ``workers`` parallel experiment workers via
    ``DiscoverySpace.sample_batch``);
  ⑤ apply the transfer criteria (linear fit, r > 0.7, p < 0.01);
  ⑥/⑦ if met, install the fitted line as a surrogate predictor experiment,
    producing a new Discovery Space A*_pred (provenance preserved);
  ⑧ sweep the surrogate over the remaining points of A*_pred.
"""

from __future__ import annotations

import uuid
from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence

import numpy as np

from .actions import ActionSpace, MeasurementError, SurrogateExperiment
from .clustering import select_indices
from .discovery import DiscoverySpace
from .entities import Configuration, Sample
from .transfer import (TransferAssessment, TransferCriteria, assess_transfer)

__all__ = ["RSSCResult", "rssc_transfer"]


@dataclass
class RSSCResult:
    property_name: str
    selection: str
    representatives: list            # source configurations
    translated: list                 # target configurations
    source_values: np.ndarray
    target_values: np.ndarray
    assessment: TransferAssessment
    predicted_space: Optional[DiscoverySpace]  # A*_pred (None if not transferable)
    n_target_measured: int = 0

    @property
    def transferable(self) -> bool:
        return self.assessment.transferable

    def summary(self) -> dict:
        out = {"property": self.property_name, "method": self.selection,
               "points_selected": len(self.representatives)}
        out.update(self.assessment.summary())
        return out


def _invert_mapping(mapping: Mapping[str, Mapping]) -> dict:
    inv: dict = {}
    for dim, m in mapping.items():
        inv[dim] = {v: k for k, v in m.items()}
    return inv


def rssc_transfer(
    source: DiscoverySpace,
    target: DiscoverySpace,
    property_name: str,
    mapping: Optional[Mapping[str, Mapping]] = None,
    selection: str = "clustering",
    criteria: TransferCriteria = TransferCriteria(),
    rng: Optional[np.random.Generator] = None,
    top_k: int = 5,
    predict_remaining: bool = True,
    workers: int = 1,
    backend=None,
) -> RSSCResult:
    """Run the full RSSC procedure from source to target Discovery Space.

    ``selection`` ∈ {"clustering", "top5", "linspace"} — the paper's method
    and its two baselines (§V-B2).  ``workers``/``backend`` route the
    target-space measurements of step ④ (and the step-⑧ surrogate sweep)
    through an execution backend (``DiscoverySpace.sample_batch``):
    representative measurement is the only real sampling cost of the
    procedure, so that is where parallel — or process-isolated, or remote —
    execution pays off.
    """
    rng = rng if rng is not None else np.random.default_rng(0)
    mapping = dict(mapping or {})
    inverse = _invert_mapping(mapping)

    # ② representative sub-space of A
    samples = [s for s in source.read() if s.has(property_name)]
    if len(samples) < 3:
        raise ValueError(f"source space has only {len(samples)} samples with "
                         f"{property_name!r}; RSSC needs a well-sampled source")
    values = np.array([s.value(property_name) for s in samples])
    idx = select_indices(values, selection, rng, top_k=top_k)
    reps = [samples[i].configuration for i in idx]
    source_values = values[np.array(idx)]

    # ③ translate to A*
    translated = [source.space.translate(c, mapping) for c in reps]

    # ④ measure the representative sub-space in A* (batched, parallel).
    # Priorities ride on the work items: representatives farthest from the
    # source median are measured first — the extremes pin the linear fit's
    # slope earliest, so a budget-cut (or straggling) measurement pass still
    # yields the most informative subset (mode-agnostic: distance, not sign).
    op = target.begin_operation("rssc", {"property": property_name,
                                         "selection": selection})
    spread = np.abs(source_values - float(np.median(source_values)))
    results = target.sample_batch(translated, operation_id=op, workers=workers,
                                  backend=backend,
                                  priorities=[float(s) for s in spread])
    target_values = []
    kept_src, kept_tgt, kept_src_vals = [], [], []
    n_measured = 0
    for src_c, tgt_c, sv, result in zip(reps, translated, source_values, results):
        if not result.ok:
            continue
        if result.action == "measured":
            n_measured += 1
        target_values.append(result.sample.value(property_name))
        kept_src.append(src_c)
        kept_tgt.append(tgt_c)
        kept_src_vals.append(sv)
    target_values = np.array(target_values)
    source_values = np.array(kept_src_vals)

    # ⑤ transfer criteria
    assessment = assess_transfer(source_values, target_values, criteria)

    predicted_space = None
    if assessment.transferable:
        # ⑥/⑦ the surrogate experiment: source-value lookup ∘ fitted line.
        src_lookup = _make_source_lookup(source, property_name, inverse)
        surrogate = SurrogateExperiment(
            source=src_lookup,
            model=assessment.surrogate,
            property_name=property_name,
            name=f"rssc-{property_name}",
            version="1",
            params={"slope": assessment.surrogate.slope,
                    "intercept": assessment.surrogate.intercept,
                    "source_space": source.space_id,
                    "fit_id": uuid.uuid4().hex[:8]},
        )
        predicted_space = target.with_predictor(surrogate)
        if predict_remaining and target.space.finite:
            # ⑧ sweep predictions over all not-yet-sampled points (batched;
            # failed predictions are recorded and skipped, as in the serial
            # sweep).  A caller-provided backend *instance* is bound to the
            # target's action space, not A*_pred's (it would execute the
            # real experiments instead of the surrogate) — re-resolve by
            # name/None for the predicted space instead.
            from .execution import ExecutionBackend
            pred_backend = (None if isinstance(backend, ExecutionBackend)
                            else backend)
            pred_op = predicted_space.begin_operation("rssc-predict")
            predicted_space.sample_batch(
                list(predicted_space.remaining_configurations()),
                operation_id=pred_op, workers=workers, backend=pred_backend)

    return RSSCResult(
        property_name=property_name,
        selection=selection,
        representatives=kept_src,
        translated=kept_tgt,
        source_values=source_values,
        target_values=target_values,
        assessment=assessment,
        predicted_space=predicted_space,
        n_target_measured=n_measured,
    )


def _make_source_lookup(source: DiscoverySpace, property_name: str,
                        inverse_mapping: Mapping[str, Mapping]):
    """Map a target configuration to its source-space property value."""

    def lookup(target_config: Configuration) -> float:
        src_config = source.space.translate(target_config, inverse_mapping)
        sample = source.read_one(src_config)
        if sample is None or not sample.has(property_name):
            raise MeasurementError(
                f"no source value of {property_name!r} for {src_config!r}"
            )
        return sample.value(property_name)

    return lookup
