"""Pareto dominance and hypervolume over measured property tuples.

The SLA-constrained search story (paper abstract: minimal cost while
meeting a service level agreement) is inherently multi-objective: the
interesting summary of a Discovery Space's paid measurements is not one
incumbent but the *frontier* of non-dominated (cost, latency, ...) points.
This module is the pure-math half of that view — the store backends expose
``frontier`` (which filters measured rows through :func:`pareto_front`),
and ``benchmarks/moo_bench.py`` tracks :func:`hypervolume` over paid
measurements as its progress metric.

All helpers take per-coordinate ``modes`` (``"min"`` | ``"max"``; default
all-min) and normalize internally to minimization.  The hypervolume
computation is exact (hypervolume-by-slicing-objectives), fine for the
small fronts and low dimensionalities of configuration searches; it is not
meant for hundreds of points in many objectives.
"""

from __future__ import annotations

from typing import Optional, Sequence

__all__ = ["pareto_front", "dominates", "hypervolume"]


def _signs(n: int, modes: Optional[Sequence[str]]) -> tuple:
    if modes is None:
        return (1.0,) * n
    if len(modes) != n:
        raise ValueError(
            f"modes has {len(modes)} entries for {n} objectives")
    signs = []
    for m in modes:
        if m not in ("min", "max"):
            raise ValueError(f"mode must be min|max, got {m!r}")
        signs.append(1.0 if m == "min" else -1.0)
    return tuple(signs)


def _normalize(point: Sequence[float], signs: tuple) -> tuple:
    if len(point) != len(signs):
        raise ValueError(
            f"point has {len(point)} coordinates, expected {len(signs)}")
    return tuple(s * float(v) for s, v in zip(signs, point))


def dominates(a: Sequence[float], b: Sequence[float],
              modes: Optional[Sequence[str]] = None) -> bool:
    """True when ``a`` Pareto-dominates ``b``: at least as good in every
    coordinate and strictly better in one."""
    signs = _signs(len(a), modes)
    an, bn = _normalize(a, signs), _normalize(b, signs)
    return all(x <= y for x, y in zip(an, bn)) and an != bn


def pareto_front(points: Sequence[Sequence[float]],
                 modes: Optional[Sequence[str]] = None) -> list:
    """Indices of the non-dominated points, in input order.

    Duplicate-valued points are all kept (distinct configurations can land
    on the same objective tuple; neither dominates the other).
    """
    if not points:
        return []
    signs = _signs(len(points[0]), modes)
    normed = [_normalize(p, signs) for p in points]
    out = []
    for i, p in enumerate(normed):
        if not any(all(x <= y for x, y in zip(q, p)) and q != p
                   for q in normed):
            out.append(i)
    return out


def hypervolume(points: Sequence[Sequence[float]],
                reference: Sequence[float],
                modes: Optional[Sequence[str]] = None) -> float:
    """Exact volume dominated by ``points`` and bounded by ``reference``.

    The reference point must be the worst corner (e.g. worst cost AND worst
    latency); points not strictly better than it in every coordinate
    contribute nothing.  Monotone in the point set, so it works as a
    paid-measurement progress curve: each new measurement can only grow it.
    """
    signs = _signs(len(reference), modes)
    ref = _normalize(reference, signs)
    normed = [_normalize(p, signs) for p in points]
    inside = [p for p in normed
              if all(x < r for x, r in zip(p, ref))]
    if not inside:
        return 0.0
    front = [inside[i] for i in pareto_front(inside)]
    return _hv_min(sorted(set(front)), ref)


def _hv_min(front: list, ref: tuple) -> float:
    """Hypervolume of a minimization front (sorted, deduped, all strictly
    inside ``ref``) by slicing along the first objective."""
    if not front:
        return 0.0
    if len(ref) == 1:
        return ref[0] - min(p[0] for p in front)
    vol = 0.0
    for i, p in enumerate(front):
        upper = front[i + 1][0] if i + 1 < len(front) else ref[0]
        width = upper - p[0]
        if width <= 0.0:
            continue
        slab = [q[1:] for q in front[:i + 1]]
        sub = [slab[j] for j in pareto_front(slab)]
        vol += width * _hv_min(sorted(set(sub)), ref[1:])
    return vol
