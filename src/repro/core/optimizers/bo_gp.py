"""Gaussian-process Bayesian optimization with expected improvement.

The skopt-BO family the paper evaluates (§V-B1).  Implementation: RBF + white
kernel GP on the unit-cube encoding of configurations, analytic EI
acquisition maximized over the pool of unsampled configurations.

Two interchangeable acquisition paths (see :mod:`.accel`):

* ``backend="numpy"`` (default) — the reference ``_fit_predict`` below:
  scipy Cholesky, per-candidate posterior, scipy-norm EI.
* ``backend="jax"``/``"pallas"`` — a jitted fit/score pair
  (:func:`.accel.gp_ei`): the Cholesky factorization, cached until the
  history changes, plus batched analytic EI over the *entire* candidate
  pool via a single forward triangular solve, with the Gram matrices
  optionally built by the blocked pallas RBF kernel.  Regression-gated
  draw-for-draw against the numpy path (same candidates, same rng stream,
  argmax-identical proposals at float32 tolerances).

Robustness (shared by both backends): a Gram matrix the jittered Cholesky
cannot factor, or an EI surface that is entirely NaN (e.g. a posterior
``std`` underflow when every history value is identical after campaign
foreign-folding), must never crash the worker — ``ask`` degrades to random
proposals for that step, and isolated NaN scores are zeroed by a
``np.nan_to_num`` guard before ranking.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np
from scipy.linalg import cho_factor, cho_solve
from scipy.stats import norm

from .base import Optimizer, ScoredCandidate, SearchAdapter

__all__ = ["GPBayesOpt"]


class GPBayesOpt(Optimizer):
    name = "bo-gp"

    def __init__(self, seed: int = 0, n_initial: int = 3, length_scale: float = 0.35,
                 noise: float = 1e-4, xi: float = 0.01, backend: str = "numpy",
                 max_candidates: int = 512):
        super().__init__(seed, backend=backend, max_candidates=max_candidates)
        self.n_initial = n_initial
        self.length_scale = length_scale
        self.noise = noise
        self.xi = xi  # EI exploration offset
        # Accelerated-backend fit cache (one entry: the current factorization
        # as device buffers).  Any history change — every tell or foreign
        # fold — changes the content hash and replaces it, so repeated asks
        # against one fitted surrogate skip the O(|H|^3) refit.  The
        # feasibility classifier GP keeps its own single-entry cache — its
        # training set (±1 labels over labelled trials) changes on a
        # different schedule than the value history.
        self._accel_cache: dict = {}
        self._feas_cache: dict = {}

    # -- GP machinery -----------------------------------------------------------

    def _kernel(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        # RBF kernel on unit cube
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / (self.length_scale ** 2))

    def _fit_predict(self, X: np.ndarray, y: np.ndarray, Xc: np.ndarray):
        """Posterior (mean, std) at ``Xc``, or None when the Gram matrix
        cannot be factored even after the jitter retry — the caller treats
        an unfittable model as "no model" and proposes randomly, instead of
        letting a second ``LinAlgError`` kill the worker (and with it the
        whole campaign member) mid-ask."""
        mu_y, sd_y = y.mean(), y.std() + 1e-12
        yn = (y - mu_y) / sd_y
        K = self._kernel(X, X) + self.noise * np.eye(len(X))
        try:
            cf = cho_factor(K, lower=True)
        except np.linalg.LinAlgError:
            try:
                cf = cho_factor(K + 1e-6 * np.eye(len(X)), lower=True)
            except np.linalg.LinAlgError:
                return None
        alpha = cho_solve(cf, yn)
        Ks = self._kernel(Xc, X)
        mean = Ks @ alpha
        v = cho_solve(cf, Ks.T)
        var = np.clip(1.0 - np.einsum("ij,ji->i", Ks, v), 1e-12, None)
        return mean * sd_y + mu_y, np.sqrt(var) * sd_y

    def _acquisition(self, X: np.ndarray, y: np.ndarray, Xc: np.ndarray,
                     best: Optional[float] = None) -> Optional[np.ndarray]:
        """EI over the whole encoded candidate pool, backend-dispatched;
        None signals an unfittable model (caller falls back to random).
        ``best`` overrides the incumbent EI improves on (constrained asks
        pass the best *feasible* value); default is the history minimum."""
        if self.backend != "numpy":
            from . import accel
            ei = accel.gp_ei(X, y, Xc, length_scale=self.length_scale,
                             noise=self.noise, xi=self.xi,
                             use_pallas=self.backend == "pallas",
                             cache=self._accel_cache, best=best)
            if ei is not None:
                return ei
        fit = self._fit_predict(X, y, Xc)
        if fit is None:
            return None
        mean, std = fit
        if best is None:
            best = y.min()
        # expected improvement for minimization
        z = (best - self.xi - mean) / std
        return (best - self.xi - mean) * norm.cdf(z) + std * norm.pdf(z)

    def _feasibility_weight(self, adapter: SearchAdapter,
                            Xc: np.ndarray) -> Optional[np.ndarray]:
        """P(feasible) over the candidate pool: a second GP regressed on ±1
        feasibility labels, squashed through the normal CDF (the
        constraint-classifier construction of Gardner et al. 2014).  None
        when weighting carries no signal — the labels are all one class —
        or the classifier GP cannot be fitted.  All-feasible callers then
        rank on EI alone; all-infeasible callers (no incumbent either) fall
        back to random exploration: the standardized-y GP fit degenerates
        on a constant label vector (posterior mean -1, std ~0 -> PoF = 0
        everywhere), and ranking on that flat surface would crawl the
        candidate pool in enumeration order instead of exploring."""
        Xf, z = self._feasibility_arrays(adapter)
        if len(z) == 0 or bool((z > 0).all()) or bool((z < 0).all()):
            return None
        if self.backend != "numpy":
            from . import accel
            pof = accel.gp_pof(Xf, z, Xc, length_scale=self.length_scale,
                               noise=self.noise,
                               use_pallas=self.backend == "pallas",
                               cache=self._feas_cache)
            if pof is not None:
                return pof
        fit = self._fit_predict(Xf, z, Xc)
        if fit is None:
            return None
        mean, std = fit
        return norm.cdf(mean / np.maximum(std, 1e-12))

    # -- proposal -----------------------------------------------------------------

    def ask(self, adapter: SearchAdapter, rng: np.random.Generator,
            n: int = 1) -> List[ScoredCandidate]:
        """Top-n expected improvement over one GP fit (the model only changes
        on tell, so one posterior serves the whole batch); candidates carry
        their EI as the acquisition score.

        History handling: the GP posterior fits ``_history_arrays`` — every
        valued trial in the adapter, own *and* campaign-foreign — so under
        cooperative sharing the incumbent ``best`` and the EI surface reflect
        the union of the fleet's measurements (and fleet history counts
        toward ``n_initial``, skipping redundant random warmup).  Sharing
        never consumes rng draws, so solo trajectories are unchanged.

        Degenerate fits degrade instead of crashing: an unfactorable Gram
        matrix or an all-NaN EI surface (posterior-std underflow on an
        all-equal history) falls back to random proposals for this step,
        and residual NaN scores are zeroed before ranking so ``_top_n``
        never sorts on NaN.

        Under a constrained objective (SLA bounds on the adapter's
        ``objective``) the acquisition is feasibility-weighted EI: the value
        GP still fits every valued trial (an infeasible measurement is real
        evidence about the objective surface), but EI improves on the best
        *feasible* incumbent and is multiplied by P(feasible) from a second
        GP classifying the constraint verdicts.  Before any feasible value
        exists, P(feasible) alone drives the search toward the feasible
        region.  The weighting never consumes rng draws, so unconstrained
        trajectories are unchanged draw-for-draw.
        """
        candidates = self._unseen_candidates(adapter, rng, self.max_candidates)
        if not candidates:
            return []
        X, y = self._history_arrays(adapter)
        if len(y) < self.n_initial:
            return self._random_n(candidates, rng, n)

        Xc = np.stack([adapter.space.encode(c) for c in candidates])
        if not self._constrained(adapter):
            ei = self._acquisition(X, y, Xc)
            if ei is None or bool(np.isnan(ei).all()):
                return self._random_n(candidates, rng, n)
            ei = np.nan_to_num(ei, nan=0.0)
            return self._top_n(candidates, ei, n)

        pof = self._feasibility_weight(adapter, Xc)
        best = self._best_feasible(adapter)
        if best is None:
            # nothing feasible measured yet: EI has no incumbent to improve
            # on — chase feasibility itself (or fall back to random when the
            # classifier has nothing to say either)
            if pof is None or bool(np.isnan(pof).all()):
                return self._random_n(candidates, rng, n)
            return self._top_n(candidates, np.nan_to_num(pof, nan=0.0), n)
        ei = self._acquisition(X, y, Xc, best=best)
        if ei is None or bool(np.isnan(ei).all()):
            return self._random_n(candidates, rng, n)
        score = np.clip(np.nan_to_num(ei, nan=0.0), 0.0, None)
        if pof is not None:
            score = score * np.nan_to_num(pof, nan=0.0)
        return self._top_n(candidates, score, n)
