"""Gaussian-process Bayesian optimization with expected improvement.

The skopt-BO family the paper evaluates (§V-B1).  Implementation: RBF + white
kernel GP on the unit-cube encoding of configurations, analytic EI
acquisition maximized over the pool of unsampled configurations.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np
from scipy.linalg import cho_factor, cho_solve
from scipy.stats import norm

from .base import Optimizer, ScoredCandidate, SearchAdapter

__all__ = ["GPBayesOpt"]


class GPBayesOpt(Optimizer):
    name = "bo-gp"

    def __init__(self, seed: int = 0, n_initial: int = 3, length_scale: float = 0.35,
                 noise: float = 1e-4, xi: float = 0.01):
        super().__init__(seed)
        self.n_initial = n_initial
        self.length_scale = length_scale
        self.noise = noise
        self.xi = xi  # EI exploration offset

    # -- GP machinery -----------------------------------------------------------

    def _kernel(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        # RBF kernel on unit cube
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / (self.length_scale ** 2))

    def _fit_predict(self, X: np.ndarray, y: np.ndarray, Xc: np.ndarray):
        mu_y, sd_y = y.mean(), y.std() + 1e-12
        yn = (y - mu_y) / sd_y
        K = self._kernel(X, X) + self.noise * np.eye(len(X))
        try:
            cf = cho_factor(K, lower=True)
        except np.linalg.LinAlgError:
            cf = cho_factor(K + 1e-6 * np.eye(len(X)), lower=True)
        alpha = cho_solve(cf, yn)
        Ks = self._kernel(Xc, X)
        mean = Ks @ alpha
        v = cho_solve(cf, Ks.T)
        var = np.clip(1.0 - np.einsum("ij,ji->i", Ks, v), 1e-12, None)
        return mean * sd_y + mu_y, np.sqrt(var) * sd_y

    # -- proposal -----------------------------------------------------------------

    def ask(self, adapter: SearchAdapter, rng: np.random.Generator,
            n: int = 1) -> List[ScoredCandidate]:
        """Top-n expected improvement over one GP fit (the model only changes
        on tell, so one posterior serves the whole batch); candidates carry
        their EI as the acquisition score.

        History handling: the GP posterior fits ``_history_arrays`` — every
        valued trial in the adapter, own *and* campaign-foreign — so under
        cooperative sharing the incumbent ``best`` and the EI surface reflect
        the union of the fleet's measurements (and fleet history counts
        toward ``n_initial``, skipping redundant random warmup).  Sharing
        never consumes rng draws, so solo trajectories are unchanged.
        """
        candidates = self._unseen_candidates(adapter, rng)
        if not candidates:
            return []
        X, y = self._history_arrays(adapter)
        if len(y) < self.n_initial:
            return self._random_n(candidates, rng, n)

        Xc = np.stack([adapter.space.encode(c) for c in candidates])
        mean, std = self._fit_predict(X, y, Xc)
        best = y.min()
        # expected improvement for minimization
        z = (best - self.xi - mean) / std
        ei = (best - self.xi - mean) * norm.cdf(z) + std * norm.pdf(z)
        return self._top_n(candidates, ei, n)
