"""Jitted GP posterior + batched analytic EI (the BO-GP ask hot path).

Two jitted device calls replace the numpy ``_fit_predict`` + EI sequence
in :mod:`..bo_gp`, split along the standard fit/predict seam (the same
separation sklearn's ``GaussianProcessRegressor`` and GPyTorch draw):

* :func:`_gp_fit` — masked standardization, RBF Gram build (jnp
  dot-expansion or the pallas kernel), Cholesky factorization with the
  factor explicitly inverted, and the ``alpha = K^-1 y`` weights.  Its
  result is cached (caller-owned dict, keyed by a content hash of the
  history) until the history changes, so asking repeatedly against one
  fitted surrogate — the benchmark's steady-state regime, and any
  multi-batch ask between tells — pays the O(|H|^3) factorization once.
  A campaign tell invalidates the key.
* :func:`_gp_ei` — cross-covariance to the *entire* candidate pool,
  posterior mean via the cached ``alpha``, posterior variance via a
  blocked lower-triangular product (``var_i = 1 - ||L^-1 k_i||^2``, at
  roughly a quarter of the flops a generic ``cho_solve`` against the pool
  would pay), and the analytic EI surface.

Shape bucketing
---------------

History and pool sizes change every ask; jitting on exact shapes would
recompile each step.  Inputs are therefore zero-padded to power-of-two
buckets with a validity mask, so a whole campaign reuses O(log |H|)
compiled programs.  Padding is exact, not approximate: padded history rows
are masked out of the standardization, carry an identity diagonal block in
K (their Cholesky factor is trivially 1), and have zero cross-covariance
columns, so ``alpha`` and the posterior over real candidates are bitwise
independent of the bucket size; padded *candidate* rows are simply sliced
off on the host.

Robustness mirrors the numpy reference: jnp.linalg.cholesky signals
failure with NaN (not an exception), which propagates into ``alpha`` — the
host wrapper detects it and refits once with the same 1e-6 jitter the
numpy path uses, and a second failure yields an all-NaN EI surface that
the caller's NaN guard converts into a random-proposal fallback.
"""

from __future__ import annotations

import functools
import hashlib

import numpy as np

try:  # pragma: no cover - exercised implicitly by backend gating
    import jax
    import jax.numpy as jnp
    from jax.scipy.linalg import solve_triangular
    from jax.scipy.stats import norm as _jnorm
    HAVE_JAX = True
except Exception:  # pragma: no cover - jax-less installs
    HAVE_JAX = False

from . import bucket

__all__ = ["gp_ei", "gp_pof", "bucket"]


if HAVE_JAX:

    def _rbf(A, B, inv2ls2, use_pallas):
        from .pallas_rbf import rbf_matrix_jnp, rbf_matrix_pallas
        if use_pallas:
            return rbf_matrix_pallas(A, B, inv2ls2)
        return rbf_matrix_jnp(A, B, inv2ls2)

    @functools.partial(jax.jit, static_argnames=("use_pallas",))
    def _gp_fit(Xh, yh, mh, inv2ls2, noise, use_pallas):
        # masked standardization (matches y.mean()/y.std() over real rows)
        nh = mh.sum()
        mu = (yh * mh).sum() / nh
        sd = jnp.sqrt((((yh - mu) * mh) ** 2).sum() / nh) + 1e-12
        yn = (yh - mu) / sd * mh

        # Gram with an identity block over padded rows: valid block gets the
        # RBF + noise diagonal, padded diagonal is 1, padded off-diagonal 0
        pair = mh[:, None] * mh[None, :]
        K = _rbf(Xh, Xh, inv2ls2, use_pallas) * pair
        K = K + jnp.diag(noise * mh + (1.0 - mh))

        L = jnp.linalg.cholesky(K)
        eye = jnp.eye(K.shape[0], dtype=K.dtype)
        Linv = solve_triangular(L, eye, lower=True)
        w = Linv @ yn
        alpha = Linv.T @ w
        best = jnp.where(mh > 0, yh, jnp.inf).min()
        return Linv, alpha, mu, sd, best

    def _inv_quadform(Linv, Ks, nblocks=8):
        """Per-row ||Linv @ k_i||^2 for lower-triangular ``Linv`` and
        row-major ``Ks`` of shape (|pool|, |H|): block matmuls that skip
        the identically-zero upper blocks of ``Linv`` — ~half the flops of
        a dense product (or a triangular solve, which XLA:CPU runs at the
        same rate).  Everything stays pool-major, so only the small
        (bs, <=n) ``Linv`` block is ever transposed, and the per-block sum
        of squares never materializes the full (|pool|, |H|) product."""
        n = Linv.shape[0]
        bs = max(1, n // nblocks)
        q = jnp.zeros(Ks.shape[0], Ks.dtype)
        for lo in range(0, n, bs):
            Vi = Ks[:, :lo + bs] @ Linv[lo:lo + bs, :lo + bs].T
            q = q + (Vi * Vi).sum(axis=1)
        return q

    @functools.partial(jax.jit, static_argnames=("use_pallas",))
    def _gp_ei(Linv, alpha, mu, sd, best, Xh, mh, Xc, inv2ls2, xi,
               use_pallas):
        Ks = _rbf(Xc, Xh, inv2ls2, use_pallas) * mh[None, :]
        mean = Ks @ alpha
        # One triangular product gives the variance:
        # k*^T K^-1 k* = ||L^-1 k*||^2, so the backward half of a
        # cho_solve — the same O(|H|^2 |pool|) again, and the single most
        # expensive op of the whole ask — is never needed.
        var = jnp.clip(1.0 - _inv_quadform(Linv, Ks), 1e-12, None)
        mean, std = mean * sd + mu, jnp.sqrt(var) * sd

        imp = best - xi - mean
        z = imp / std
        return imp * _jnorm.cdf(z) + std * _jnorm.pdf(z)

    @functools.partial(jax.jit, static_argnames=("use_pallas",))
    def _gp_pof(Linv, alpha, mu, sd, Xh, mh, Xc, inv2ls2, use_pallas):
        # Same cached-fit posterior as _gp_ei, squashed to P(feasible):
        # the GP regresses ±1 feasibility labels, so Φ(mean/std) is the
        # posterior probability mass above the decision boundary at 0.
        Ks = _rbf(Xc, Xh, inv2ls2, use_pallas) * mh[None, :]
        mean = Ks @ alpha
        var = jnp.clip(1.0 - _inv_quadform(Linv, Ks), 1e-12, None)
        mean, std = mean * sd + mu, jnp.sqrt(var) * sd
        return _jnorm.cdf(mean / jnp.maximum(std, 1e-12))


def _history_key(X, y, H, D, length_scale, noise, use_pallas):
    """Content hash of the fit inputs — any tell/fold changes it."""
    digest = hashlib.blake2b(digest_size=16)
    digest.update(np.ascontiguousarray(X, np.float64).tobytes())
    digest.update(np.ascontiguousarray(y, np.float64).tobytes())
    return (H, D, float(length_scale), float(noise), bool(use_pallas),
            digest.digest())


def _fit_cached(X: np.ndarray, y: np.ndarray, length_scale: float,
                noise: float, use_pallas: bool, cache: dict | None):
    """The (padded, jitted, NaN-retried) GP fit behind both scorers,
    served from ``cache`` while the history content hash matches."""
    H = len(y)
    D = X.shape[1]
    Hp = bucket(H)
    key = _history_key(X, y, H, D, length_scale, noise, use_pallas)
    fit = cache.get("fit") if cache is not None else None
    if fit is None or fit[0] != key:
        Xh = np.zeros((Hp, D), np.float32)
        Xh[:H] = X
        yh = np.zeros(Hp, np.float32)
        yh[:H] = y
        mh = np.zeros(Hp, np.float32)
        mh[:H] = 1.0
        inv2ls2 = np.float32(0.5 / (length_scale * length_scale))
        Linv, alpha, mu, sd, best = _gp_fit(Xh, yh, mh, inv2ls2,
                                            np.float32(noise), use_pallas)
        if bool(jnp.isnan(alpha).any()):
            # Cholesky failed (NaN factor): one jittered retry, exactly the
            # numpy reference's second cho_factor attempt.  If this also
            # fails, the NaN surface downstream triggers the random fallback.
            Linv, alpha, mu, sd, best = _gp_fit(Xh, yh, mh, inv2ls2,
                                                np.float32(noise + 1e-6),
                                                use_pallas)
        fit = (key, Linv, alpha, mu, sd, best, Xh, mh, inv2ls2)
        if cache is not None:
            cache["fit"] = fit
    return fit


def gp_ei(X: np.ndarray, y: np.ndarray, Xc: np.ndarray, *,
          length_scale: float, noise: float, xi: float,
          use_pallas: bool = False, cache: dict | None = None,
          best: float | None = None):
    """Batched EI over the whole candidate pool; returns a float64 numpy
    array of shape ``(len(Xc),)``, or None when jax is unavailable (caller
    falls back to the numpy reference path).

    ``cache`` is an optimizer-owned dict holding the fitted factorization
    (device buffers) from the previous call; it is reused when the history
    content hash matches and replaced otherwise, so it never grows beyond
    one fit.  ``best`` overrides the incumbent EI improves on (constrained
    asks pass the best *feasible* value — the history minimum may be an SLA
    violator); default is the fit's history minimum.
    """
    if not HAVE_JAX:  # pragma: no cover - jax-less installs
        return None
    C = len(Xc)
    Cp = bucket(C)
    fit = _fit_cached(X, y, length_scale, noise, use_pallas, cache)
    _, Linv, alpha, mu, sd, fit_best, Xh, mh, inv2ls2 = fit
    if best is not None:
        fit_best = np.float32(best)
    Xcp = np.zeros((Cp, X.shape[1]), np.float32)
    Xcp[:C] = Xc
    ei = _gp_ei(Linv, alpha, mu, sd, fit_best, Xh, mh, Xcp, inv2ls2,
                np.float32(xi), use_pallas)
    return np.asarray(ei)[:C].astype(np.float64)


def gp_pof(X: np.ndarray, z: np.ndarray, Xc: np.ndarray, *,
           length_scale: float, noise: float, use_pallas: bool = False,
           cache: dict | None = None):
    """P(feasible) over the whole candidate pool from a GP regressed on ±1
    feasibility labels ``z`` (the feasibility-weighted-EI classifier);
    float64 array of shape ``(len(Xc),)``, or None when jax is unavailable.

    Reuses the exact fit machinery (padding, caching, NaN retry) of
    :func:`gp_ei` — pass a *separate* cache dict, since the label vector
    changes on a different schedule than the value history.
    """
    if not HAVE_JAX:  # pragma: no cover - jax-less installs
        return None
    C = len(Xc)
    Cp = bucket(C)
    fit = _fit_cached(X, z, length_scale, noise, use_pallas, cache)
    _, Linv, alpha, mu, sd, _best, Xh, mh, inv2ls2 = fit
    Xcp = np.zeros((Cp, X.shape[1]), np.float32)
    Xcp[:C] = Xc
    pof = _gp_pof(Linv, alpha, mu, sd, Xh, mh, Xcp, inv2ls2, use_pallas)
    return np.asarray(pof)[:C].astype(np.float64)
