"""Vmapped TPE Parzen ratio (the TPE/BOHB ask hot path).

The numpy reference (:func:`..tpe.tpe_score`) loops dimensions in Python
and materializes a (|pool|, |obs|) temporary per dimension per density.
Here the whole score — per-dimension numeric KDEs and smoothed categorical
pmfs for BOTH the good and bad sets, evaluated for all candidates at once —
is a single jitted device call, vmapped over dimensions.

Encoding: numeric dimensions (discrete + continuous) stack into a
``(D_num, n)`` unit-interval matrix; categorical dimensions stack into a
``(D_cat, n)`` index matrix padded to the largest cardinality, with a
per-dimension category mask so the add-one smoothing never counts
nonexistent categories.  Observation counts are zero-padded to power-of-two
buckets (masked out of every sum), so compiled programs are reused across
history growth exactly as in :mod:`.gp_jax`.

The empty-observation case (n = 0 after masking) degrades to the uniform
prior — numeric density 1 on [0, 1], categorical pmf 1/k — matching the
numpy reference evaluated on an empty set, which is what TPE's degenerate-
split fallback scores against.
"""

from __future__ import annotations

import functools

import numpy as np

try:  # pragma: no cover - exercised implicitly by backend gating
    import jax
    import jax.numpy as jnp
    HAVE_JAX = True
except Exception:  # pragma: no cover - jax-less installs
    HAVE_JAX = False

from . import bucket

__all__ = ["tpe_scores"]

_SQRT_2PI = float(np.sqrt(2.0 * np.pi))


if HAVE_JAX:

    def _log_parzen_numeric(u_obs, m_obs, u_cand, bw):
        """Masked 1-d Parzen log-density (uniform prior + one Gaussian per
        real observation) at candidate coordinates."""
        n = m_obs.sum()
        d = (u_cand[:, None] - u_obs[None, :]) / bw
        k = jnp.exp(-0.5 * d * d) / (bw * _SQRT_2PI) * m_obs[None, :]
        dens = (1.0 + k.sum(axis=1)) / (n + 1.0)
        return jnp.log(jnp.clip(dens, 1e-12, None))

    def _log_parzen_categorical(i_obs, m_obs, i_cand, k_mask):
        """Masked add-one categorical log-pmf at candidate indices."""
        oh = jax.nn.one_hot(i_obs, k_mask.shape[0]) * m_obs[:, None]
        counts = k_mask + oh.sum(axis=0) * k_mask
        pmf = counts / counts.sum()
        return jnp.log(jnp.clip(pmf[i_cand], 1e-12, None))

    @jax.jit
    def _tpe_scores(g_num, g_m, b_num, b_m, c_num,
                    g_cat, b_cat, c_cat, k_masks, bw):
        score = jnp.zeros(c_num.shape[1] if c_num.shape[0]
                          else c_cat.shape[1])
        if g_num.shape[0]:  # static: number of numeric dimensions
            lnum = jax.vmap(_log_parzen_numeric, in_axes=(0, None, 0, None))
            score = score + (lnum(g_num, g_m, c_num, bw).sum(axis=0)
                             - lnum(b_num, b_m, c_num, bw).sum(axis=0))
        if g_cat.shape[0]:  # static: number of categorical dimensions
            lcat = jax.vmap(_log_parzen_categorical, in_axes=(0, None, 0, 0))
            score = score + (lcat(g_cat, g_m, c_cat, k_masks).sum(axis=0)
                             - lcat(b_cat, b_m, c_cat, k_masks).sum(axis=0))
        return score


def _encode(space, configs, n_pad, num_dims, cat_dims):
    """(numeric unit matrix, categorical index matrix, mask) zero-padded to
    ``n_pad`` observations."""
    n = len(configs)
    num = np.zeros((len(num_dims), n_pad), np.float32)
    cat = np.zeros((len(cat_dims), n_pad), np.int32)
    for j, dim in enumerate(num_dims):
        num[j, :n] = [dim.to_unit(c[dim.name]) for c in configs]
    for j, dim in enumerate(cat_dims):
        cat[j, :n] = [dim.values.index(c[dim.name]) for c in configs]
    mask = np.zeros(n_pad, np.float32)
    mask[:n] = 1.0
    return num, cat, mask


def tpe_scores(space, good_configs, bad_configs, candidates,
               bw: float = 0.12):
    """log l(x) - log g(x) per candidate as a float64 numpy array, or None
    when jax is unavailable (caller falls back to the numpy reference)."""
    if not HAVE_JAX:  # pragma: no cover - jax-less installs
        return None
    num_dims = [d for d in space.dimensions if d.kind != "categorical"]
    cat_dims = [d for d in space.dimensions if d.kind == "categorical"]
    gp, bp = bucket(len(good_configs)), bucket(len(bad_configs))
    cp = bucket(len(candidates))
    g_num, g_cat, g_m = _encode(space, good_configs, gp, num_dims, cat_dims)
    b_num, b_cat, b_m = _encode(space, bad_configs, bp, num_dims, cat_dims)
    c_num, c_cat, _ = _encode(space, candidates, cp, num_dims, cat_dims)
    k_max = max((d.cardinality for d in cat_dims), default=1)
    k_masks = np.zeros((len(cat_dims), k_max), np.float32)
    for j, dim in enumerate(cat_dims):
        k_masks[j, :dim.cardinality] = 1.0
    score = _tpe_scores(g_num, g_m, b_num, b_m, c_num,
                        g_cat, b_cat, c_cat, k_masks, np.float32(bw))
    return np.asarray(score)[:len(candidates)].astype(np.float64)
