"""Accelerated ask backends for the optimizer suite.

Backend selection (an :class:`~repro.core.optimizers.base.Optimizer`
constructor arg, threaded through
:class:`~repro.core.api.spec.OptimizerSpec`):

* ``"numpy"``  — the reference implementation (default).  Always available;
  every other backend is regression-gated draw-for-draw against it.
* ``"jax"``    — jitted/vmapped hot paths on whatever device jax sees:
  :func:`gp_ei` fuses the GP Cholesky solve + batched analytic EI over the
  whole candidate pool into one device call; :func:`tpe_scores` evaluates
  every per-dimension Parzen density for all candidates at once.
* ``"pallas"`` — the jax backend with the pairwise-distance/RBF Gram
  matrices built by the blocked pallas kernel (:mod:`.pallas_rbf`), for
  the large-history regime where the Gram build dominates the GP fit.
  Degrades to ``"jax"`` on installs without pallas.

Missing-dependency policy (repo rule: never require packages the container
lacks): when jax itself is unavailable, :func:`resolve_backend` degrades
any accelerated choice to ``"numpy"`` with a one-time warning instead of
raising, and the scorer entry points return None so callers take the
reference path.

Import discipline: this package is imported by every optimizer
constructor, and ``repro.core`` is imported by every queue/process worker
the execution backends spawn — so nothing here may import jax at module
scope.  Backend probing uses ``importlib.util.find_spec`` (no import), and
the jitted implementations (:mod:`.gp_jax`, :mod:`.tpe_jax`) load on the
first accelerated scoring call.
"""

from __future__ import annotations

import importlib.util
import warnings

__all__ = ["BACKENDS", "jax_available", "pallas_available",
           "resolve_backend", "gp_ei", "gp_pof", "tpe_scores", "bucket"]

#: Every selectable ask backend, reference first.
BACKENDS = ("numpy", "jax", "pallas")

_warned: set = set()


def bucket(n: int, floor: int = 8) -> int:
    """Smallest power of two >= max(n, floor) — the shape key the jitted
    scorers pad to, so compiled programs are reused as history grows."""
    return max(floor, 1 << (max(n, 1) - 1).bit_length())


def jax_available() -> bool:
    """Cheap spec-level probe — deliberately does NOT import jax."""
    try:
        return importlib.util.find_spec("jax") is not None
    except (ImportError, ValueError):  # pragma: no cover - broken installs
        return False


def pallas_available() -> bool:
    """True when ``jax.experimental.pallas`` imports (this one does import
    jax — only called on an explicit pallas opt-in)."""
    if not jax_available():  # pragma: no cover - jax-less installs
        return False
    from .pallas_rbf import pallas_available as _pa
    return _pa()


def resolve_backend(backend: str) -> str:
    """Validate a backend name, degrading gracefully when the accelerator
    stack is missing: unknown names raise, unavailable ones warn once and
    fall back to the best available tier (pallas -> jax -> numpy)."""
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown ask backend {backend!r} (known: {BACKENDS})")
    if backend != "numpy" and not jax_available():  # pragma: no cover
        if backend not in _warned:
            _warned.add(backend)
            warnings.warn(f"ask backend {backend!r} needs jax, which is "
                          f"unavailable — falling back to 'numpy'")
        return "numpy"
    if backend == "pallas" and not pallas_available():  # pragma: no cover
        if backend not in _warned:
            _warned.add(backend)
            warnings.warn("pallas is unavailable — degrading the 'pallas' "
                          "backend to 'jax' (pure-jnp Gram build)")
        return "jax"
    return backend


def gp_ei(X, y, Xc, *, length_scale, noise, xi, use_pallas=False,
          cache=None, best=None):
    """Lazy dispatch to :func:`.gp_jax.gp_ei`; None when jax is missing.
    ``best`` overrides the incumbent EI improves on (constrained asks pass
    the best feasible value); default is the history minimum."""
    if not jax_available():  # pragma: no cover - jax-less installs
        return None
    from . import gp_jax
    return gp_jax.gp_ei(X, y, Xc, length_scale=length_scale, noise=noise,
                        xi=xi, use_pallas=use_pallas, cache=cache, best=best)


def gp_pof(X, z, Xc, *, length_scale, noise, use_pallas=False, cache=None):
    """Lazy dispatch to :func:`.gp_jax.gp_pof` — P(feasible) over the
    candidate pool from a GP on ±1 labels; None when jax is missing."""
    if not jax_available():  # pragma: no cover - jax-less installs
        return None
    from . import gp_jax
    return gp_jax.gp_pof(X, z, Xc, length_scale=length_scale, noise=noise,
                         use_pallas=use_pallas, cache=cache)


def tpe_scores(space, good_configs, bad_configs, candidates, bw=0.12):
    """Lazy dispatch to :func:`.tpe_jax.tpe_scores`; None when jax is
    missing."""
    if not jax_available():  # pragma: no cover - jax-less installs
        return None
    from . import tpe_jax
    return tpe_jax.tpe_scores(space, good_configs, bad_configs, candidates,
                              bw)
