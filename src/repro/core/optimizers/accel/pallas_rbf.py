"""Pallas TPU kernel for the pairwise squared-distance / RBF kernel matrix.

At large history the GP fit is dominated by building the two Gram blocks
K(X, X) (|H|²·d) and K(Xc, X) (|pool|·|H|·d).  The numpy reference
materializes the full (M, N, d) broadcast difference before reducing — a
memory-bound O(M·N·d) temporary.  This kernel streams (block_m, d) ×
(block_n, d) tiles through VMEM and fuses the ``|a|² + |b|² − 2ab``
expansion with the exponential, so the MXU does the contraction and the
(M, N) output is written once.

Follows the repo kernel conventions (``src/repro/kernels/``): explicit
BlockSpecs, fp32 accumulation via ``preferred_element_type``, lane padding
to 128, ``interpret=True`` on CPU so the kernel is testable everywhere, and
a pure-jnp oracle (:func:`rbf_matrix_jnp`) the pallas path is regression-
gated against.  Import of pallas itself is deferred and failure-tolerant:
:func:`pallas_available` gates dispatch, and callers fall back to the jnp
path on any platform where pallas is absent.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["rbf_matrix_jnp", "rbf_matrix_pallas", "pallas_available"]

#: TPU lane width — the trailing block dim must be a multiple of this.
_LANES = 128


def pallas_available() -> bool:
    """True when ``jax.experimental.pallas`` imports on this install."""
    try:  # pragma: no cover - trivially true on the baked toolchain
        from jax.experimental import pallas  # noqa: F401
        from jax.experimental.pallas import tpu  # noqa: F401
        return True
    except Exception:  # pragma: no cover - pallas-less installs
        return False


def rbf_matrix_jnp(A: jax.Array, B: jax.Array, inv2ls2: jax.Array) -> jax.Array:
    """Pure-jnp oracle: ``exp(-d²(A, B) * inv2ls2)`` via the dot-expansion
    (no (M, N, d) temporary), where ``inv2ls2 = 1 / (2·ls²)``."""
    d2 = ((A * A).sum(-1)[:, None] + (B * B).sum(-1)[None, :]
          - 2.0 * A @ B.T)
    return jnp.exp(-jnp.maximum(d2, 0.0) * inv2ls2)


def _rbf_block(s_ref, a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)  # (block_m, d_pad)
    b = b_ref[...].astype(jnp.float32)  # (block_n, d_pad)
    # zero-padded feature columns contribute 0 to every distance term
    d2 = ((a * a).sum(axis=1)[:, None] + (b * b).sum(axis=1)[None, :]
          - 2.0 * jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32))
    o_ref[...] = jnp.exp(-jnp.maximum(d2, 0.0) * s_ref[0, 0])


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "interpret"))
def _rbf_pallas_call(A, B, inv2ls2, *, block_m, block_n, interpret):
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    M, d = A.shape
    N = B.shape[0]
    bm, bn = min(block_m, M), min(block_n, N)
    pad_m, pad_n, pad_d = (-M) % bm, (-N) % bn, (-d) % _LANES
    if pad_m or pad_d:
        A = jnp.pad(A, ((0, pad_m), (0, pad_d)))
    if pad_n or pad_d:
        B = jnp.pad(B, ((0, pad_n), (0, pad_d)))
    Mp, Np, dp = M + pad_m, N + pad_n, d + pad_d
    scale = jnp.asarray(inv2ls2, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _rbf_block,
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(scale, A.astype(jnp.float32), B.astype(jnp.float32))
    return out[:M, :N]


def rbf_matrix_pallas(A: jax.Array, B: jax.Array, inv2ls2, *,
                      block_m: int = 256, block_n: int = 256,
                      interpret=None) -> jax.Array:
    """Blocked pallas RBF Gram matrix; ``interpret=None`` auto-selects the
    interpreter off-TPU (the repo-wide CPU-validation convention)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _rbf_pallas_call(A, B, jnp.asarray(inv2ls2, jnp.float32),
                            block_m=block_m, block_n=block_n,
                            interpret=bool(interpret))
