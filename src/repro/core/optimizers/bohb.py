"""BOHB-lite: TPE model-based suggestion + successive-halving brackets
(Falkner, Klein & Hutter 2018 — the third optimizer family in paper §V-B1).

Multi-fidelity needs experiments that accept a budget.  In this framework a
fidelity-aware experiment exposes the budget as an experiment *parameter* —
so low-fidelity measurements are distinct provenance entries in the common
context and never contaminate full-fidelity data (TRACE: Encapsulated).

Used as a plain suggester (via :func:`run_optimizer`) BOHB degrades to TPE
with a more exploratory prior, which matches how BOHB behaves when the
budget dimension collapses.  :meth:`BOHB.run_brackets` provides the true
multi-fidelity loop for objectives that support ``evaluate_at(config,
budget)``.
"""

from __future__ import annotations

import math
from typing import Callable, List, Optional

import numpy as np

from ..entities import Configuration
from .base import Optimizer, ScoredCandidate, SearchAdapter
from .tpe import TPE

__all__ = ["BOHB"]


class BOHB(TPE):
    name = "bohb"

    def __init__(self, seed: int = 0, n_initial: int = 4, gamma: float = 0.15,
                 bandwidth: float = 0.18, eta: int = 3, min_budget: float = 1.0,
                 max_budget: float = 9.0, random_fraction: float = 0.2,
                 backend: str = "numpy", max_candidates: int = 512):
        super().__init__(seed=seed, n_initial=n_initial, gamma=gamma,
                         bandwidth=bandwidth, backend=backend,
                         max_candidates=max_candidates)
        self.eta = eta
        self.min_budget = min_budget
        self.max_budget = max_budget
        self.random_fraction = random_fraction

    def ask(self, adapter: SearchAdapter, rng: np.random.Generator,
            n: int = 1, exclude: Optional[set] = None) -> List[ScoredCandidate]:
        # BOHB interleaves random configurations for theoretical guarantees —
        # per batch *slot*, so a batch mixes model and random picks in the
        # same proportion as the serial loop (and draw-for-draw at n=1).
        # Model picks carry their TPE acquisition score; the interleaved
        # random picks are unscored.  History handling is inherited from TPE:
        # campaign-foreign trials join the model's good/bad split and the
        # n_initial warmup count, while the random interleave keeps drawing
        # from the not-yet-sampled pool (which excludes foreign digests via
        # adapter.seen_digests()), so the exploration guarantee holds over
        # the union of the fleet's history too.
        out: List[ScoredCandidate] = []
        exclude = set(exclude) if exclude else set()
        for _ in range(n):
            if rng.uniform() < self.random_fraction:
                candidates = self._unseen_candidates(
                    adapter, rng, self.max_candidates, exclude=exclude)
                if not candidates:
                    break
                pick = ScoredCandidate(
                    candidates[int(rng.integers(len(candidates)))])
            else:
                model = super().ask(adapter, rng, n=1, exclude=exclude)
                if not model:
                    break
                pick = model[0]
            out.append(pick)
            exclude.add(pick.digest)
        return out

    # -- true multi-fidelity loop ------------------------------------------------

    def run_brackets(
        self,
        evaluate_at: Callable[[Configuration, float], Optional[float]],
        suggest_pool: Callable[[int], list],
        n_brackets: int = 2,
        rng: Optional[np.random.Generator] = None,
    ) -> list:
        """Run successive-halving brackets.

        ``evaluate_at(config, budget)`` returns the (minimization) objective at
        a fidelity; ``suggest_pool(n)`` returns n candidate configurations.
        Returns ``[(config, best_full_budget_value)]`` for surviving configs.
        """
        rng = rng if rng is not None else np.random.default_rng(self.seed)
        s_max = int(math.floor(math.log(self.max_budget / self.min_budget, self.eta)))
        results = []
        for bracket in range(min(n_brackets, s_max + 1)):
            s = s_max - bracket
            n0 = int(math.ceil((s_max + 1) / (s + 1) * self.eta ** s))
            b0 = self.max_budget * self.eta ** (-s)
            configs = suggest_pool(n0)
            for i in range(s + 1):
                budget = b0 * self.eta ** i
                scored = []
                for c in configs:
                    v = evaluate_at(c, budget)
                    if v is not None:
                        scored.append((c, v))
                scored.sort(key=lambda cv: cv[1])
                keep = max(1, int(len(scored) / self.eta))
                configs = [c for c, _ in scored[:keep]]
                if i == s:
                    results.extend(scored[:keep])
        return results
