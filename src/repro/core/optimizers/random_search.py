"""Random-walk baseline: uniform sampling without replacement (paper §V-B1)."""

from __future__ import annotations

from typing import List

import numpy as np

from .base import Optimizer, ScoredCandidate, SearchAdapter

__all__ = ["RandomSearch"]


class RandomSearch(Optimizer):
    name = "random"

    def ask(self, adapter: SearchAdapter, rng: np.random.Generator,
            n: int = 1) -> List[ScoredCandidate]:
        """Uniform draws carry no acquisition model: every candidate is
        unscored (scheduling priority 0 — pure FIFO).

        History handling: random search has no model to train, so campaign
        sharing affects it only through ``adapter.seen_digests()`` — foreign
        digests leave the draw pool, making the walk sampling-without-
        replacement over the *fleet's* remaining space (it never re-pays for
        a configuration another member measured).  Solo runs see no foreign
        digests and are unchanged.
        """
        space = adapter.space
        if space.finite and space.size <= 65536:
            # served from the adapter's told-invalidated cache when it has
            # one (same pool, same enumeration order — draw-for-draw with
            # the fresh enumeration, without the O(|Ω|)-per-ask walk)
            unseen = getattr(adapter, "unseen_pool", None)
            if unseen is not None:
                pool = [c for d, c in unseen().items()
                        if d not in adapter.pending]
            else:
                seen = adapter.seen_digests()
                pool = [c for c in space.all_configurations()
                        if c.digest not in seen]
            return self._random_n(pool, rng, n)
        seen = adapter.seen_digests()
        # continuous / huge spaces: rejection-sample the batch
        out: List[ScoredCandidate] = []
        exclude: set = set()
        for _ in range(n):
            for _ in range(1024):
                c = space.sample_configuration(rng)
                if c.digest not in seen and c.digest not in exclude:
                    out.append(ScoredCandidate(c))
                    exclude.add(c.digest)
                    break
            else:
                break
        return out
