"""Random-walk baseline: uniform sampling without replacement (paper §V-B1)."""

from __future__ import annotations

from typing import Optional

import numpy as np

from ..entities import Configuration
from .base import Optimizer, SearchAdapter

__all__ = ["RandomSearch"]


class RandomSearch(Optimizer):
    name = "random"

    def suggest(self, adapter: SearchAdapter, rng: np.random.Generator) -> Optional[Configuration]:
        space = adapter.space
        seen = adapter.seen_digests()
        if space.finite and space.size <= 65536:
            pool = [c for c in space.all_configurations() if c.digest not in seen]
            if not pool:
                return None
            return pool[int(rng.integers(len(pool)))]
        for _ in range(1024):
            c = space.sample_configuration(rng)
            if c.digest not in seen:
                return c
        return None
