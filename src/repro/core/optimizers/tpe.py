"""Tree-structured Parzen estimator (Bergstra et al. 2011).

The SMBO family behind Optuna's default sampler and BOHB's model.  Splits the
observation history at the γ-quantile into good/bad sets, builds per-dimension
Parzen densities l(x) and g(x) for each, and proposes the candidate that
maximizes l(x)/g(x).
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from .base import Optimizer, ScoredCandidate, SearchAdapter

__all__ = ["TPE", "tpe_score"]


def _parzen_logpdf_numeric(u_obs: np.ndarray, u_cand: np.ndarray, bw: float) -> np.ndarray:
    """Log density of a 1-d Parzen (Gaussian KDE) mixture incl. a uniform prior
    component, evaluated at candidate coordinates (all in [0,1])."""
    # components: uniform prior + one gaussian per observation
    n = len(u_obs)
    dens = np.full(u_cand.shape, 1.0)  # uniform prior on [0,1]
    if n:
        d = (u_cand[:, None] - u_obs[None, :]) / bw
        k = np.exp(-0.5 * d * d) / (bw * np.sqrt(2 * np.pi))
        dens = (dens + k.sum(axis=1)) / (n + 1)
    return np.log(np.clip(dens, 1e-12, None))


def _parzen_logpmf_categorical(idx_obs: np.ndarray, idx_cand: np.ndarray, k: int) -> np.ndarray:
    """Smoothed categorical pmf (add-one) evaluated at candidate indices."""
    counts = np.ones(k)
    for i in idx_obs:
        counts[int(i)] += 1.0
    pmf = counts / counts.sum()
    return np.log(pmf[idx_cand])


def tpe_score(space, good_configs, bad_configs, candidates, bw: float = 0.12) -> np.ndarray:
    """log l(x) - log g(x) per candidate."""
    score = np.zeros(len(candidates))
    for d_i, dim in enumerate(space.dimensions):
        cand_vals = [c[dim.name] for c in candidates]
        if dim.kind == "categorical":
            k = dim.cardinality
            gi = np.array([dim.values.index(c[dim.name]) for c in good_configs])
            bi = np.array([dim.values.index(c[dim.name]) for c in bad_configs])
            ci = np.array([dim.values.index(v) for v in cand_vals])
            score += _parzen_logpmf_categorical(gi, ci, k)
            score -= _parzen_logpmf_categorical(bi, ci, k)
        else:
            gu = np.array([dim.to_unit(c[dim.name]) for c in good_configs])
            bu = np.array([dim.to_unit(c[dim.name]) for c in bad_configs])
            cu = np.array([dim.to_unit(v) for v in cand_vals])
            score += _parzen_logpdf_numeric(gu, cu, bw)
            score -= _parzen_logpdf_numeric(bu, cu, bw)
    return score


class TPE(Optimizer):
    name = "tpe"

    def __init__(self, seed: int = 0, n_initial: int = 4, gamma: float = 0.25,
                 bandwidth: float = 0.12, backend: str = "numpy",
                 max_candidates: int = 512):
        super().__init__(seed, backend=backend, max_candidates=max_candidates)
        self.n_initial = n_initial
        self.gamma = gamma
        self.bandwidth = bandwidth

    def _score(self, space, good, bad, candidates) -> np.ndarray:
        """Backend-dispatched Parzen ratio: the vmapped jax path evaluates
        every per-dimension KDE for all candidates in one device call
        (:func:`.accel.tpe_scores`), regression-gated draw-for-draw against
        the numpy reference ``tpe_score``."""
        if self.backend != "numpy":
            from . import accel
            score = accel.tpe_scores(space, good, bad, candidates,
                                     self.bandwidth)
            if score is not None:
                return score
        return tpe_score(space, good, bad, candidates, self.bandwidth)

    def ask(self, adapter: SearchAdapter, rng: np.random.Generator,
            n: int = 1, exclude: Optional[set] = None) -> List[ScoredCandidate]:
        """Propose the batch maximizing l(x)/g(x) (top-n of one scored pool;
        the model only updates on tell, so scoring once per ask is exact).
        Candidates carry their log l(x) - log g(x) as the acquisition score.
        ``exclude`` lets BOHB thread its interleaved batch picks through.

        History handling: the good/bad split runs over *every* valued trial
        in ``adapter.trials`` — including ``action='foreign'`` trials a
        campaign folded in from other optimizers' operations — so under
        cooperative sharing the Parzen densities train on the union of the
        fleet's measurements.  Foreign trials also count toward
        ``n_initial``: a member warm-started by the fleet leaves its random
        init phase early.  Solo runs have no foreign trials, and sharing
        never touches the rng stream, so solo trajectories are unchanged.

        Under a constrained objective the γ-quantile split is
        constraint-filtered: only *feasible* valued trials compete for the
        good set, and every valued SLA violator lands in the bad set
        whatever its objective value — l(x)/g(x) then models "good AND
        within SLA" against everything else.  With no feasible valued trial
        yet the split degrades to the unconstrained one (the violators are
        still the only signal there is).  Filtering happens before scoring,
        so the accelerated backends inherit it unchanged, and it never
        consumes rng draws.
        """
        candidates = self._unseen_candidates(adapter, rng, self.max_candidates,
                                             exclude=exclude)
        if not candidates:
            return []
        ok = [t for t in adapter.trials if t.value is not None]
        if len(ok) < self.n_initial:
            return self._random_n(candidates, rng, n)

        if self._constrained(adapter):
            feas = [t for t in ok if t.feasible is not False]
            if feas:
                infeas = [t for t in ok if t.feasible is False]
                values = np.array([adapter.signed(t.value) for t in feas])
                order = np.argsort(values)
                n_good = max(1, int(np.ceil(self.gamma * len(feas))))
                good = [feas[i].configuration for i in order[:n_good]]
                bad = [feas[i].configuration for i in order[n_good:]] \
                    + [t.configuration for t in infeas]
                score = self._score(adapter.space, good, bad, candidates)
                return self._top_n(candidates, score, n)

        values = np.array([adapter.signed(t.value) for t in ok])
        order = np.argsort(values)
        n_good = max(1, int(np.ceil(self.gamma * len(ok))))
        good = [ok[i].configuration for i in order[:n_good]]
        # Degenerate split (n_good == len(ok), e.g. gamma ~ 1 or a history
        # only as long as n_good): aliasing bad to good would make
        # l(x)/g(x) exactly 1 for EVERY candidate, so each score is 0 and
        # _top_n's stable sort silently returns pool order.  An empty bad
        # set instead scores l(x) against the uniform prior alone (the
        # Parzen densities degrade to the prior when fed no observations),
        # which still ranks candidates by proximity to the good set.
        bad = [ok[i].configuration for i in order[n_good:]]
        score = self._score(adapter.space, good, bad, candidates)
        return self._top_n(candidates, score, n)
