"""Optimizer base classes + the Discovery Space compatibility wrapper.

Mirrors the paper's design (§III-D): optimization algorithms are decoupled
from workload experiments — they only see the ``sample`` method of a
Discovery Space through :class:`SearchAdapter`.  The adapter also implements
the paper's stopping rule (§V-B1: stop when the incumbent has not improved
for five consecutive trials) and reports, per trial, whether the sample was
*measured* or transparently *reused* from the common context — the raw data
behind the paper's Fig. 7 incremental-sampling evaluation.

Ask/tell protocol
-----------------

Optimizers implement ``ask(adapter, rng, n) -> [ScoredCandidate]``: propose
up to ``n`` distinct unsampled candidates *without* evaluating them, each
carrying the optimizer's acquisition score (None when the proposal is
unscored, e.g. random draws).  Scores ride along as work-item *priorities*:
queue-rendezvous workers measure the highest-acquisition configurations
first (Lynceus-style), while results and records stay in submission/tell
order, so scoring never perturbs the trajectory.  Evaluation is the
driver's job: :meth:`SearchAdapter.evaluate_batch` routes the batch through
``DiscoverySpace.sample_batch`` (fanning experiments over a worker pool)
and *tells* the resulting :class:`Trial` list back into the adapter's
history, which is the only state optimizers observe.  ``ask`` with ``n=1``
is the classic suggest step — :meth:`Optimizer.suggest` remains as that thin
wrapper, and :func:`run_optimizer` with ``batch_size=1`` reproduces the
serial trajectory draw-for-draw.
"""

from __future__ import annotations

import abc
import math
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..actions import MeasurementError
from ..discovery import BatchResult, DiscoverySpace
from ..entities import Configuration
from ..execution import ExecutionBackend, WorkItem

__all__ = ["Trial", "OptimizerRun", "ScoredCandidate", "SearchAdapter",
           "Optimizer", "run_optimizer", "hypergeom_p_found"]


@dataclass(frozen=True)
class ScoredCandidate:
    """One proposed configuration + the acquisition score behind it.

    ``score`` is in *maximization* orientation (higher = more informative:
    EI for GP-BO, log l/g for TPE) and becomes the work item's scheduling
    priority; None marks an unscored proposal (random draws, init phase),
    which schedules at priority 0.  The wrapper is deliberately thin —
    ``digest`` proxies through so candidate bookkeeping (dedup sets, BOHB's
    interleaved exclude) reads the same as for a bare configuration.
    """

    configuration: Configuration
    score: Optional[float] = None

    @property
    def digest(self) -> str:
        return self.configuration.digest


def _split_scored(batch: Sequence) -> Tuple[List[Configuration], Optional[List[float]]]:
    """Normalize an ask batch (ScoredCandidates and/or bare Configurations)
    into parallel (configurations, priorities) lists; priorities is None
    when nothing in the batch carried a score (all-FIFO, no point tagging)."""
    configs: List[Configuration] = []
    scores: List[float] = []
    any_scored = False
    for cand in batch:
        if isinstance(cand, ScoredCandidate):
            configs.append(cand.configuration)
            scores.append(0.0 if cand.score is None else float(cand.score))
            any_scored = any_scored or cand.score is not None
        else:
            configs.append(cand)
            scores.append(0.0)
    return configs, (scores if any_scored else None)


@dataclass
class Trial:
    configuration: Configuration
    value: Optional[float]  # objective value (None => non-deployable)
    action: str             # 'measured' | 'reused' | 'predicted' | 'failed'
    seq: int


@dataclass
class OptimizerRun:
    optimizer: str
    metric: str
    mode: str
    trials: list = field(default_factory=list)
    operation_id: str = ""
    batch_size: int = 1
    max_inflight: Optional[int] = None  # set when the pipelined engine ran

    @property
    def num_trials(self) -> int:
        return len(self.trials)

    @property
    def num_measured(self) -> int:
        return sum(1 for t in self.trials if t.action == "measured")

    @property
    def num_reused(self) -> int:
        return sum(1 for t in self.trials if t.action in ("reused", "predicted"))

    @property
    def best(self) -> Optional[Trial]:
        vals = [t for t in self.trials if t.value is not None]
        if not vals:
            return None
        key = (lambda t: t.value) if self.mode == "min" else (lambda t: -t.value)
        return min(vals, key=key)

    @property
    def normalized_cost(self) -> float:
        """Paper §V-B1: new measurements / total samples."""
        if not self.trials:
            return 0.0
        return self.num_measured / len(self.trials)

    def best_value_by_step(self) -> list:
        out, best = [], None
        sign = 1.0 if self.mode == "min" else -1.0
        for t in self.trials:
            if t.value is not None:
                v = sign * t.value
                best = v if best is None else min(best, v)
            out.append(None if best is None else sign * best)
        return out


class SearchAdapter:
    """The 'Ray Tune wrapper' of §III-D: optimizer-facing view of a study.

    The driver asks an optimizer for a candidate batch, evaluates it here
    (:meth:`evaluate_batch` routes everything through
    ``DiscoverySpace.sample_batch`` so all TRACE bookkeeping happens — with
    ``workers > 1`` the experiments run on a thread pool), and the resulting
    trials are *told* back into :attr:`trials`, the only optimizer-visible
    state.  :meth:`evaluate` is the batch-of-one convenience used by legacy
    serial loops.
    """

    def __init__(self, ds: DiscoverySpace, metric: str, mode: str = "min",
                 operation_id: Optional[str] = None, optimizer_name: str = "opt"):
        if mode not in ("min", "max"):
            raise ValueError(f"mode must be min|max, got {mode}")
        self.ds = ds
        self.metric = metric
        self.mode = mode
        self.operation_id = operation_id or ds.begin_operation(
            "optimization", {"optimizer": optimizer_name, "metric": metric, "mode": mode}
        )
        self.trials: list = []
        # Digests proposed but not yet told (in-flight on an execution
        # backend).  The pipelined driver marks/clears these so ``ask`` never
        # re-proposes an outstanding candidate.
        self.pending: set = set()

    @property
    def space(self):
        return self.ds.space

    # -- ask/tell -----------------------------------------------------------

    def tell(self, trials: Sequence[Trial]) -> None:
        """Record externally-evaluated trials into the optimizer-visible
        history (the 'tell' half of the protocol).  Partial batches are fine:
        the pipelined engine tells each trial as its backend completes it,
        without waiting for stragglers."""
        self.trials.extend(trials)

    def _make_trial(self, result: BatchResult, seq: int) -> Trial:
        if not result.ok:
            return Trial(result.configuration, None, "failed", seq)
        if not result.sample.has(self.metric):
            raise KeyError(
                f"metric {self.metric!r} not among action-space properties "
                f"{self.ds.actions.observed_properties}"
            )
        return Trial(result.configuration, result.sample.value(self.metric),
                     result.action, seq)

    def tell_result(self, result: BatchResult) -> Trial:
        """Tell ONE completed evaluation (the pipelined engine's tell path)."""
        trial = self._make_trial(result, len(self.trials))
        self.tell([trial])
        return trial

    def evaluate_batch(self, configurations: Sequence,
                       workers: int = 1, executor=None,
                       backend=None) -> List[Optional[float]]:
        """Evaluate a candidate batch and tell the results.

        Accepts :class:`ScoredCandidate` lists (the ``ask`` contract) or
        bare configurations; acquisition scores are forwarded as work-item
        priorities so scheduling backends measure best-first.  Experiments
        fan out over an execution backend (``workers`` threads, a
        caller-owned ``executor`` reused across batches, or any backend
        accepted by ``DiscoverySpace.sample_batch``); trials are appended in
        submission order so the history (and therefore every subsequent
        ``ask``) is deterministic regardless of completion order.  Failed
        measurements become ``action='failed'`` trials with value None.
        """
        configs, priorities = _split_scored(configurations)
        results = self.ds.sample_batch(
            configs, operation_id=self.operation_id, workers=workers,
            executor=executor, backend=backend, priorities=priorities)
        batch = [self._make_trial(result, len(self.trials) + i)
                 for i, result in enumerate(results)]
        self.tell(batch)
        return [t.value for t in batch]

    def evaluate(self, configuration) -> Optional[float]:
        return self.evaluate_batch([configuration])[0]

    def seen_digests(self) -> set:
        return {t.configuration.digest for t in self.trials} | self.pending

    def signed(self, value: float) -> float:
        """Value in canonical minimization orientation."""
        return value if self.mode == "min" else -value


class Optimizer(abc.ABC):
    """Ask-only optimizer interface (observation happens via history).

    Implementations propose candidate *batches*; they never evaluate.  The
    contract for :meth:`ask`:

    * return up to ``n`` configurations, all distinct and none already in the
      adapter's history (an exhausted finite space returns fewer, possibly
      ``[]`` which stops the run);
    * with ``n=1`` the rng consumption must match the classic one-step
      suggest exactly, so serial trajectories are reproducible;
    * model state must come from ``adapter.trials`` only — pending proposals
      within the batch are accounted for by excluding them from the pool, not
      by mutating shared state (the paper's multi-worker setting: another
      process may append to the store between ask and tell).
    """

    name = "optimizer"

    def __init__(self, seed: int = 0):
        self.seed = seed

    @abc.abstractmethod
    def ask(self, adapter: SearchAdapter, rng: np.random.Generator,
            n: int = 1) -> List[ScoredCandidate]:
        """Propose up to ``n`` next candidates ([] => space exhausted).

        Each candidate carries the acquisition score that ranked it (None
        for unscored proposals); drivers forward scores as scheduling
        priorities.  Scoring must never change rng consumption — the n=1
        stream stays draw-for-draw identical to the classic suggest step.
        """

    def suggest(self, adapter: SearchAdapter, rng: np.random.Generator) -> Optional[Configuration]:
        """Single-candidate convenience wrapper over :meth:`ask` — returns
        the bare configuration (the classic suggest contract; the score is
        scheduling metadata with no meaning for a batch of one).  Tolerates
        subclasses whose ``ask`` still returns bare configurations, like
        every other consumer of the ask batch."""
        batch = self.ask(adapter, rng, n=1)
        if not batch:
            return None
        first = batch[0]
        return first.configuration if isinstance(first, ScoredCandidate) else first

    # -- helpers shared by concrete optimizers ---------------------------------

    @staticmethod
    def _unseen_candidates(adapter: SearchAdapter, rng: np.random.Generator,
                           max_candidates: int = 512,
                           exclude: Optional[set] = None) -> list:
        """Candidate pool: unsampled configurations of a finite space (or
        random draws for continuous spaces).  ``exclude`` removes candidates
        already proposed earlier in the current batch."""
        space = adapter.space
        seen = adapter.seen_digests()
        if exclude:
            seen |= exclude
        if space.finite and space.size <= 4096:
            pool = [c for c in space.all_configurations() if c.digest not in seen]
            if len(pool) > max_candidates:
                idx = rng.choice(len(pool), size=max_candidates, replace=False)
                pool = [pool[i] for i in idx]
            return pool
        out, tries = [], 0
        while len(out) < max_candidates and tries < max_candidates * 4:
            c = space.sample_configuration(rng)
            if c.digest not in seen:
                out.append(c)
            tries += 1
        return out

    @staticmethod
    def _history_arrays(adapter: SearchAdapter):
        """(X, y) over successful trials, y in minimization orientation."""
        ok = [t for t in adapter.trials if t.value is not None]
        if not ok:
            return np.zeros((0, len(adapter.space.dimensions))), np.zeros((0,))
        X = np.stack([adapter.space.encode(t.configuration) for t in ok])
        y = np.array([adapter.signed(t.value) for t in ok])
        return X, y

    @staticmethod
    def _top_n(candidates: list, score: np.ndarray, n: int) -> List[ScoredCandidate]:
        """The n best-scoring candidates (with their acquisition scores), in
        score order.  Stable on ties so ``_top_n(c, s, 1)[0].configuration
        == c[np.argmax(s)]`` exactly."""
        order = np.argsort(-score, kind="stable")
        return [ScoredCandidate(candidates[i], float(score[i]))
                for i in order[:n]]

    @staticmethod
    def _random_n(pool: Sequence[Configuration], rng: np.random.Generator,
                  n: int) -> List[ScoredCandidate]:
        """Up to n unscored draws without replacement, one ``rng.integers``
        call per pick — the shared init-phase sampler, draw-for-draw
        identical to the classic single-suggest draw at n=1."""
        pool = list(pool)
        out: List[ScoredCandidate] = []
        for _ in range(min(n, len(pool))):
            out.append(ScoredCandidate(pool.pop(int(rng.integers(len(pool))))))
        return out


class _StoppingRule:
    """The paper's §V-B1 stopping rule, shared by both engines: halt when the
    incumbent best has not improved for ``patience`` consecutive trials."""

    def __init__(self, adapter: SearchAdapter, patience: int, min_trials: int):
        self.adapter = adapter
        self.patience = patience
        self.min_trials = min_trials
        self.best: Optional[float] = None
        self.stall = 0
        self.stop = False

    def observe(self, value: Optional[float]) -> None:
        if value is not None:
            sv = self.adapter.signed(value)
            if self.best is None or sv < self.best - 1e-12:
                self.best = sv
                self.stall = 0
            else:
                self.stall += 1
        else:
            self.stall += 1
        if len(self.adapter.trials) >= self.min_trials and self.stall >= self.patience:
            self.stop = True


def _run_pipelined(
    optimizer: Optimizer,
    adapter: SearchAdapter,
    rng: np.random.Generator,
    max_trials: int,
    rule: _StoppingRule,
    max_inflight: int,
    backend,
) -> None:
    """The Lynceus-style pipelined ask/tell engine.

    Keeps up to ``max_inflight`` trials outstanding on an execution backend;
    every completion is told immediately (a partial tell) and its slot is
    refilled by asking the optimizer for ONE replacement — no barrier, so a
    straggling experiment never stalls the next ask.  In-flight candidates
    are visible to ``ask`` through ``adapter.pending``, which keeps proposals
    distinct without mutating optimizer state.

    Records land in completion order; with ``max_inflight=1`` completion
    order *is* submission order and the run reproduces the serial
    ``batch_size=1`` trajectory draw-for-draw (same rng stream, same record).
    """
    ds = adapter.ds
    owned = not isinstance(backend, ExecutionBackend)
    engine = ds.execution_backend(backend, workers=max_inflight)
    inflight: dict = {}  # tag -> (configuration, digest)
    tag = 0
    exhausted = False
    crash: Optional[BaseException] = None
    pause = 0.0005
    try:
        while True:
            while (not rule.stop and crash is None and not exhausted
                   and len(inflight) < max_inflight
                   and len(adapter.trials) + len(inflight) < max_trials):
                batch = optimizer.ask(adapter, rng, n=1)
                if not batch:
                    exhausted = True
                    break
                configs, priorities = _split_scored(batch)
                config = configs[0]
                priority = priorities[0] if priorities is not None else 0.0
                digest = ds.store.put_configuration(config)
                adapter.pending.add(digest)
                engine.submit(WorkItem(config, digest, tag, priority=priority))
                inflight[tag] = (config, digest)
                tag += 1
            if not inflight:
                break
            completed = engine.poll()
            if not completed:
                ds._maybe_sweep_claims()
                time.sleep(pause)
                pause = min(pause * 2, 0.005)
                continue
            pause = 0.0005
            for res in completed:
                config, digest = inflight.pop(res.item.tag)
                adapter.pending.discard(digest)
                if res.action == "crashed":
                    # an in-process backend surfaced an experiment bug:
                    # propagate like the batch engine — but only after the
                    # remaining in-flight trials drain, so their records and
                    # tells land first (their values are already durable)
                    crash = crash if crash is not None else res.error
                    continue
                result = ds.record_result(config, digest, res.action,
                                          res.error, adapter.operation_id)
                trial = adapter.tell_result(result)
                rule.observe(trial.value)
            # once stopping (or a crash) triggers we submit nothing new, but
            # trials already in flight are drained and told — they are paid
            # for, and the batch engine likewise tells its full final batch
        if crash is not None:
            raise crash
    finally:
        if owned:
            engine.close()


def run_optimizer(
    optimizer: Optimizer,
    ds: DiscoverySpace,
    metric: str,
    mode: str = "min",
    max_trials: int = 200,
    patience: int = 5,
    rng: Optional[np.random.Generator] = None,
    min_trials: int = 1,
    batch_size: int = 1,
    workers: int = 1,
    max_inflight: Optional[int] = None,
    backend: Union[ExecutionBackend, str, None] = None,
) -> OptimizerRun:
    """Run one optimization operation on a Discovery Space.

    Two engines share the ask/tell protocol and the stopping rule:

    * **batched** (default): each step asks for a ``batch_size`` candidate
      batch and evaluates it with ``workers`` parallel experiment workers,
      barrier-synchronizing per batch (with the defaults this is the classic
      serial loop, draw-for-draw);
    * **pipelined** (``max_inflight=N``): up to N trials stay outstanding on
      an execution backend; completed trials are told and replaced
      immediately, so slow experiments never stall the next ask.
      ``max_inflight=1`` reproduces the serial trajectory draw-for-draw.

    ``backend`` routes experiment execution (``serial | thread | process |
    queue`` or an :class:`~repro.core.execution.ExecutionBackend`); None
    keeps thread execution sized to the engine's parallelism.

    Stopping rule follows the paper (§V-B1): halt when the incumbent best has
    not improved for ``patience`` consecutive trials (or after ``max_trials``,
    or when the space is exhausted).  Trials are assessed in tell order, so
    the stopping decision is identical for serial and parallel execution of
    the same proposals.
    """
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    if max_inflight is not None and max_inflight < 1:
        raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
    rng = rng if rng is not None else np.random.default_rng(optimizer.seed)
    adapter = SearchAdapter(ds, metric, mode, optimizer_name=optimizer.name)
    rule = _StoppingRule(adapter, patience, min_trials)
    if max_inflight is not None:
        _run_pipelined(optimizer, adapter, rng, max_trials, rule,
                       max_inflight, backend)
    else:
        # one worker pool / backend for the whole run, not one per batch
        owned = not isinstance(backend, ExecutionBackend)
        pool = (ThreadPoolExecutor(max_workers=workers)
                if workers > 1 and backend is None else None)
        engine = (ds.execution_backend(backend, workers=workers)
                  if backend is not None else None)
        try:
            while not rule.stop and len(adapter.trials) < max_trials:
                n = min(batch_size, max_trials - len(adapter.trials))
                batch = optimizer.ask(adapter, rng, n=n)
                if not batch:
                    break
                values = adapter.evaluate_batch(batch, workers=workers,
                                                executor=pool, backend=engine)
                for value in values:
                    rule.observe(value)
        finally:
            if pool is not None:
                pool.shutdown(wait=False)
            if engine is not None and owned:
                engine.close()
    return OptimizerRun(
        optimizer=optimizer.name,
        metric=metric,
        mode=mode,
        trials=adapter.trials,
        operation_id=adapter.operation_id,
        batch_size=batch_size,
        max_inflight=max_inflight,
    )


def hypergeom_p_found(space_size: int, target_count: int, n_draws: int) -> float:
    """P(≥1 target configuration after n draws without replacement).

    The paper's random-walk baseline (§V-B1) 'analytically described by the
    hypergeometric distribution':  1 - C(N-K, n) / C(N, n).
    """
    n_draws = min(n_draws, space_size)
    log_p_none = 0.0
    for i in range(n_draws):
        good_left = space_size - target_count - i
        total_left = space_size - i
        if good_left <= 0:
            return 1.0
        log_p_none += math.log(good_left) - math.log(total_left)
    return 1.0 - math.exp(log_p_none)
