"""Optimizer base classes + the Discovery Space compatibility wrapper.

Mirrors the paper's design (§III-D): optimization algorithms are decoupled
from workload experiments — they only see the ``sample`` method of a
Discovery Space through :class:`SearchAdapter`.  The adapter also implements
the paper's stopping rule (§V-B1: stop when the incumbent has not improved
for five consecutive trials) and reports, per trial, whether the sample was
*measured* or transparently *reused* from the common context — the raw data
behind the paper's Fig. 7 incremental-sampling evaluation.

Ask/tell protocol
-----------------

Optimizers implement ``ask(adapter, rng, n) -> [ScoredCandidate]``: propose
up to ``n`` distinct unsampled candidates *without* evaluating them, each
carrying the optimizer's acquisition score (None when the proposal is
unscored, e.g. random draws).  Scores ride along as work-item *priorities*:
queue-rendezvous workers measure the highest-acquisition configurations
first (Lynceus-style), while results and records stay in submission/tell
order, so scoring never perturbs the trajectory.  Evaluation is the
driver's job: :meth:`SearchAdapter.evaluate_batch` routes the batch through
``DiscoverySpace.sample_batch`` (fanning experiments over a worker pool)
and *tells* the resulting :class:`Trial` list back into the adapter's
history, which is the only state optimizers observe.  ``ask`` with ``n=1``
is the classic suggest step — :meth:`Optimizer.suggest` remains as that thin
wrapper, and :func:`run_optimizer` with ``batch_size=1`` reproduces the
serial trajectory draw-for-draw.
"""

from __future__ import annotations

import abc
import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..actions import MeasurementError
from ..discovery import BatchResult, DiscoverySpace
from ..entities import Configuration
from ..execution import ExecutionBackend

__all__ = ["Trial", "OptimizerRun", "ScoredCandidate", "SearchAdapter",
           "Optimizer", "run_optimizer", "hypergeom_p_found", "as_scored",
           "FOREIGN_ACTION", "WARM_ACTION"]

#: Action tag of a trial folded into an adapter's history from ANOTHER
#: operation's sampling record (a campaign foreign tell).  Deliberately not
#: part of the sampling-record vocabulary: foreign trials exist only in the
#: optimizer-visible history — the store record of the originating operation
#: is the single source of truth, so nothing is double-recorded.
FOREIGN_ACTION = "foreign"

#: Action tag of a trial folded by :meth:`SearchAdapter.warm_start` — a value
#: transferred from a *related* space (paper §IV-3/4): typically a surrogate
#: prediction, sometimes a re-measured representative.  Like foreign trials
#: these exist only in the optimizer-visible history; unlike them, warm
#: digests are NOT marked seen, so the optimizer may still propose (and truly
#: measure) a warm-predicted configuration — predictions guide the model,
#: they never veto a measurement.
WARM_ACTION = "warm"


@dataclass(frozen=True)
class ScoredCandidate:
    """One proposed configuration + the acquisition score behind it.

    ``score`` is in *maximization* orientation (higher = more informative:
    EI for GP-BO, log l/g for TPE) and becomes the work item's scheduling
    priority; None marks an unscored proposal (random draws, init phase),
    which schedules at priority 0.  The wrapper is deliberately thin —
    ``digest`` proxies through so candidate bookkeeping (dedup sets, BOHB's
    interleaved exclude) reads the same as for a bare configuration.
    """

    configuration: Configuration
    score: Optional[float] = None

    @property
    def digest(self) -> str:
        return self.configuration.digest


def as_scored(batch: Sequence) -> List[ScoredCandidate]:
    """Normalize an ask batch to :class:`ScoredCandidate`s.

    :meth:`Optimizer.ask` documents a ScoredCandidate return, but the
    tolerance :meth:`Optimizer.suggest` extends — a subclass still returning
    bare configurations — must hold at *every* driver boundary, or a legacy
    optimizer works under the batch engine and crashes the pipelined engine
    (or the campaign foreign-tell path) the first time something reads
    ``.configuration``/``.score`` off its batch.  Drivers call this once on
    each ask result; everything downstream sees ScoredCandidates only.
    None (another legacy exhaustion signal, tolerated by the batched driver)
    normalizes to [].
    """
    return [c if isinstance(c, ScoredCandidate) else ScoredCandidate(c)
            for c in (batch if batch is not None else [])]


def _split_scored(batch: Sequence) -> Tuple[List[Configuration], Optional[List[float]]]:
    """Normalize an ask batch (ScoredCandidates and/or bare Configurations)
    into parallel (configurations, priorities) lists; priorities is None
    when nothing in the batch carried a score (all-FIFO, no point tagging)."""
    scored = as_scored(batch)
    configs = [c.configuration for c in scored]
    if all(c.score is None for c in scored):
        return configs, None
    return configs, [0.0 if c.score is None else float(c.score)
                     for c in scored]


@dataclass
class Trial:
    configuration: Configuration
    value: Optional[float]  # objective value (None => non-deployable)
    action: str             # 'measured' | 'reused' | 'predicted' | 'failed'
    seq: int
    # SLA verdict under the adapter's objective constraints: True/False when
    # evaluated against one, None when unconstrained or unknowable (warm
    # predictions carry no constraint properties).  Infeasible trials are
    # real evidence — they train models — but are never incumbents.
    feasible: Optional[bool] = None


@dataclass
class OptimizerRun:
    optimizer: str
    metric: str
    mode: str
    trials: list = field(default_factory=list)
    operation_id: str = ""
    batch_size: int = 1
    max_inflight: Optional[int] = None  # set when the pipelined engine ran

    @property
    def num_trials(self) -> int:
        return len(self.trials)

    @property
    def num_measured(self) -> int:
        return sum(1 for t in self.trials if t.action == "measured")

    @property
    def num_reused(self) -> int:
        return sum(1 for t in self.trials if t.action in ("reused", "predicted"))

    @property
    def num_infeasible(self) -> int:
        return sum(1 for t in self.trials if t.feasible is False)

    @staticmethod
    def _incumbent_eligible(t: Trial) -> bool:
        """Incumbents are REAL, SLA-meeting observations: warm trials are
        surrogate predictions (an unmeasured guess must never be reported as
        the best found), and constraint-violating trials are infeasible."""
        return (t.value is not None and t.action != WARM_ACTION
                and t.feasible is not False)

    @property
    def best(self) -> Optional[Trial]:
        vals = [t for t in self.trials if self._incumbent_eligible(t)]
        if not vals:
            return None
        key = (lambda t: t.value) if self.mode == "min" else (lambda t: -t.value)
        return min(vals, key=key)

    @property
    def normalized_cost(self) -> float:
        """Paper §V-B1: new measurements / samples this run itself told.
        Foreign- and warm-folded history is other operations' spending (or
        free predictions) — counting it in the denominator understates the
        member's own cost."""
        own = sum(1 for t in self.trials
                  if t.action not in (FOREIGN_ACTION, WARM_ACTION))
        if not own:
            return 0.0
        return self.num_measured / own

    def best_value_by_step(self) -> list:
        out, best = [], None
        sign = 1.0 if self.mode == "min" else -1.0
        for t in self.trials:
            if self._incumbent_eligible(t):
                v = sign * t.value
                best = v if best is None else min(best, v)
            out.append(None if best is None else sign * best)
        return out


class SearchAdapter:
    """The 'Ray Tune wrapper' of §III-D: optimizer-facing view of a study.

    The driver asks an optimizer for a candidate batch, evaluates it here
    (:meth:`evaluate_batch` routes everything through
    ``DiscoverySpace.sample_batch`` so all TRACE bookkeeping happens — with
    ``workers > 1`` the experiments run on a thread pool), and the resulting
    trials are *told* back into :attr:`trials`, the only optimizer-visible
    state.  :meth:`evaluate` is the batch-of-one convenience used by legacy
    serial loops.
    """

    def __init__(self, ds: DiscoverySpace, metric: str, mode: str = "min",
                 operation_id: Optional[str] = None, optimizer_name: str = "opt",
                 objective=None):
        if mode not in ("min", "max"):
            raise ValueError(f"mode must be min|max, got {mode}")
        self.ds = ds
        self.metric = metric
        self.mode = mode
        # Optional ObjectiveSpec (repro.core.api.spec): scalarizes the
        # trial value from several measured properties and/or attaches hard
        # SLA constraints.  None keeps the single-metric behavior exactly.
        self.objective = objective
        self._constrained = objective is not None and bool(objective.constraints)
        meta = {"optimizer": optimizer_name, "metric": metric, "mode": mode}
        if self._constrained:
            meta["constraints"] = [c.describe() for c in objective.constraints]
        self.operation_id = operation_id or ds.begin_operation(
            "optimization", meta
        )
        self.trials: list = []
        # Digests proposed but not yet told (in-flight on an execution
        # backend).  The pipelined driver marks/clears these so ``ask`` never
        # re-proposes an outstanding candidate.
        self.pending: set = set()
        # Foreign-tell sync state: the highest sampling-record ``rowid`` this
        # adapter has folded, plus the value-None *failed* trials (own and
        # foreign alike — registered by tell()) that are provisional:
        # failures can be transient, so if a later foreign record shows the
        # configuration was successfully measured, sync_foreign upgrades the
        # trial's value in place instead of masking it.  Solo drivers never
        # sync, so both are inert outside campaigns.
        self.record_watermark: int = 0
        self._provisional_failed: dict = {}
        # Incrementally-maintained digest set over ``trials`` (tell() adds;
        # nothing ever leaves a history), so per-sync dedup is O(new rows)
        # instead of rebuilding a set over the whole history every call.
        self._history_digests: set = set()
        # Trials folded by warm_start (cross-space transfer): counted apart
        # from told trials so budgets/stopping rules never charge for them.
        self.warm_told: int = 0
        # Lazily-built {digest: configuration} of the finite space's
        # not-yet-told configurations, in enumeration order.  tell() evicts
        # told digests, so ``ask`` filters O(pool) instead of re-enumerating
        # O(|Ω|) every call (see Optimizer._unseen_candidates).  Pending and
        # warm digests stay IN the cache — pending clears on tell/requeue and
        # warm configurations may legitimately be re-proposed — and are
        # filtered per-ask.
        self._unseen_cache: Optional[dict] = None

    def unseen_pool(self) -> dict:
        """The cached not-yet-told enumeration of a finite space."""
        if self._unseen_cache is None:
            self._unseen_cache = {
                c.digest: c for c in self.space.all_configurations()
                if c.digest not in self._history_digests}
        return self._unseen_cache

    @property
    def space(self):
        return self.ds.space

    # -- ask/tell -----------------------------------------------------------

    def tell(self, trials: Sequence[Trial]) -> None:
        """Record externally-evaluated trials into the optimizer-visible
        history (the 'tell' half of the protocol).  Partial batches are fine:
        the pipelined engine tells each trial as its backend completes it,
        without waiting for stragglers.

        Value-None failed trials (own failures and foreign-folded ones) are
        registered as *provisional*: a failure can be transient, and if a
        later sampling record shows another operation measured the
        configuration successfully, :meth:`sync_foreign` upgrades the
        trial's value in place rather than letting the failure mask it.
        """
        for t in trials:
            self._history_digests.add(t.configuration.digest)
            if self._unseen_cache is not None:
                self._unseen_cache.pop(t.configuration.digest, None)
            if t.value is None and t.action in ("failed", FOREIGN_ACTION):
                self._provisional_failed[t.configuration.digest] = t
        self.trials.extend(trials)

    def _objective_properties(self) -> tuple:
        """Properties the trial value is computed from."""
        if self.objective is not None and self.objective.scalarized:
            return self.objective.objective_properties()
        return (self.metric,)

    def _sample_objective(self, sample):
        """``(value, feasible)`` of a sample under this adapter's objective,
        or None when the sample lacks the properties the value needs (e.g. a
        foreign operation measured a different action space)."""
        obj = self.objective
        if obj is None or not obj.scalarized:
            if not sample.has(self.metric):
                return None
            value = sample.value(self.metric)
        else:
            if not all(sample.has(p) for p in obj.objective_properties()):
                return None
            value = obj.value(sample.value)
        feasible = None
        if self._constrained:
            feasible = obj.feasible(
                lambda p: sample.value(p) if sample.has(p) else None)
        return value, feasible

    def _make_trial(self, result: BatchResult, seq: int) -> Trial:
        if not result.ok:
            # a non-deployable configuration certainly does not meet an SLA
            return Trial(result.configuration, None, "failed", seq,
                         feasible=False if self._constrained else None)
        vf = self._sample_objective(result.sample)
        if vf is None:
            raise KeyError(
                f"objective properties {self._objective_properties()!r} not "
                f"all among action-space properties "
                f"{self.ds.actions.observed_properties}"
            )
        value, feasible = vf
        return Trial(result.configuration, value, result.action, seq,
                     feasible=feasible)

    def tell_result(self, result: BatchResult) -> Trial:
        """Tell ONE completed evaluation (the pipelined engine's tell path)."""
        trial = self._make_trial(result, len(self.trials))
        self.tell([trial])
        return trial

    def warm_start(self, entries: Sequence[Tuple[Configuration, float]]) -> int:
        """Fold cross-space transferred values into the model-visible history
        (the paper's §IV-3/4 reuse: surrogate predictions over a related,
        already-measured space warm-starting a fresh search).

        Each ``(configuration, value)`` entry becomes an
        ``action='warm'`` :class:`Trial`, appended in the given order — the
        caller supplies a deterministic order, and this method is rng-free,
        so warm-started trajectories are exactly reproducible.  Unlike
        :meth:`tell`, warm digests are NOT added to the seen set: a warm
        value is (usually) a *prediction*, and excluding its configuration
        from proposals would let an approximate surrogate veto ever
        measuring the true best.  The optimizer trains on warm values
        immediately (they count toward model-phase thresholds like
        ``n_initial``, exactly as foreign trials do) and re-proposing a warm
        configuration measures it for real — the measured trial then joins
        the history alongside the prediction, correcting the model.

        Warm trials are never told to the store (no sampling-record event:
        the source space's record is the single source of truth, as with
        foreign tells) and never charged against budgets or stopping rules
        — drivers count *own* told trials.  Returns the number folded.
        """
        folded = 0
        for config, value in entries:
            self.trials.append(
                Trial(config, float(value), WARM_ACTION, len(self.trials)))
            folded += 1
        self.warm_told += folded
        return folded

    def sync_foreign(self) -> int:
        """Fold other operations' sampling events into this history — the
        campaign foreign-tell path (paper §V: transparent sharing between
        concurrently-executing optimizers).

        Reads the space's record incrementally from ``record_watermark``
        (:meth:`SampleStore.records_since`: O(new rows), indexed) and
        appends one ``action='foreign'`` :class:`Trial` per *new* foreign
        configuration, so the optimizer's next model fit trains on the union
        of the fleet's history.  Digest-deduplicated against everything this
        adapter already knows — own trials, in-flight proposals, and
        previously folded foreign tells — so a configuration enters the
        history at most once no matter how many operations sampled it.
        Foreign ``failed`` events fold as value-None trials: the optimizer
        learns the configuration is non-deployable without re-paying for
        it.  A value-None failed trial is *provisional*, though — failures
        can be transient (quota, preemption) and the store permits
        re-measurement — so if a later record shows another operation
        successfully measured the same configuration, a foreign *recovery*
        trial carrying the measured value is appended at the current
        history position (never mutating the already-told failure: trial
        objects are shared with fleet event traces and per-member results,
        and rewriting them would retroactively falsify time-to-best
        metrics).  Recovery is the one case a digest legitimately appears
        twice in a history — once failed-None, once valued — and each
        digest recovers at most once.

        Safe to call at any time (records are appended only after their
        values are durable, so every folded trial's value is readable), and
        works identically when the foreign operations live in *other
        processes* sharing the store file.  Returns the number of trials
        folded; solo drivers never call this, which keeps their trajectories
        byte-identical.
        """
        store = self.ds.store
        # Snapshot the committed tail FIRST: everything at or below it is
        # either returned below or our own (already in the history), so the
        # watermark can safely jump to it even when own rows dominate the
        # range — repeated syncs never re-scan them.  Rows committing after
        # this read get higher rowids (single-writer id allocation) and are
        # picked up next sync.
        tail = store.last_record_rowid(self.ds.space_id)
        if tail <= self.record_watermark:
            return 0
        folded = 0
        # Page the range instead of materializing it: each page holds at
        # most RECORD_PAGE_SIZE entries and its configurations are
        # prefetched in ONE batched (cache-assisted) read — at 10⁶-record
        # depth a first sync streams the record in bounded memory, and on
        # the served backend a page costs two round-trips, not 2·page_size.
        for page in self._record_pages(store, tail):
            interesting = [
                rec.config_digest for rec in page
                if rec.config_digest not in self._history_digests
                or rec.config_digest in self._provisional_failed]
            configs = store.get_configurations(interesting)
            folded += self._fold_page(store, page, configs)
        self.record_watermark = tail
        return folded

    def _record_pages(self, store, tail: int):
        """Snapshot-bounded pages of foreign records in (watermark, tail]."""
        from ..store.base import RECORD_PAGE_SIZE
        watermark = self.record_watermark
        while watermark < tail:
            page = store.records_since(self.ds.space_id, watermark,
                                       limit=RECORD_PAGE_SIZE,
                                       exclude_operation=self.operation_id,
                                       upto_rowid=tail)
            if page:
                yield page
            if len(page) < RECORD_PAGE_SIZE:
                return  # LIMIT not hit: the remaining range is exhausted
            watermark = page[-1].rowid

    def _fold_page(self, store, records, configs: dict) -> int:
        folded = 0
        for rec in records:
            provisional = self._provisional_failed.get(rec.config_digest)
            seen = (rec.config_digest in self._history_digests
                    or rec.config_digest in self.pending)
            if seen and provisional is None:
                continue
            config = configs.get(rec.config_digest) \
                or store.get_configuration(rec.config_digest)
            if config is None:  # pragma: no cover - store corruption guard
                continue
            if rec.action == "failed":
                if seen:
                    continue  # a trial (provisional or not) already stands
                self.tell([Trial(
                    config, None, FOREIGN_ACTION, len(self.trials),
                    feasible=False if self._constrained else None,
                )])  # registers provisional
                folded += 1
                continue
            sample = self.ds._reconstruct(rec.config_digest, config)
            vf = self._sample_objective(sample)
            if vf is None:
                # foreign operation measured a different action space's
                # properties; nothing this study can train on
                continue
            value, feasible = vf
            if provisional is not None:
                # the earlier failure (own or foreign) was transient:
                # another operation since measured this configuration —
                # append a recovery trial at the CURRENT position (the
                # failed trial stays untouched; see docstring), at most
                # once per digest
                del self._provisional_failed[rec.config_digest]
            self.tell([Trial(config, value, FOREIGN_ACTION, len(self.trials),
                             feasible=feasible)])
            folded += 1
        return folded

    def evaluate_batch(self, configurations: Sequence,
                       workers: int = 1, executor=None,
                       backend=None) -> List[Optional[float]]:
        """Evaluate a candidate batch and tell the results.

        Accepts :class:`ScoredCandidate` lists (the ``ask`` contract) or
        bare configurations; acquisition scores are forwarded as work-item
        priorities so scheduling backends measure best-first.  Experiments
        fan out over an execution backend (``workers`` threads, a
        caller-owned ``executor`` reused across batches, or any backend
        accepted by ``DiscoverySpace.sample_batch``); trials are appended in
        submission order so the history (and therefore every subsequent
        ``ask``) is deterministic regardless of completion order.  Failed
        measurements become ``action='failed'`` trials with value None.
        """
        configs, priorities = _split_scored(configurations)
        results = self.ds.sample_batch(
            configs, operation_id=self.operation_id, workers=workers,
            executor=executor, backend=backend, priorities=priorities)
        batch = [self._make_trial(result, len(self.trials) + i)
                 for i, result in enumerate(results)]
        self.tell(batch)
        return [t.value for t in batch]

    def evaluate(self, configuration) -> Optional[float]:
        return self.evaluate_batch([configuration])[0]

    def seen_digests(self) -> set:
        return self._history_digests | self.pending

    def signed(self, value: float) -> float:
        """Value in canonical minimization orientation."""
        return value if self.mode == "min" else -value


class Optimizer(abc.ABC):
    """Ask-only optimizer interface (observation happens via history).

    Implementations propose candidate *batches*; they never evaluate.  The
    contract for :meth:`ask`:

    * return up to ``n`` configurations, all distinct and none already in the
      adapter's history (an exhausted finite space returns fewer, possibly
      ``[]`` which stops the run);
    * with ``n=1`` the rng consumption must match the classic one-step
      suggest exactly, so serial trajectories are reproducible;
    * model state must come from ``adapter.trials`` only — pending proposals
      within the batch are accounted for by excluding them from the pool, not
      by mutating shared state (the paper's multi-worker setting: another
      process may append to the store between ask and tell).
    """

    name = "optimizer"

    def __init__(self, seed: int = 0, backend: str = "numpy",
                 max_candidates: int = 512):
        """``backend`` selects the ask-scoring implementation (``numpy`` —
        the reference — or the accelerated ``jax``/``pallas`` paths, see
        :mod:`.accel`); unavailable accelerators degrade to numpy rather
        than raise.  ``max_candidates`` caps the per-ask candidate pool the
        acquisition is scored over (the accelerated backends score the
        whole pool in one device call, so large pools are cheap there)."""
        from .accel import resolve_backend
        self.seed = seed
        self.backend = resolve_backend(backend)
        if max_candidates < 1:
            raise ValueError(
                f"max_candidates must be >= 1, got {max_candidates}")
        self.max_candidates = max_candidates

    @abc.abstractmethod
    def ask(self, adapter: SearchAdapter, rng: np.random.Generator,
            n: int = 1) -> List[ScoredCandidate]:
        """Propose up to ``n`` next candidates ([] => space exhausted).

        Each candidate carries the acquisition score that ranked it (None
        for unscored proposals); drivers forward scores as scheduling
        priorities.  Scoring must never change rng consumption — the n=1
        stream stays draw-for-draw identical to the classic suggest step.
        """

    def suggest(self, adapter: SearchAdapter, rng: np.random.Generator) -> Optional[Configuration]:
        """Single-candidate convenience wrapper over :meth:`ask` — returns
        the bare configuration (the classic suggest contract; the score is
        scheduling metadata with no meaning for a batch of one).  Tolerates
        subclasses whose ``ask`` still returns bare configurations, like
        every other consumer of the ask batch."""
        batch = as_scored(self.ask(adapter, rng, n=1))
        return batch[0].configuration if batch else None

    # -- helpers shared by concrete optimizers ---------------------------------

    @staticmethod
    def _unseen_candidates(adapter: SearchAdapter, rng: np.random.Generator,
                           max_candidates: int = 512,
                           exclude: Optional[set] = None) -> list:
        """Candidate pool: unsampled configurations of a finite space (or
        random draws for continuous spaces).  ``exclude`` removes candidates
        already proposed earlier in the current batch.

        Finite spaces are ALWAYS enumerated and filtered, whatever their
        size: the old ``size <= 4096`` cutoff sent large finite spaces
        through the rejection-sampling loop below, whose try cap made a
        near-exhausted pool (most digests seen, so almost every draw
        rejects) return ``[]`` — falsely reporting exhaustion and stopping
        the run with unsampled configurations still on the table.
        Enumeration finds exactly the unseen remainder; when it exceeds
        ``max_candidates``, a uniform subsample keeps the pool bounded.
        The rejection loop now serves only truly continuous spaces, where
        ``[]`` genuinely cannot mean exhaustion.

        Finite enumeration is served from the adapter's told-invalidated
        cache when it has one (:meth:`SearchAdapter.unseen_pool`): the space
        is walked ONCE per adapter instead of once per ask — at depth d over
        |Ω| that is O(|Ω| + Σ pool) instead of O(d·|Ω|).  Dict insertion
        order preserves enumeration order, so the filtered pool (and the
        subsample drawn from it) is draw-for-draw identical to a fresh
        enumeration.  Adapters without the cache (ask-only stubs, legacy
        wrappers) fall back to enumerating."""
        space = adapter.space
        if space.finite:
            unseen = getattr(adapter, "unseen_pool", None)
            if unseen is not None:
                skip = adapter.pending if not exclude \
                    else adapter.pending | exclude
                pool = [c for d, c in unseen().items() if d not in skip]
            else:
                seen = adapter.seen_digests()
                if exclude:
                    seen = seen | exclude
                pool = [c for c in space.all_configurations()
                        if c.digest not in seen]
            if len(pool) > max_candidates:
                idx = rng.choice(len(pool), size=max_candidates, replace=False)
                pool = [pool[i] for i in idx]
            return pool
        seen = adapter.seen_digests()
        if exclude:
            seen |= exclude
        out, tries = [], 0
        while len(out) < max_candidates and tries < max_candidates * 4:
            c = space.sample_configuration(rng)
            if c.digest not in seen:
                # the draw itself joins `seen`: without this, a continuous
                # space that happens to re-draw the same point (coarse
                # dimensions, near-exhausted pools) returns a pool with
                # duplicates and `ask` can emit a non-distinct batch,
                # breaking its documented contract
                seen.add(c.digest)
                out.append(c)
            tries += 1
        return out

    @staticmethod
    def _history_arrays(adapter: SearchAdapter):
        """(X, y) over successful trials, y in minimization orientation."""
        ok = [t for t in adapter.trials if t.value is not None]
        if not ok:
            return np.zeros((0, len(adapter.space.dimensions))), np.zeros((0,))
        X = np.stack([adapter.space.encode(t.configuration) for t in ok])
        y = np.array([adapter.signed(t.value) for t in ok])
        return X, y

    @staticmethod
    def _constrained(adapter: SearchAdapter) -> bool:
        """True when the adapter's objective carries hard SLA constraints
        (duck-typed: ask-only stubs without an objective are unconstrained)."""
        obj = getattr(adapter, "objective", None)
        return obj is not None and bool(obj.constraints)

    @staticmethod
    def _feasibility_arrays(adapter: SearchAdapter):
        """(X, z) over trials with a KNOWN feasibility verdict, z = ±1.

        Failed trials count (labelled infeasible at tell time under a
        constrained objective); warm predictions carry None and are skipped
        — the feasibility classifier trains on evidence only."""
        labelled = [t for t in adapter.trials if t.feasible is not None]
        if not labelled:
            return (np.zeros((0, len(adapter.space.dimensions))),
                    np.zeros((0,)))
        X = np.stack([adapter.space.encode(t.configuration)
                      for t in labelled])
        z = np.array([1.0 if t.feasible else -1.0 for t in labelled])
        return X, z

    @staticmethod
    def _best_feasible(adapter: SearchAdapter) -> Optional[float]:
        """Best (signed, minimization-oriented) value over trials not known
        to violate a constraint — the incumbent a constrained acquisition
        improves on.  None when no such value exists yet."""
        vals = [adapter.signed(t.value) for t in adapter.trials
                if t.value is not None and t.feasible is not False]
        return min(vals) if vals else None

    @staticmethod
    def _top_n(candidates: list, score: np.ndarray, n: int) -> List[ScoredCandidate]:
        """The n best-scoring candidates (with their acquisition scores), in
        score order.  Stable on ties so ``_top_n(c, s, 1)[0].configuration
        == c[np.argmax(s)]`` exactly."""
        order = np.argsort(-score, kind="stable")
        return [ScoredCandidate(candidates[i], float(score[i]))
                for i in order[:n]]

    @staticmethod
    def _random_n(pool: Sequence[Configuration], rng: np.random.Generator,
                  n: int) -> List[ScoredCandidate]:
        """Up to n unscored draws without replacement, one ``rng.integers``
        call per pick — the shared init-phase sampler, draw-for-draw
        identical to the classic single-suggest draw at n=1."""
        pool = list(pool)
        out: List[ScoredCandidate] = []
        for _ in range(min(n, len(pool))):
            out.append(ScoredCandidate(pool.pop(int(rng.integers(len(pool))))))
        return out


class _StoppingRule:
    """The paper's §V-B1 stopping rule, shared by both engines: halt when the
    incumbent best has not improved for ``patience`` consecutive trials.

    ``count`` supplies the trial count the ``min_trials`` floor is checked
    against; the default — everything in the adapter's history — is right
    for solo runs, but campaign members pass their OWN told-trial count so
    foreign-folded history can never satisfy a floor the caller asked this
    member to reach itself.
    """

    def __init__(self, adapter: SearchAdapter, patience: int, min_trials: int,
                 count: Optional[Callable[[], int]] = None):
        self.adapter = adapter
        self.patience = patience
        self.min_trials = min_trials
        self.count = count if count is not None else (
            lambda: len(adapter.trials))
        self.best: Optional[float] = None
        self.stall = 0
        self.stop = False

    def observe(self, value: Optional[float],
                feasible: Optional[bool] = None) -> None:
        """One trial's outcome.  ``feasible=False`` marks an SLA-violating
        trial: whatever its value, it can never improve the incumbent — the
        rule tracks the best *feasible* value, so a streak of ever-cheaper
        constraint violators still counts as stalling."""
        if value is not None and feasible is not False:
            sv = self.adapter.signed(value)
            if self.best is None or sv < self.best - 1e-12:
                self.best = sv
                self.stall = 0
            else:
                self.stall += 1
        else:
            self.stall += 1
        if self.count() >= self.min_trials and self.stall >= self.patience:
            self.stop = True


def run_optimizer(
    optimizer: Optimizer,
    ds: DiscoverySpace,
    metric: str,
    mode: str = "min",
    max_trials: int = 200,
    patience: int = 5,
    rng: Optional[np.random.Generator] = None,
    min_trials: int = 1,
    batch_size: int = 1,
    workers: int = 1,
    max_inflight: Optional[int] = None,
    backend: Union[ExecutionBackend, str, None] = None,
) -> OptimizerRun:
    """Run one optimization operation on a Discovery Space.

    Thin shim over the declarative engine: builds a one-member
    :class:`~repro.core.api.investigation.Investigation`
    (:meth:`~repro.core.api.investigation.Investigation.from_components`)
    and returns its member's run — trajectories are regression-gated
    draw-for-draw against the pre-shim engines.  Two engine shapes share
    the ask/tell protocol and the stopping rule:

    * **batched** (default): each step asks for a ``batch_size`` candidate
      batch and evaluates it with ``workers`` parallel experiment workers,
      barrier-synchronizing per batch (with the defaults this is the classic
      serial loop, draw-for-draw);
    * **pipelined** (``max_inflight=N``): up to N trials stay outstanding on
      an execution backend (a one-member fleet on the campaign coordinator,
      :func:`repro.core.campaign._drive_fleet`); completed trials are told
      and replaced immediately, so slow experiments never stall the next
      ask.  ``max_inflight=1`` reproduces the serial trajectory
      draw-for-draw.

    ``backend`` routes experiment execution (``serial | thread | process |
    queue`` or an :class:`~repro.core.execution.ExecutionBackend`); None
    keeps thread execution sized to the engine's parallelism.

    Stopping rule follows the paper (§V-B1): halt when the incumbent best has
    not improved for ``patience`` consecutive trials (or after ``max_trials``,
    or when the space is exhausted).  Trials are assessed in tell order, so
    the stopping decision is identical for serial and parallel execution of
    the same proposals.
    """
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    if max_inflight is not None and max_inflight < 1:
        raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
    from ..api.investigation import Investigation  # local: avoid cycle

    inv = Investigation.from_components(
        ds, [optimizer], metric, mode=mode,
        rngs=[rng if rng is not None
              else np.random.default_rng(optimizer.seed)],
        max_trials=max_trials, patience=patience, min_trials=min_trials,
        batch_size=batch_size, workers=workers, max_inflight=max_inflight,
        backend=backend)
    return inv.run().members[0].run


def hypergeom_p_found(space_size: int, target_count: int, n_draws: int) -> float:
    """P(≥1 target configuration after n draws without replacement).

    The paper's random-walk baseline (§V-B1) 'analytically described by the
    hypergeometric distribution':  1 - C(N-K, n) / C(N, n).
    """
    n_draws = min(n_draws, space_size)
    log_p_none = 0.0
    for i in range(n_draws):
        good_left = space_size - target_count - i
        total_left = space_size - i
        if good_left <= 0:
            return 1.0
        log_p_none += math.log(good_left) - math.log(total_left)
    return 1.0 - math.exp(log_p_none)
