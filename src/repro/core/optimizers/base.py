"""Optimizer base classes + the Discovery Space compatibility wrapper.

Mirrors the paper's design (§III-D): optimization algorithms are decoupled
from workload experiments — they only see the ``sample`` method of a
Discovery Space through :class:`SearchAdapter`.  The adapter also implements
the paper's stopping rule (§V-B1: stop when the incumbent has not improved
for five consecutive trials) and reports, per trial, whether the sample was
*measured* or transparently *reused* from the common context — the raw data
behind the paper's Fig. 7 incremental-sampling evaluation.
"""

from __future__ import annotations

import abc
import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from ..actions import MeasurementError
from ..discovery import DiscoverySpace
from ..entities import Configuration

__all__ = ["Trial", "OptimizerRun", "SearchAdapter", "Optimizer", "run_optimizer",
           "hypergeom_p_found"]


@dataclass
class Trial:
    configuration: Configuration
    value: Optional[float]  # objective value (None => non-deployable)
    action: str             # 'measured' | 'reused' | 'predicted' | 'failed'
    seq: int


@dataclass
class OptimizerRun:
    optimizer: str
    metric: str
    mode: str
    trials: list = field(default_factory=list)
    operation_id: str = ""

    @property
    def num_trials(self) -> int:
        return len(self.trials)

    @property
    def num_measured(self) -> int:
        return sum(1 for t in self.trials if t.action == "measured")

    @property
    def num_reused(self) -> int:
        return sum(1 for t in self.trials if t.action in ("reused", "predicted"))

    @property
    def best(self) -> Optional[Trial]:
        vals = [t for t in self.trials if t.value is not None]
        if not vals:
            return None
        key = (lambda t: t.value) if self.mode == "min" else (lambda t: -t.value)
        return min(vals, key=key)

    @property
    def normalized_cost(self) -> float:
        """Paper §V-B1: new measurements / total samples."""
        if not self.trials:
            return 0.0
        return self.num_measured / len(self.trials)

    def best_value_by_step(self) -> list:
        out, best = [], None
        sign = 1.0 if self.mode == "min" else -1.0
        for t in self.trials:
            if t.value is not None:
                v = sign * t.value
                best = v if best is None else min(best, v)
            out.append(None if best is None else sign * best)
        return out


class SearchAdapter:
    """The 'Ray Tune wrapper' of §III-D: optimizer-facing view of a study.

    Optimizers call :meth:`evaluate` with a configuration; the adapter routes
    it through ``DiscoverySpace.sample`` (so all TRACE bookkeeping happens),
    extracts the target metric, and translates minimize/maximize.
    """

    def __init__(self, ds: DiscoverySpace, metric: str, mode: str = "min",
                 operation_id: Optional[str] = None, optimizer_name: str = "opt"):
        if mode not in ("min", "max"):
            raise ValueError(f"mode must be min|max, got {mode}")
        self.ds = ds
        self.metric = metric
        self.mode = mode
        self.operation_id = operation_id or ds.begin_operation(
            "optimization", {"optimizer": optimizer_name, "metric": metric, "mode": mode}
        )
        self.trials: list = []

    @property
    def space(self):
        return self.ds.space

    def evaluate(self, configuration: Configuration) -> Optional[float]:
        try:
            sample = self.ds.sample(configuration, operation_id=self.operation_id)
        except MeasurementError:
            self.trials.append(Trial(configuration, None, "failed", len(self.trials)))
            return None
        record = self.ds.timeseries(self.operation_id)[-1]
        if not sample.has(self.metric):
            raise KeyError(
                f"metric {self.metric!r} not among action-space properties "
                f"{self.ds.actions.observed_properties}"
            )
        value = sample.value(self.metric)
        self.trials.append(Trial(configuration, value, record.action, len(self.trials)))
        return value

    def seen_digests(self) -> set:
        return {t.configuration.digest for t in self.trials}

    def signed(self, value: float) -> float:
        """Value in canonical minimization orientation."""
        return value if self.mode == "min" else -value


class Optimizer(abc.ABC):
    """Suggest-only optimizer interface (observation happens via history)."""

    name = "optimizer"

    def __init__(self, seed: int = 0):
        self.seed = seed

    @abc.abstractmethod
    def suggest(self, adapter: SearchAdapter, rng: np.random.Generator) -> Optional[Configuration]:
        """Propose the next configuration (None => space exhausted)."""

    # -- helpers shared by concrete optimizers ---------------------------------

    @staticmethod
    def _unseen_candidates(adapter: SearchAdapter, rng: np.random.Generator,
                           max_candidates: int = 512) -> list:
        """Candidate pool: unsampled configurations of a finite space (or
        random draws for continuous spaces)."""
        space = adapter.space
        seen = adapter.seen_digests()
        if space.finite and space.size <= 4096:
            pool = [c for c in space.all_configurations() if c.digest not in seen]
            if len(pool) > max_candidates:
                idx = rng.choice(len(pool), size=max_candidates, replace=False)
                pool = [pool[i] for i in idx]
            return pool
        out, tries = [], 0
        while len(out) < max_candidates and tries < max_candidates * 4:
            c = space.sample_configuration(rng)
            if c.digest not in seen:
                out.append(c)
            tries += 1
        return out

    @staticmethod
    def _history_arrays(adapter: SearchAdapter):
        """(X, y) over successful trials, y in minimization orientation."""
        ok = [t for t in adapter.trials if t.value is not None]
        if not ok:
            return np.zeros((0, len(adapter.space.dimensions))), np.zeros((0,))
        X = np.stack([adapter.space.encode(t.configuration) for t in ok])
        y = np.array([adapter.signed(t.value) for t in ok])
        return X, y


def run_optimizer(
    optimizer: Optimizer,
    ds: DiscoverySpace,
    metric: str,
    mode: str = "min",
    max_trials: int = 200,
    patience: int = 5,
    rng: Optional[np.random.Generator] = None,
    min_trials: int = 1,
) -> OptimizerRun:
    """Run one optimization operation on a Discovery Space.

    Stopping rule follows the paper (§V-B1): halt when the incumbent best has
    not improved for ``patience`` consecutive trials (or after ``max_trials``,
    or when a finite space is exhausted).
    """
    rng = rng if rng is not None else np.random.default_rng(optimizer.seed)
    adapter = SearchAdapter(ds, metric, mode, optimizer_name=optimizer.name)
    best: Optional[float] = None
    stall = 0
    while len(adapter.trials) < max_trials:
        config = optimizer.suggest(adapter, rng)
        if config is None:
            break
        value = adapter.evaluate(config)
        if value is not None:
            sv = adapter.signed(value)
            if best is None or sv < best - 1e-12:
                best = sv
                stall = 0
            else:
                stall += 1
        else:
            stall += 1
        if len(adapter.trials) >= min_trials and stall >= patience:
            break
    return OptimizerRun(
        optimizer=optimizer.name,
        metric=metric,
        mode=mode,
        trials=adapter.trials,
        operation_id=adapter.operation_id,
    )


def hypergeom_p_found(space_size: int, target_count: int, n_draws: int) -> float:
    """P(≥1 target configuration after n draws without replacement).

    The paper's random-walk baseline (§V-B1) 'analytically described by the
    hypergeometric distribution':  1 - C(N-K, n) / C(N, n).
    """
    n_draws = min(n_draws, space_size)
    log_p_none = 0.0
    for i in range(n_draws):
        good_left = space_size - target_count - i
        total_left = space_size - i
        if good_left <= 0:
            return 1.0
        log_p_none += math.log(good_left) - math.log(total_left)
    return 1.0 - math.exp(log_p_none)
