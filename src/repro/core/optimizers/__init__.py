"""Optimizer suite driving Discovery Spaces (paper §III-D, §V-B1).

The paper ran skopt-BO, Ax, and BOHB through a Ray-Tune compatibility
wrapper.  Those libraries are not available offline, so this package
implements the same three optimizer *families* from scratch on numpy/scipy —
GP-based Bayesian optimization (≈ skopt), TPE (the model family Optuna/Ax
style samplers draw from), and BOHB (TPE + successive halving) — plus the
random-walk baseline whose behaviour is analytically the hypergeometric
distribution (paper §V-B1).

All optimizers interact with a study exclusively through
:class:`~repro.core.optimizers.base.SearchAdapter` — the analogue of the
paper's Ray Tune wrapper — via the batched ask/tell protocol: ``ask(n)``
proposes a candidate batch over (Ω, P), the adapter evaluates it through
``DiscoverySpace.sample_batch`` (fanning experiments over a worker pool) and
tells the trials back.  Optimizers never touch experiments directly, which
is what makes the framework workload-agnostic and lets multiple optimizers —
in one process or many — share one sample store (§III-D).

Cooperative campaigns (paper §V): :class:`~repro.core.campaign.Campaign`
runs several of these optimizers concurrently over one Discovery Space,
folding every member's completed measurements into every other member's
history before each ask (``SearchAdapter.sync_foreign``, an incremental
watermark read of the shared sampling record) — each model trains on the
union of the fleet's data while rng streams, operations, and stopping rules
stay per-member, so solo trajectories are untouched.

Accelerated ask backends
------------------------

Campaign warm-starts (PR 5) fold thousands of trials into every member's
history, which put BO-GP/TPE ask-latency — O(|H|³) Cholesky plus
per-candidate scoring — on the critical path.  Every model-based optimizer
therefore takes a ``backend`` constructor argument (threaded through spec
JSON as ``OptimizerSpec.backend``):

* ``"numpy"`` (default) — the reference implementation;
* ``"jax"`` — the GP posterior + batched analytic EI, and TPE's
  per-dimension Parzen densities, each fused into one jitted device call
  over the *entire* candidate pool (shape-bucketed so a growing history
  reuses O(log |H|) compiled programs);
* ``"pallas"`` — the jax path with the pairwise-distance/RBF Gram matrices
  built by a blocked pallas kernel (:mod:`.accel.pallas_rbf`), for the
  large-history regime where the Gram build dominates; degrades to
  ``"jax"`` where pallas is unavailable (and any accelerated choice
  degrades to ``"numpy"`` without jax — a spec never fails to run).

Parity guarantee: accelerated backends consume the identical rng stream
(scoring is rng-free) and are regression-gated **draw-for-draw** against
the numpy path in ``tests/test_accel_parity.py`` — same candidate pools,
argmax-identical proposals per family across seeds, history sizes, and
categorical/continuous spaces, at float32 tolerances.

``benchmarks/ask_bench.py`` measures ask latency vs history length × pool
size and writes ``BENCH_ask.json``: per family, one row per
(history, pool, backend) with median milliseconds (``ms``) and first-call
latency including jit compile (``first_ms``); ``gate`` records the CI soft
regression gate — jitted ask at |H|=2048 must not be slower than numpy —
and ``speedup`` is numpy-ms / backend-ms at each grid point (compile time
excluded: campaigns amortize it across every subsequent ask).
"""

from . import accel
from .base import (FOREIGN_ACTION, OptimizerRun, ScoredCandidate,
                   SearchAdapter, Trial, as_scored, run_optimizer,
                   hypergeom_p_found)
from .random_search import RandomSearch
from .bo_gp import GPBayesOpt
from .tpe import TPE
from .bohb import BOHB

OPTIMIZER_REGISTRY = {
    "random": RandomSearch,
    "bo-gp": GPBayesOpt,
    "tpe": TPE,
    "bohb": BOHB,
}

__all__ = [
    "OptimizerRun",
    "ScoredCandidate",
    "SearchAdapter",
    "Trial",
    "run_optimizer",
    "hypergeom_p_found",
    "as_scored",
    "FOREIGN_ACTION",
    "RandomSearch",
    "GPBayesOpt",
    "TPE",
    "BOHB",
    "OPTIMIZER_REGISTRY",
    "accel",
]
