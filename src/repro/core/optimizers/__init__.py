"""Optimizer suite driving Discovery Spaces (paper §III-D, §V-B1).

The paper ran skopt-BO, Ax, and BOHB through a Ray-Tune compatibility
wrapper.  Those libraries are not available offline, so this package
implements the same three optimizer *families* from scratch on numpy/scipy —
GP-based Bayesian optimization (≈ skopt), TPE (the model family Optuna/Ax
style samplers draw from), and BOHB (TPE + successive halving) — plus the
random-walk baseline whose behaviour is analytically the hypergeometric
distribution (paper §V-B1).

All optimizers interact with a study exclusively through
:class:`~repro.core.optimizers.base.SearchAdapter` — the analogue of the
paper's Ray Tune wrapper — via the batched ask/tell protocol: ``ask(n)``
proposes a candidate batch over (Ω, P), the adapter evaluates it through
``DiscoverySpace.sample_batch`` (fanning experiments over a worker pool) and
tells the trials back.  Optimizers never touch experiments directly, which
is what makes the framework workload-agnostic and lets multiple optimizers —
in one process or many — share one sample store (§III-D).

Cooperative campaigns (paper §V): :class:`~repro.core.campaign.Campaign`
runs several of these optimizers concurrently over one Discovery Space,
folding every member's completed measurements into every other member's
history before each ask (``SearchAdapter.sync_foreign``, an incremental
watermark read of the shared sampling record) — each model trains on the
union of the fleet's data while rng streams, operations, and stopping rules
stay per-member, so solo trajectories are untouched.
"""

from .base import (FOREIGN_ACTION, OptimizerRun, ScoredCandidate,
                   SearchAdapter, Trial, as_scored, run_optimizer,
                   hypergeom_p_found)
from .random_search import RandomSearch
from .bo_gp import GPBayesOpt
from .tpe import TPE
from .bohb import BOHB

OPTIMIZER_REGISTRY = {
    "random": RandomSearch,
    "bo-gp": GPBayesOpt,
    "tpe": TPE,
    "bohb": BOHB,
}

__all__ = [
    "OptimizerRun",
    "ScoredCandidate",
    "SearchAdapter",
    "Trial",
    "run_optimizer",
    "hypergeom_p_found",
    "as_scored",
    "FOREIGN_ACTION",
    "RandomSearch",
    "GPBayesOpt",
    "TPE",
    "BOHB",
    "OPTIMIZER_REGISTRY",
]
