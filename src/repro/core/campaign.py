"""Cooperative multi-optimizer campaigns over one shared store (paper §V).

The paper's first headline claim is "safe, transparent sharing of data
between executions of best-of-breed optimizers increasing the efficiency of
optimal configuration detection".  No single optimizer family wins across
workloads (Lazuka et al. 2022), and reusing other investigators'
measurements slashes search cost (Scout, Hsu et al. 2018) — so instead of
picking one optimizer, a :class:`Campaign` runs N heterogeneous optimizers
*concurrently* over one :class:`~repro.core.discovery.DiscoverySpace` and
lets every participant train on the union of the fleet's history:

* each member keeps its own operation (its own sampling record, its own
  rng, its own stopping rule) — runs stay attributable and individually
  reproducible;
* every completed measurement — no matter which member asked for it — is
  told to *all* members: before each ask, a member folds the other
  operations' new sampling events into its history via
  :meth:`~repro.core.optimizers.base.SearchAdapter.sync_foreign`, an
  incremental, watermark-paged read of the shared record
  (:meth:`~repro.core.store.SampleStore.records_since`, O(new rows) per
  sync).  Because the sync goes through the store, members may equally live
  in different processes sharing the database file;
* all members submit through ONE execution backend, so a campaign shares a
  single worker fleet: acquisition scores ride
  :class:`~repro.core.execution.WorkItem` priorities into the scheduler
  exactly as they do for a solo run, and the store's measurement-claim
  arbitration guarantees a configuration proposed by two members
  concurrently is still measured exactly once (the second tell lands as a
  transparent ``reused``).

Determinism guarantees
----------------------

Sharing is strictly additive: a member's rng stream is consumed only by its
own asks, and ``sync_foreign`` never touches the rng.  A single-member
campaign (nothing foreign to fold) reproduces
``run_optimizer(max_inflight=1)`` — and therefore the classic serial loop —
draw-for-draw; this is regression-gated per optimizer family in
``tests/test_campaign.py``.  With multiple members the *interleaving* of
foreign tells depends on completion order (as in any pipelined run), but
every value a member trains on comes from the store's reconciled sample
set, so histories never diverge from the durable data.

Reproducing the §V sharing-efficiency result: ``python -m
benchmarks.campaign_bench`` measures time-to-best-cost for a shared-history
campaign vs the same optimizers isolated, writing ``BENCH_sharing.json``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Union

import numpy as np

from .discovery import DiscoverySpace
from .execution import ExecutionBackend, WorkItem
from .optimizers.base import (FOREIGN_ACTION, WARM_ACTION, Optimizer,
                              OptimizerRun, SearchAdapter, Trial,
                              _StoppingRule, as_scored)

__all__ = ["Campaign", "CampaignResult", "MemberResult", "run_campaign"]


@dataclass
class MemberResult:
    """One member's view of a finished campaign/investigation."""

    optimizer: str
    operation_id: str
    run: OptimizerRun          # own trials only (what this member asked for)
    foreign_trials: int        # fleet history folded into its model
    history_size: int          # own + foreign + warm: what the model fit saw
    warm_trials: int = 0       # cross-space transfer trials folded pre-run

    @property
    def best(self) -> Optional[Trial]:
        return self.run.best


@dataclass
class CampaignResult:
    """Fleet-level outcome: per-member results + the global tell order."""

    metric: str
    mode: str
    members: List[MemberResult]
    #: ``(member_label, Trial)`` in fleet-wide tell (completion) order —
    #: the trace the sharing-efficiency bench computes time-to-best on.
    events: list = field(default_factory=list)

    @property
    def num_measured(self) -> int:
        return sum(1 for _, t in self.events if t.action == "measured")

    @property
    def num_trials(self) -> int:
        return len(self.events)

    @property
    def best(self) -> Optional[Trial]:
        sign = 1.0 if self.mode == "min" else -1.0
        valued = [t for _, t in self.events if t.value is not None]
        if not valued:
            return None
        return min(valued, key=lambda t: sign * t.value)

    def measurements_to_best(self) -> Optional[int]:
        """Measured experiments spent until the final best value first
        appeared (1-based) — the fleet's time-to-best-cost."""
        best = self.best
        if best is None:
            return None
        measured = 0
        for _, t in self.events:
            if t.action == "measured":
                measured += 1
            if t.value is not None and t.value == best.value:
                return measured
        return measured  # pragma: no cover - best always appears in events


class _Member:
    """Per-optimizer fleet state: one asker on the shared coordinator loop.

    Also the unit a solo pipelined run
    (``run_optimizer(max_inflight=N)`` via
    :class:`~repro.core.api.investigation.Investigation`) wraps itself in —
    the caller supplies a ready adapter/rule/rng, so the solo engine and
    the campaign share one state machine (and one set of
    submit/tell/crash-drain semantics) by construction.
    """

    def __init__(self, label: str, optimizer: Optimizer,
                 adapter: SearchAdapter, rng: np.random.Generator,
                 rule: _StoppingRule, max_inflight: int):
        self.label = label
        self.optimizer = optimizer
        self.adapter = adapter
        self.rng = rng
        self.rule = rule
        self.max_inflight = max_inflight
        self.inflight = 0          # this member's outstanding work items
        self.own_told = 0          # trials this member asked for and got back
        self.exhausted = False
        self.foreign_told = 0

    def wants_more(self, max_trials: int) -> bool:
        return (not self.rule.stop and not self.exhausted
                and self.inflight < self.max_inflight
                and self.own_told + self.inflight < max_trials)

    def own_trials(self) -> list:
        """Trials this member asked for itself — the foreign-folded fleet
        history and warm-start transfer trials live only in the adapter."""
        return [t for t in self.adapter.trials
                if t.action not in (FOREIGN_ACTION, WARM_ACTION)]


class _RunState:
    """Mutable coordinator-loop state shared with :func:`_absorb`."""

    def __init__(self):
        self.inflight: dict = {}   # tag -> (member, configuration, digest)
        self.events: list = []     # (member_label, Trial) in tell order
        self.tag = 0
        self.crash: Optional[BaseException] = None


def _absorb(ds: DiscoverySpace, completed, state: _RunState) -> bool:
    """Tell a batch of backend completions into their members' histories
    (record under the asking member's operation, observe its stopping rule,
    append to the fleet event trace).  Returns True if anything landed."""
    for res in completed:
        member, config, digest = state.inflight.pop(res.item.tag)
        member.inflight -= 1
        member.adapter.pending.discard(digest)
        if res.action == "crashed":
            state.crash = state.crash if state.crash is not None else res.error
            continue
        result = ds.record_result(config, digest, res.action, res.error,
                                  member.adapter.operation_id)
        trial = member.adapter.tell_result(result)
        member.own_told += 1
        member.rule.observe(trial.value, trial.feasible)
        state.events.append((member.label, trial))
    return bool(completed)


def _drive_fleet(ds: DiscoverySpace, members: Sequence[_Member],
                 max_trials: int, share_history: bool,
                 backend: Union[ExecutionBackend, str, None]) -> _RunState:
    """THE coordinator state machine: N askers multiplexed over one backend.

    A solo pipelined ``run_optimizer(max_inflight=N)`` — routed through
    :class:`~repro.core.api.investigation.Investigation` — is this loop
    with a single member and ``share_history=False`` (``max_inflight=1``
    then reproduces the serial trajectory draw-for-draw — regression-gated
    per optimizer); :meth:`Campaign.run` is the same loop with N members
    and foreign-tell syncs.  One implementation means one set of
    submit/tell/crash-drain semantics to maintain.

    Round-robin, one submission per member per pass — each member with
    in-flight headroom syncs foreign history (campaigns only), asks once,
    and submits; completions are drained *between* submissions, so with a
    synchronous backend every ask trains on every measurement the fleet
    has finished (full-information sharing, the §V measurement-efficiency
    setting), while concurrent backends pipeline naturally with at most
    ``max_inflight`` staleness per member.  A crash surfaced by an
    in-process backend stops new submissions fleet-wide, drains what is in
    flight (those measurements are paid for and durable), and is returned
    on the state for the caller to raise.
    """
    total_inflight = sum(m.max_inflight for m in members)
    owned = not isinstance(backend, ExecutionBackend)
    engine = ds.execution_backend(backend, workers=total_inflight)
    state = _RunState()
    pause = 0.0005
    try:
        while True:
            submitted = False
            if state.crash is None:
                for member in members:
                    if state.crash is not None:
                        # a completion absorbed mid-pass surfaced a crash:
                        # stop submitting immediately — the remaining
                        # members must not start new paid measurements
                        break
                    if not member.wants_more(max_trials):
                        continue
                    if share_history:
                        member.foreign_told += member.adapter.sync_foreign()
                    batch = as_scored(member.optimizer.ask(
                        member.adapter, member.rng, n=1))
                    if not batch:
                        member.exhausted = True
                        continue
                    cand = batch[0]
                    digest = ds.store.put_configuration(cand.configuration)
                    member.adapter.pending.add(digest)
                    engine.submit(WorkItem(
                        cand.configuration, digest, state.tag,
                        priority=(0.0 if cand.score is None
                                  else float(cand.score))))
                    state.inflight[state.tag] = (
                        member, cand.configuration, digest)
                    member.inflight += 1
                    state.tag += 1
                    submitted = True
                    # drain anything already finished before the next
                    # member's ask: synchronous backends hand every ask
                    # the complete fleet history
                    if _absorb(ds, engine.poll(), state):
                        pause = 0.0005
            if not state.inflight and not submitted:
                break
            if _absorb(ds, engine.poll(), state) or submitted:
                pause = 0.0005
                continue
            ds._maybe_sweep_claims()
            time.sleep(pause)
            pause = min(pause * 2, 0.005)
    finally:
        if owned:
            engine.close()
    return state


class Campaign:
    """Run N heterogeneous optimizers cooperatively over one Discovery Space.

    ``optimizers`` are the campaign members (any mix of families; the same
    family twice with different seeds is fine — labels are made unique).
    Each member runs the pipelined ask/tell protocol with its own operation,
    rng, and stopping rule (§V-B1: ``patience`` trials without improvement),
    up to ``max_trials`` *own* trials per member; all members share one
    execution backend resolved from ``backend`` (a name, an instance, or
    None for the default), sized to the fleet's total in-flight budget.

    ``share_history=True`` (the cooperative mode) folds every other
    operation's completed measurements into each member's history before
    each ask; ``False`` runs the same fleet with isolated models — members
    then interact only through the store's transparent measure-once reuse,
    which is the paper's baseline sharing level.  ``warm_start=True``
    additionally folds sampling events that were already in the store
    *before* the campaign began (cross-campaign reuse, paper Fig. 7).

    ``rngs`` fixes per-member randomness (defaults derive from each
    optimizer's own seed, matching ``run_optimizer``'s default).
    """

    def __init__(
        self,
        ds: DiscoverySpace,
        optimizers: Sequence[Optimizer],
        metric: str,
        mode: str = "min",
        max_trials: int = 50,
        patience: int = 5,
        min_trials: int = 1,
        max_inflight: int = 1,
        share_history: bool = True,
        warm_start: bool = False,
        backend: Union[ExecutionBackend, str, None] = None,
        rngs: Optional[Sequence[np.random.Generator]] = None,
    ):
        if not optimizers:
            raise ValueError("a campaign needs at least one optimizer")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if rngs is not None and len(rngs) != len(optimizers):
            raise ValueError(f"rngs must match optimizers: "
                             f"{len(rngs)} != {len(optimizers)}")
        self.ds = ds
        self.metric = metric
        self.mode = mode
        self.max_trials = max_trials
        self.share_history = share_history
        self.backend = backend
        counts: dict = {}
        self.members: List[_Member] = []
        for i, opt in enumerate(optimizers):
            n = counts.get(opt.name, 0)
            counts[opt.name] = n + 1
            label = opt.name if n == 0 else f"{opt.name}#{n + 1}"
            rng = (rngs[i] if rngs is not None
                   else np.random.default_rng(opt.seed))
            adapter = SearchAdapter(ds, metric, mode, optimizer_name=label)
            member = _Member(label, opt, adapter, rng, None, max_inflight)
            # min_trials floors this member's OWN trial count: foreign-
            # folded history must never satisfy a floor the caller asked of
            # this member
            member.rule = _StoppingRule(adapter, patience, min_trials,
                                        count=(lambda m=member: m.own_told))
            self.members.append(member)
        if not warm_start:
            # start the sync watermark at the current record tail: members
            # share what the fleet produces, not pre-campaign history
            watermark = ds.store.last_record_rowid(ds.space_id)
            for m in self.members:
                m.adapter.record_watermark = watermark

    # ------------------------------------------------------------------ run

    def run(self) -> CampaignResult:
        """Drive the fleet to completion and return the campaign result.

        Thin shim over the declarative engine: hands the prebuilt members
        to an :class:`~repro.core.api.investigation.Investigation`
        (:meth:`~repro.core.api.investigation.Investigation.for_members`),
        which runs :func:`_drive_fleet` — the coordinator state machine
        shared with the solo pipelined engine — with foreign-tell syncing
        per ``share_history`` and a final fold so every member's reported
        history covers the fleet's last completions.  A crash surfaced by
        an in-process backend propagates after the surviving in-flight
        trials drain, exactly the solo pipelined contract.  Trajectories
        are regression-gated draw-for-draw against the pre-shim engine.
        """
        from .api.investigation import Investigation  # local: avoid cycle

        inv = Investigation.for_members(
            self.ds, self.members, self.metric, self.mode, self.max_trials,
            share_history=self.share_history, backend=self.backend)
        res = inv.run()
        return CampaignResult(
            metric=self.metric,
            mode=self.mode,
            members=res.members,
            events=res.events,
        )


def run_campaign(ds: DiscoverySpace, optimizers: Sequence[Optimizer],
                 metric: str, **kwargs) -> CampaignResult:
    """Convenience wrapper: build a :class:`Campaign` and :meth:`~Campaign.run` it."""
    return Campaign(ds, optimizers, metric, **kwargs).run()
