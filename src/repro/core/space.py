"""Probability space ``(P, Ω)`` over configuration dimensions (paper §III-B1).

Ω is the cartesian product of the dimensions' value sets; P is the product of
per-dimension priors (uniform by default).  The event space F is the
elementary event set (single configurations) and is omitted, as in the paper.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Iterator, Mapping, Optional, Sequence

import numpy as np

from .entities import Configuration, Dimension, content_hash

__all__ = ["ProbabilitySpace"]


@dataclass(frozen=True)
class ProbabilitySpace:
    """The scope + selection criteria of a configuration search study."""

    dimensions: tuple

    def __post_init__(self):
        names = [d.name for d in self.dimensions]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate dimension names: {names}")

    @staticmethod
    def make(dims: Sequence[Dimension]) -> "ProbabilitySpace":
        return ProbabilitySpace(dimensions=tuple(dims))

    # -- structure -----------------------------------------------------------

    @property
    def names(self) -> tuple:
        return tuple(d.name for d in self.dimensions)

    def dimension(self, name: str) -> Dimension:
        for d in self.dimensions:
            if d.name == name:
                return d
        raise KeyError(name)

    @property
    def finite(self) -> bool:
        return all(d.finite for d in self.dimensions)

    @property
    def size(self) -> int:
        """|Ω| for finite spaces."""
        if not self.finite:
            raise ValueError("space has continuous dimensions")
        n = 1
        for d in self.dimensions:
            n *= d.cardinality
        return n

    @property
    def digest(self) -> str:
        return content_hash([d.to_json() for d in self.dimensions])

    # -- membership (the Encapsulated characteristic needs this) -------------

    def contains(self, config: Configuration) -> bool:
        d = config.as_dict()
        if set(d) != set(self.names):
            return False
        return all(self.dimension(k).contains(v) for k, v in d.items())

    def validate(self, config: Configuration) -> None:
        if not self.contains(config):
            raise ValueError(
                f"configuration {config!r} is not an element of this space "
                f"(dimensions: {self.names})"
            )

    # -- enumeration & sampling ----------------------------------------------

    def all_configurations(self) -> Iterator[Configuration]:
        if not self.finite:
            raise ValueError("cannot enumerate a continuous space")
        value_sets = [d.values for d in self.dimensions]
        for combo in itertools.product(*value_sets):
            yield Configuration.make(dict(zip(self.names, combo)))

    def sample_configuration(self, rng: np.random.Generator) -> Configuration:
        """Draw one configuration according to P (per-dimension priors)."""
        values = {}
        for d in self.dimensions:
            if d.kind == "continuous":
                values[d.name] = float(rng.uniform(d.low, d.high))
            else:
                p = None
                if d.prior:
                    p = np.asarray(d.prior, dtype=float)
                    p = p / p.sum()
                idx = rng.choice(len(d.values), p=p)
                values[d.name] = d.values[int(idx)]
        return Configuration.make(values)

    def sample_configurations(self, rng: np.random.Generator,
                              n: int) -> list:
        """Up to ``n`` *distinct* configurations drawn according to P.

        A finite space with ``size <= n`` enumerates exhaustively (rng
        shuffles the order, so the draw is still P-flavored downstream);
        otherwise rejection-sample digests until ``n`` distinct ones land.
        Used by trace capture, where re-measuring a digest would only
        overwrite the same trace trial.
        """
        if n < 1:
            return []
        if self.finite and self.size <= n:
            configs = list(self.all_configurations())
            rng.shuffle(configs)
            return configs
        seen: set = set()
        out: list = []
        budget = max(1000, 50 * n)  # tiny prior-mass tails must not spin
        while len(out) < n and budget > 0:
            budget -= 1
            c = self.sample_configuration(rng)
            if c.digest not in seen:
                seen.add(c.digest)
                out.append(c)
        return out

    # -- vector encoding for optimizers ---------------------------------------

    def encode(self, config: Configuration) -> np.ndarray:
        """Configuration -> unit-cube vector (one coordinate per dimension)."""
        return np.array([d.to_unit(config[d.name]) for d in self.dimensions])

    def decode(self, vec: np.ndarray) -> Configuration:
        values = {d.name: d.from_unit(u) for d, u in zip(self.dimensions, vec)}
        return Configuration.make(values)

    # -- derived spaces --------------------------------------------------------

    def map_values(self, mapping: Mapping[str, Mapping[Any, Any]]) -> "ProbabilitySpace":
        """Build a related space by substituting values on named dimensions.

        This is the paper's §IV-1 configuration-parameter mapping: e.g.
        ``{"gpu_model": {"A100-PCIE": "A100-SXM4"}}`` builds the target space
        A* from A.  Dimensions not named are copied unchanged.
        """
        new_dims = []
        for d in self.dimensions:
            if d.name in mapping and d.finite:
                m = mapping[d.name]
                new_vals = tuple(m.get(v, v) for v in d.values)
                new_dims.append(
                    Dimension(name=d.name, kind=d.kind, values=new_vals, prior=d.prior,
                              low=d.low, high=d.high)
                )
            else:
                new_dims.append(d)
        return ProbabilitySpace(dimensions=tuple(new_dims))

    def translate(self, config: Configuration,
                  mapping: Mapping[str, Mapping[Any, Any]]) -> Configuration:
        """Translate a configuration of this space through a value mapping."""
        d = config.as_dict()
        out = {}
        for k, v in d.items():
            m = mapping.get(k, {})
            out[k] = m.get(v, v)
        return Configuration.make(out)

    # -- serialization ---------------------------------------------------------

    def to_json(self) -> dict:
        return {"dimensions": [d.to_json() for d in self.dimensions]}

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "ProbabilitySpace":
        return ProbabilitySpace(
            dimensions=tuple(Dimension.from_json(x) for x in d["dimensions"])
        )
