"""Core entities of the Discovery Space data model.

The paper (§III-B) defines a Discovery Space as ``D = (P, Ω) ⊗ A`` where
``(P, Ω)`` is a probability space over configuration dimensions and ``A`` is
an *Action space* of experiments.  The entities here are the vocabulary that
definition is written in:

* :class:`Dimension` — one axis of the sample space Ω (categorical, discrete
  numeric, or continuous), optionally with a non-uniform prior (the measure P).
* :class:`Configuration` — one element of Ω: an immutable, hash-identified
  assignment of a value to every dimension.  The content hash is the identity
  used by the common-context store, so the *same* configuration sampled by two
  different studies reconciles to one row (paper Fig. 4).
* :class:`PropertyValue` — a measured (or predicted) value for one property of
  a configuration, carrying provenance: which experiment produced it and when.
* :class:`Sample` — a configuration together with all property values known
  for it under a given action space: one element of ``D``.
"""

from __future__ import annotations

import hashlib
import json
import math
import time
from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping, Sequence

__all__ = [
    "Dimension",
    "Configuration",
    "PropertyValue",
    "Sample",
    "canonical_json",
    "content_hash",
]


def canonical_json(obj: Any) -> str:
    """Deterministic JSON encoding used for all content hashing."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"), default=_json_default)


def _json_default(obj: Any):
    # numpy scalars and similar sneak in from optimizers; normalize them.
    if hasattr(obj, "item"):
        return obj.item()
    raise TypeError(f"not JSON serializable: {type(obj)!r}")


def content_hash(obj: Any) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()[:32]


# ---------------------------------------------------------------------------
# Dimensions (the axes of Ω, with optional prior P per-dimension)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Dimension:
    """One dimension of a configuration sample space.

    ``kind``:
      * ``"categorical"`` — unordered finite set of values (strings or tuples).
      * ``"discrete"``    — ordered finite set of numeric values.
      * ``"continuous"``  — interval ``[low, high]``.

    ``prior`` — optional per-value weights (finite kinds only); uniform when
    omitted.  This is the per-dimension factor of the probability measure P.
    """

    name: str
    kind: str
    values: tuple = ()
    low: float = 0.0
    high: float = 1.0
    prior: tuple = ()

    def __post_init__(self):
        if self.kind not in ("categorical", "discrete", "continuous"):
            raise ValueError(f"unknown dimension kind {self.kind!r}")
        if self.kind in ("categorical", "discrete"):
            if not self.values:
                raise ValueError(f"dimension {self.name!r}: finite kinds need values")
            if self.prior and len(self.prior) != len(self.values):
                raise ValueError(f"dimension {self.name!r}: prior/value length mismatch")
            if self.kind == "discrete":
                vals = list(self.values)
                if any(not isinstance(v, (int, float)) for v in vals):
                    raise ValueError(f"dimension {self.name!r}: discrete values must be numeric")
                if vals != sorted(vals):
                    raise ValueError(f"dimension {self.name!r}: discrete values must be sorted")
        else:
            if not (math.isfinite(self.low) and math.isfinite(self.high) and self.low < self.high):
                raise ValueError(f"dimension {self.name!r}: bad interval [{self.low},{self.high}]")

    # -- membership & cardinality ------------------------------------------

    @property
    def finite(self) -> bool:
        return self.kind != "continuous"

    @property
    def cardinality(self) -> int:
        if not self.finite:
            raise ValueError(f"dimension {self.name!r} is continuous")
        return len(self.values)

    def contains(self, value: Any) -> bool:
        if self.kind == "continuous":
            return isinstance(value, (int, float)) and self.low <= value <= self.high
        return value in self.values

    # -- encoding for optimizers -------------------------------------------

    def to_unit(self, value: Any) -> float:
        """Map a value into [0, 1] (index-based for finite kinds)."""
        if self.kind == "continuous":
            return (float(value) - self.low) / (self.high - self.low)
        idx = self.values.index(value)
        if len(self.values) == 1:
            return 0.0
        return idx / (len(self.values) - 1)

    def from_unit(self, u: float) -> Any:
        u = min(1.0, max(0.0, float(u)))
        if self.kind == "continuous":
            return self.low + u * (self.high - self.low)
        idx = int(round(u * (len(self.values) - 1)))
        return self.values[idx]

    # -- serialization ------------------------------------------------------

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "values": list(self.values),
            "low": self.low,
            "high": self.high,
            "prior": list(self.prior),
        }

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "Dimension":
        return Dimension(
            name=d["name"],
            kind=d["kind"],
            values=tuple(tuple(v) if isinstance(v, list) else v for v in d.get("values", ())),
            low=d.get("low", 0.0),
            high=d.get("high", 1.0),
            prior=tuple(d.get("prior", ())),
        )

    # convenience constructors
    @staticmethod
    def categorical(name: str, values: Sequence[Any], prior: Sequence[float] = ()) -> "Dimension":
        return Dimension(name=name, kind="categorical", values=tuple(values), prior=tuple(prior))

    @staticmethod
    def discrete(name: str, values: Sequence[float], prior: Sequence[float] = ()) -> "Dimension":
        return Dimension(name=name, kind="discrete", values=tuple(sorted(values)), prior=tuple(prior))

    @staticmethod
    def continuous(name: str, low: float, high: float) -> "Dimension":
        return Dimension(name=name, kind="continuous", low=float(low), high=float(high))


# ---------------------------------------------------------------------------
# Configurations (elements of Ω)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Configuration:
    """An immutable point in a configuration space.

    Identity is the content hash of the sorted ``(name, value)`` mapping —
    the common-context store keys on this, which is what makes transparent
    sharing across studies possible.
    """

    values: tuple  # tuple of (name, value) pairs, sorted by name

    @staticmethod
    def make(mapping: Mapping[str, Any]) -> "Configuration":
        items = tuple(sorted((str(k), _freeze(v)) for k, v in mapping.items()))
        return Configuration(values=items)

    def as_dict(self) -> dict:
        return dict(self.values)

    def __getitem__(self, key: str) -> Any:
        for k, v in self.values:
            if k == key:
                return v
        raise KeyError(key)

    def get(self, key: str, default: Any = None) -> Any:
        try:
            return self[key]
        except KeyError:
            return default

    @property
    def digest(self) -> str:
        return content_hash(self.values)

    def replace(self, **updates: Any) -> "Configuration":
        d = self.as_dict()
        d.update(updates)
        return Configuration.make(d)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        inner = ", ".join(f"{k}={v!r}" for k, v in self.values)
        return f"Configuration({inner})"


def _freeze(v: Any) -> Any:
    if isinstance(v, list):
        return tuple(_freeze(x) for x in v)
    if hasattr(v, "item") and not isinstance(v, (int, float, str, bool, tuple)):
        return v.item()
    return v


# ---------------------------------------------------------------------------
# Property values & samples (elements of D)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PropertyValue:
    """A measured or predicted value with provenance."""

    name: str
    value: float
    experiment_id: str
    timestamp: float = field(default_factory=time.time)
    predicted: bool = False


@dataclass
class Sample:
    """One element of a Discovery Space: configuration ⊗ property values."""

    configuration: Configuration
    properties: dict  # name -> PropertyValue

    def value(self, name: str) -> float:
        return self.properties[name].value

    def has(self, name: str) -> bool:
        return name in self.properties

    def items(self) -> Iterator:
        return iter(self.properties.items())
