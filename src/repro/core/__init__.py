"""repro.core — the paper's contribution: Discovery Spaces with TRACE.

``D = (P, Ω) ⊗ A`` — a probability space over configuration dimensions
tensored with an action space of experiments, backed by a common-context
sample store, searched by interchangeable optimizers, and transferable across
related spaces via RSSC.

Cooperative campaigns (paper §V)
--------------------------------

:class:`~repro.core.campaign.Campaign` is the sharing layer on top: N
best-of-breed optimizers run concurrently over ONE Discovery Space, each
with its own operation/rng/stopping rule, while every completed
measurement is told to *all* of them — before each ask a member folds the
other operations' new sampling events into its history
(:meth:`SearchAdapter.sync_foreign`, an incremental watermark read via
:meth:`SampleStore.records_since`), so each model trains on the union of
the fleet's data.  Sharing is strictly additive (solo trajectories are
draw-for-draw unchanged — regression-gated per optimizer), works across
processes sharing the store file, and measures each configuration once
fleet-wide through the ordinary claim arbitration.  Determinism, the
sharing model, and how to reproduce ``BENCH_sharing.json`` are documented
in :mod:`repro.core.campaign`.
"""

from .actions import (ActionSpace, Experiment, FunctionExperiment,
                      MeasurementError, SurrogateExperiment)
from .api import (CatalogEntry, Investigation, InvestigationPlan,
                  InvestigationResult, InvestigationSpec, RelatedSpace,
                  SpaceCatalog, TransferReport, TransferSpec,
                  register_experiment)
from .campaign import Campaign, CampaignResult, MemberResult, run_campaign
from .clock import Clock, FakeClock, SYSTEM_CLOCK
from .clustering import (select_linspace, select_representatives, select_top_k,
                         silhouette_clusters)
from .discovery import DiscoverySpace
from .entities import Configuration, Dimension, PropertyValue, Sample
from .execution import (AutoscalePolicy, ExecutionBackend, LeasePacer,
                        ProcessBackend, QueueBackend, SerialBackend,
                        ThreadBackend, WorkerCrashError)
from .rssc import RSSCResult, rssc_transfer
from .space import ProbabilitySpace
from .store import RecordEntry, SampleStore, StoreBackend, open_store
from .transfer import (LinearSurrogate, PredictionQuality, TransferAssessment,
                       TransferCriteria, assess_transfer, prediction_quality)

__all__ = [
    "ActionSpace", "Experiment", "FunctionExperiment", "MeasurementError",
    "SurrogateExperiment", "DiscoverySpace", "Configuration", "Dimension",
    "PropertyValue", "Sample", "ProbabilitySpace", "RecordEntry", "SampleStore",
    "StoreBackend", "open_store",
    "RSSCResult", "rssc_transfer", "LinearSurrogate", "PredictionQuality",
    "TransferAssessment", "TransferCriteria", "assess_transfer",
    "prediction_quality", "select_representatives", "select_top_k",
    "select_linspace", "silhouette_clusters", "ExecutionBackend",
    "SerialBackend", "ThreadBackend", "ProcessBackend", "QueueBackend",
    "WorkerCrashError", "AutoscalePolicy", "LeasePacer", "Clock", "FakeClock",
    "SYSTEM_CLOCK", "Campaign", "CampaignResult", "MemberResult",
    "run_campaign", "Investigation", "InvestigationPlan",
    "InvestigationResult", "InvestigationSpec", "TransferReport",
    "TransferSpec", "SpaceCatalog", "CatalogEntry", "RelatedSpace",
    "register_experiment",
]
