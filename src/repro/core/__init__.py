"""repro.core — the paper's contribution: Discovery Spaces with TRACE.

``D = (P, Ω) ⊗ A`` — a probability space over configuration dimensions
tensored with an action space of experiments, backed by a common-context
sample store, searched by interchangeable optimizers, and transferable across
related spaces via RSSC.
"""

from .actions import (ActionSpace, Experiment, FunctionExperiment,
                      MeasurementError, SurrogateExperiment)
from .clock import Clock, FakeClock, SYSTEM_CLOCK
from .clustering import (select_linspace, select_representatives, select_top_k,
                         silhouette_clusters)
from .discovery import DiscoverySpace
from .entities import Configuration, Dimension, PropertyValue, Sample
from .execution import (AutoscalePolicy, ExecutionBackend, LeasePacer,
                        ProcessBackend, QueueBackend, SerialBackend,
                        ThreadBackend, WorkerCrashError)
from .rssc import RSSCResult, rssc_transfer
from .space import ProbabilitySpace
from .store import RecordEntry, SampleStore
from .transfer import (LinearSurrogate, PredictionQuality, TransferAssessment,
                       TransferCriteria, assess_transfer, prediction_quality)

__all__ = [
    "ActionSpace", "Experiment", "FunctionExperiment", "MeasurementError",
    "SurrogateExperiment", "DiscoverySpace", "Configuration", "Dimension",
    "PropertyValue", "Sample", "ProbabilitySpace", "RecordEntry", "SampleStore",
    "RSSCResult", "rssc_transfer", "LinearSurrogate", "PredictionQuality",
    "TransferAssessment", "TransferCriteria", "assess_transfer",
    "prediction_quality", "select_representatives", "select_top_k",
    "select_linspace", "silhouette_clusters", "ExecutionBackend",
    "SerialBackend", "ThreadBackend", "ProcessBackend", "QueueBackend",
    "WorkerCrashError", "AutoscalePolicy", "LeasePacer", "Clock", "FakeClock",
    "SYSTEM_CLOCK",
]
