"""Clustering for representative sub-space identification (paper §IV-2).

The paper clusters source-space samples on the property values to be
transferred and uses silhouette scoring to pick the number of clusters
("silhouette clustering"); cluster representatives (the samples nearest each
centroid) form the representative sub-space.  We implement k-means (numpy,
k-means++ init) + mean-silhouette model selection, plus the two baseline
point-selection methods the paper compares against: ``top5`` and
``linspace`` (§V-B2).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

__all__ = ["kmeans", "silhouette_score", "silhouette_clusters", "select_representatives",
           "select_top_k", "select_linspace", "select_indices"]


def kmeans(X: np.ndarray, k: int, rng: np.random.Generator, n_iter: int = 100):
    """Standard k-means with k-means++ seeding.  Returns (centroids, labels)."""
    n = len(X)
    k = min(k, n)
    # k-means++ init
    centroids = [X[int(rng.integers(n))]]
    for _ in range(1, k):
        d2 = np.min(((X[:, None, :] - np.array(centroids)[None]) ** 2).sum(-1), axis=1)
        total = d2.sum()
        if total <= 0:
            centroids.append(X[int(rng.integers(n))])
            continue
        probs = d2 / total
        centroids.append(X[int(rng.choice(n, p=probs))])
    C = np.array(centroids)
    labels = np.zeros(n, dtype=int)
    for _ in range(n_iter):
        d2 = ((X[:, None, :] - C[None]) ** 2).sum(-1)
        new_labels = d2.argmin(axis=1)
        if (new_labels == labels).all() and _ > 0:
            break
        labels = new_labels
        for j in range(k):
            m = labels == j
            if m.any():
                C[j] = X[m].mean(axis=0)
    return C, labels


def silhouette_score(X: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette coefficient (O(n²), fine for sample-store sizes)."""
    n = len(X)
    uniq = np.unique(labels)
    if len(uniq) < 2 or n < 3:
        return -1.0
    D = np.sqrt(((X[:, None, :] - X[None]) ** 2).sum(-1))
    s = np.zeros(n)
    for i in range(n):
        same = labels == labels[i]
        n_same = same.sum()
        a = D[i, same].sum() / max(n_same - 1, 1) if n_same > 1 else 0.0
        b = np.inf
        for c in uniq:
            if c == labels[i]:
                continue
            m = labels == c
            b = min(b, D[i, m].mean())
        s[i] = 0.0 if max(a, b) == 0 else (b - a) / max(a, b)
    return float(s.mean())


def silhouette_clusters(X: np.ndarray, rng: np.random.Generator,
                        k_min: int = 2, k_max: Optional[int] = None):
    """Pick k by maximum mean silhouette; returns (k, centroids, labels)."""
    n = len(X)
    k_max = k_max if k_max is not None else max(k_min, min(12, n // 2))
    best = None
    for k in range(k_min, k_max + 1):
        if k >= n:
            break
        C, labels = kmeans(X, k, rng)
        score = silhouette_score(X, labels)
        if best is None or score > best[0]:
            best = (score, k, C, labels)
    if best is None:  # degenerate: fewer than 3 points
        C, labels = kmeans(X, min(n, k_min), rng)
        return min(n, k_min), C, labels
    return best[1], best[2], best[3]


def select_representatives(values: np.ndarray, rng: np.random.Generator,
                           k_min: int = 4, k_max: Optional[int] = None) -> list:
    """Cluster samples on (normalized) property values; return the indices of
    the sample nearest each centroid — the representative sub-space.

    ``k_min`` defaults to 4: a linear-regression transfer criterion needs a
    handful of points to be meaningful (the paper's clustering selected
    4–33 points across its transfer tests, Table VI)."""
    V = np.atleast_2d(np.asarray(values, dtype=float))
    if V.shape[0] == 1 and V.size > 1:
        V = V.T  # single property passed as flat vector
    lo, hi = V.min(axis=0), V.max(axis=0)
    Vn = (V - lo) / np.where(hi - lo > 0, hi - lo, 1.0)
    k, C, labels = silhouette_clusters(Vn, rng, k_min=min(k_min, max(2, len(V) // 2)),
                                       k_max=k_max)
    reps = []
    for j in range(k):
        m = np.where(labels == j)[0]
        if len(m) == 0:
            continue
        d2 = ((Vn[m] - C[j]) ** 2).sum(-1)
        reps.append(int(m[d2.argmin()]))
    return sorted(set(reps))


def select_top_k(values: np.ndarray, k: int = 5, mode: str = "min") -> list:
    """Baseline 'top5' of §V-B2: the k best-ranked points."""
    v = np.asarray(values, dtype=float)
    order = np.argsort(v if mode == "min" else -v)
    return [int(i) for i in order[:k]]


def select_linspace(values: np.ndarray, k: int) -> list:
    """Baseline 'linspace' of §V-B2: k evenly spaced points over the ranking."""
    v = np.asarray(values, dtype=float)
    order = np.argsort(v)
    idx = np.linspace(0, len(v) - 1, num=min(k, len(v)))
    return sorted({int(order[int(round(i))]) for i in idx})


def select_indices(values: np.ndarray, selection: str,
                   rng: np.random.Generator, top_k: int = 5) -> list:
    """Representative-point selection dispatch shared by RSSC (§IV-2) and the
    Investigation transfer stage: ``selection`` ∈ {"clustering", "top5",
    "linspace"} — the paper's method and its two §V-B2 baselines.  The
    linspace baseline sizes itself to the clustering pick (same rng draw) so
    the comparison is point-count-matched, exactly the rssc_transfer
    behaviour this was factored out of."""
    if selection == "clustering":
        return select_representatives(values, rng)
    if selection == "top5":
        return select_top_k(values, k=top_k)
    if selection == "linspace":
        k = len(select_representatives(values, rng))  # match clustering count
        return select_linspace(values, k)
    raise ValueError(f"unknown selection method {selection!r}")
