"""The Discovery Space: ``D = (P, Ω) ⊗ A`` (paper §III-B, §III-C).

The class below is the concrete data model of the paper's Fig. 3: it is
composed of the configuration probability space, the Action space, and is
backed by the common-context :class:`~repro.core.store.SampleStore` for the
sample store + sampling records.

TRACE characteristics, and where they live:

* **Encapsulated** — :meth:`sample` validates configurations against Ω and
  only runs/records experiments in A; :meth:`read` only returns values whose
  provenance is in A.
* **Actionable** — the space itself knows how to obtain measurements
  (:meth:`sample` with no stored data runs the experiments) and what remains
  to measure (:meth:`remaining_configurations`).
* **Time-Resolved** — every sample event appends to the per-operation
  sampling record with a sequence number and timestamp
  (:meth:`timeseries`).
* **Common Context** — all values go through the shared store in the generic
  schema; nothing is kept privately on the object (operations are stateless).
* **Reconcilable** — data written by *another* space for the same
  configuration is invisible here until *this* space's :meth:`sample`
  generates that configuration; at that point the stored values are reused
  rather than re-measured (paper §III-C4, and §III-C5's
  reuse-once-sampled default).
"""

from __future__ import annotations

import uuid
from typing import Iterator, Mapping, Optional, Sequence

import numpy as np

from .actions import ActionSpace, Experiment, MeasurementError, SurrogateExperiment
from .entities import Configuration, PropertyValue, Sample, content_hash
from .space import ProbabilitySpace
from .store import RecordEntry, SampleStore

__all__ = ["DiscoverySpace"]


class DiscoverySpace:
    """A configuration search study's data model: space ⊗ actions, stored."""

    def __init__(
        self,
        space: ProbabilitySpace,
        actions: ActionSpace,
        store: Optional[SampleStore] = None,
        space_id: Optional[str] = None,
    ):
        self.space = space
        self.actions = actions
        self.store = store if store is not None else SampleStore(":memory:")
        # Identity: the space is defined by (Ω, A).  Two DiscoverySpace objects
        # over the same store with the same (Ω, A) are views of the same study.
        self.space_id = space_id or content_hash(
            {"space": space.digest, "actions": actions.digest}
        )
        self.store.register_space(
            self.space_id, space.to_json(), actions.identifiers
        )

    # ------------------------------------------------------------------ sample

    def sample(
        self,
        configuration: Optional[Configuration] = None,
        rng: Optional[np.random.Generator] = None,
        operation_id: str = "adhoc",
    ) -> Sample:
        """Sample one point of D (paper Fig. 3 right-hand flow).

        If ``configuration`` is None, draw from (P, Ω).  Then, for every
        experiment in A: if the common context already holds that
        experiment's values for this configuration, *reuse* them; otherwise
        *measure* (execute the experiment) and store the results.  Either
        way the event is appended to this space's sampling record — this is
        the only way data becomes visible to :meth:`read`.
        """
        if configuration is None:
            rng = rng if rng is not None else np.random.default_rng()
            configuration = self.space.sample_configuration(rng)
        # Encapsulated: reject configurations outside Ω.
        self.space.validate(configuration)
        digest = self.store.put_configuration(configuration)

        measured_any = False
        reused_any = False
        predicted_any = False
        try:
            for exp in self.actions.experiments:
                if self.store.has_values(digest, exp.identifier):
                    reused_any = True
                    continue
                if exp.deferred:
                    # apply-on-demand (A*_pred semantics, paper §IV-4)
                    continue
                values = exp.measure(configuration)
                self.store.put_values(
                    digest,
                    [
                        PropertyValue(
                            name=k,
                            value=float(v),
                            experiment_id=exp.identifier,
                            predicted=exp.predicted,
                        )
                        for k, v in values.items()
                    ],
                )
                if exp.predicted:
                    predicted_any = True
                else:
                    measured_any = True
        except MeasurementError:
            self.store.append_record(self.space_id, operation_id, digest, "failed")
            raise

        if measured_any:
            action = "measured"
        elif predicted_any and not reused_any:
            action = "predicted"
        else:
            action = "reused"
        self.store.append_record(self.space_id, operation_id, digest, action)
        return self._reconstruct(digest, configuration)

    # -------------------------------------------------------------------- read

    def read(self) -> list:
        """The reconciled sample set {x}: only configurations in *this*
        space's sampling record, with values restricted to *this* action
        space's experiments."""
        out = []
        for digest in self.store.sampled_digests(self.space_id):
            config = self.store.get_configuration(digest)
            if config is None:  # pragma: no cover - store corruption guard
                continue
            out.append(self._reconstruct(digest, config))
        return out

    def read_one(self, configuration: Configuration) -> Optional[Sample]:
        digest = configuration.digest
        if digest not in set(self.store.sampled_digests(self.space_id)):
            return None
        return self._reconstruct(digest, configuration)

    def _reconstruct(self, digest: str, config: Configuration) -> Sample:
        values = self.store.get_values(digest, self.actions.identifiers)
        props = {}
        for v in values:
            # last write wins within an experiment; measured values win over
            # predictions for the same property
            if v.name in props and props[v.name].predicted is False and v.predicted:
                continue
            props[v.name] = v
        return Sample(configuration=config, properties=props)

    # ------------------------------------------------------------- time series

    def timeseries(self, operation_id: Optional[str] = None) -> list:
        """The time-resolved sampling record (TRACE: Time-Resolved)."""
        return self.store.records_for(self.space_id, operation_id)

    def begin_operation(self, kind: str, meta: Optional[Mapping] = None) -> str:
        operation_id = f"{kind}-{uuid.uuid4().hex[:12]}"
        self.store.register_operation(operation_id, self.space_id, kind, meta)
        return operation_id

    # -------------------------------------------------------------- actionable

    def sampled_configurations(self) -> list:
        return [self.store.get_configuration(d)
                for d in self.store.sampled_digests(self.space_id)]

    def remaining_configurations(self) -> Iterator[Configuration]:
        """What has not been sampled yet, and (via A) how to measure it."""
        seen = set(self.store.sampled_digests(self.space_id, include_failed=True))
        for config in self.space.all_configurations():
            if config.digest not in seen:
                yield config

    def count_sampled(self) -> int:
        return len(self.store.sampled_digests(self.space_id))

    # ------------------------------------------------------------ derived space

    def with_predictor(self, surrogate: SurrogateExperiment) -> "DiscoverySpace":
        """``A*_pred``: a *new* Discovery Space whose action space adds a
        surrogate predictor (paper §IV-4).  Provenance is preserved — the
        surrogate's values are marked ``predicted``, the original experiments
        remain in the action space as *deferred* (apply-on-demand), and
        measured values win over predictions on read."""
        from .actions import DeferredExperiment  # local: avoid cycle at import

        deferred = tuple(
            e if e.deferred else DeferredExperiment(e) for e in self.actions.experiments
        )
        return DiscoverySpace(
            space=self.space,
            actions=ActionSpace(experiments=(surrogate,) + deferred),
            store=self.store,
        )

    def related(self, mapping: Mapping[str, Mapping], actions: Optional[ActionSpace] = None,
                ) -> "DiscoverySpace":
        """Define a target space A* differing by a value mapping (paper §IV-1)."""
        return DiscoverySpace(
            space=self.space.map_values(mapping),
            actions=actions if actions is not None else self.actions,
            store=self.store,
        )

    def __repr__(self) -> str:  # pragma: no cover
        size = self.space.size if self.space.finite else "inf"
        return (f"DiscoverySpace(id={self.space_id[:8]}, |Ω|={size}, "
                f"|A|={len(self.actions.experiments)}, sampled={self.count_sampled()})")
