"""The Discovery Space: ``D = (P, Ω) ⊗ A`` (paper §III-B, §III-C).

The class below is the concrete data model of the paper's Fig. 3: it is
composed of the configuration probability space, the Action space, and is
backed by the common-context :class:`~repro.core.store.SampleStore` for the
sample store + sampling records.

TRACE characteristics, and where they live:

* **Encapsulated** — :meth:`sample` validates configurations against Ω and
  only runs/records experiments in A; :meth:`read` only returns values whose
  provenance is in A.
* **Actionable** — the space itself knows how to obtain measurements
  (:meth:`sample` with no stored data runs the experiments) and what remains
  to measure (:meth:`remaining_configurations`).
* **Time-Resolved** — every sample event appends to the per-operation
  sampling record with a sequence number and timestamp
  (:meth:`timeseries`).
* **Common Context** — all values go through the shared store in the generic
  schema; nothing is kept privately on the object (operations are stateless).
  :meth:`sample_batch` exploits this: because the store is the only state,
  experiment execution fans out over a worker pool — and over independent
  worker *processes* sharing one database (§III-D) — with per-cell
  measurement claims guaranteeing each (configuration, experiment) is
  measured exactly once no matter how many investigators race for it.
* **Reconcilable** — data written by *another* space for the same
  configuration is invisible here until *this* space's :meth:`sample`
  generates that configuration; at that point the stored values are reused
  rather than re-measured (paper §III-C4, and §III-C5's
  reuse-once-sampled default).
"""

from __future__ import annotations

import uuid
from concurrent.futures import Executor
from dataclasses import dataclass
from typing import Iterator, Mapping, Optional, Sequence, Union

import numpy as np

from .actions import ActionSpace, Experiment, MeasurementError, SurrogateExperiment
from .clock import Clock
from .entities import Configuration, Sample, content_hash
from .execution import (AutoscalePolicy, ExecutionBackend, ExecutionContext,
                        WorkItem, make_backend)
from .space import ProbabilitySpace
from .store import RecordEntry, SampleStore, StoreBackend

__all__ = ["DiscoverySpace", "BatchResult"]


@dataclass
class BatchResult:
    """Outcome of one slot of a :meth:`DiscoverySpace.sample_batch` call.

    ``action`` is the sampling-record tag (``measured`` / ``reused`` /
    ``predicted`` / ``failed``); ``sample`` is None iff the measurement
    failed, in which case ``error`` holds the :class:`MeasurementError`.
    """

    configuration: Configuration
    sample: Optional[Sample]
    action: str
    error: Optional[MeasurementError] = None

    @property
    def ok(self) -> bool:
        return self.sample is not None


class DiscoverySpace:
    """A configuration search study's data model: space ⊗ actions, stored."""

    def __init__(
        self,
        space: ProbabilitySpace,
        actions: ActionSpace,
        store: Optional[StoreBackend] = None,
        space_id: Optional[str] = None,
        claim_timeout_s: float = 60.0,
        lease_s: float = 15.0,
        clock: Optional[Clock] = None,
        autoscale: Optional[AutoscalePolicy] = None,
        meta: Optional[Mapping] = None,
    ):
        self.space = space
        self.actions = actions
        self.store = store if store is not None else SampleStore(":memory:")
        # How long a concurrent investigator's in-flight measurement of the
        # same cell is waited for before its claim is presumed abandoned.
        # Size this to the action space: it should exceed the slowest
        # experiment's expected duration (cloud deployments: minutes).
        self.claim_timeout_s = claim_timeout_s
        # Heartbeat-lease horizon for owners that renew (queue/process
        # workers): their death is detected within ~lease_s even when
        # claim_timeout_s is minutes.  Compared across hosts' wall clocks —
        # on multi-machine deployments size it above the worst expected
        # clock skew (see ExecutionContext).
        self.lease_s = lease_s
        # Injectable time source for every timing decision (leases, sweeps,
        # autoscaling); defaults to the store's clock so one FakeClock at
        # the store flows through the whole stack.
        self.clock = clock if clock is not None else self.store.clock
        # Fleet-sizing policy applied by autoscaling backends (None => each
        # backend's default).
        self.autoscale = autoscale
        # Identity: the space is defined by (Ω, A).  Two DiscoverySpace objects
        # over the same store with the same (Ω, A) are views of the same study.
        self.space_id = space_id or content_hash(
            {"space": space.digest, "actions": actions.digest}
        )
        # Catalog registration: the Ω-only digest + entity metadata are what
        # SpaceCatalog.find_related matches on — a target investigation can
        # discover this space as a transfer source without reconstructing
        # its (code-only) experiments.  Caller-supplied ``meta`` (e.g. a
        # workload family's identity block) is merged in first; the reserved
        # keys below always reflect this space's actual (Ω, A).
        self.extra_meta = dict(meta) if meta else {}
        registered_meta = dict(self.extra_meta)
        registered_meta.update({
            "dimensions": list(space.names),
            "size": space.size if space.finite else None,
            "properties": list(actions.observed_properties),
        })
        self.store.register_space(
            self.space_id, space.to_json(), actions.identifiers,
            space_digest=space.digest,
            meta=registered_meta,
        )
        # Stale-claim GC pacing: the batch/pipelined drivers sweep at most
        # once per lease interval — and the FIRST call always sweeps, so
        # short-lived runs (CI smoke, --quick benches) get at least one GC
        # pass instead of skipping it entirely (see _maybe_sweep_claims).
        self._last_claim_sweep: Optional[float] = None

    # -------------------------------------------------------------- execution

    def execution_context(self) -> ExecutionContext:
        """What a backend needs to execute this space's measurements."""
        return ExecutionContext(
            store=self.store,
            experiments=self.actions.experiments,
            claim_timeout_s=self.claim_timeout_s,
            space_id=self.space_id,
            lease_s=self.lease_s,
            clock=self.clock,
            autoscale=self.autoscale,
        )

    def execution_backend(
        self,
        backend: Union[ExecutionBackend, str, None] = None,
        workers: int = 1,
        executor: Optional[Executor] = None,
    ) -> ExecutionBackend:
        """Resolve an execution backend bound to this space.

        ``backend`` is an :class:`ExecutionBackend` instance (used as-is; the
        caller keeps ownership), one of ``"serial" | "thread" | "process" |
        "queue"``, or None — then the legacy ``workers``/``executor`` knobs
        pick serial vs thread execution, matching the pre-backend engine.
        """
        return make_backend(backend, self.execution_context(),
                            workers=workers, executor=executor)

    def _maybe_sweep_claims(self) -> None:
        """Periodic stale-claim GC (ROADMAP item): reap claims from crashed
        investigators up front instead of making every waiter burn its full
        timeout.  Lease-based — a heartbeating owner is never reaped; a dead
        one is gone within its lease — and paced off the *injected* clock at
        one sweep per lease interval, with the first call sweeping
        unconditionally (wall-clock pacing used to skip GC entirely on runs
        shorter than the claim timeout, e.g. ``--quick`` CI benches)."""
        now = self.clock.monotonic()
        if (self._last_claim_sweep is None
                or now - self._last_claim_sweep >= self.lease_s):
            self._last_claim_sweep = now
            self.store.sweep_stale_claims()

    # ------------------------------------------------------------------ sample

    def sample(
        self,
        configuration: Optional[Configuration] = None,
        rng: Optional[np.random.Generator] = None,
        operation_id: str = "adhoc",
    ) -> Sample:
        """Sample one point of D (paper Fig. 3 right-hand flow).

        If ``configuration`` is None, draw from (P, Ω).  Then, for every
        experiment in A: if the common context already holds that
        experiment's values for this configuration, *reuse* them; otherwise
        *measure* (execute the experiment) and store the results.  Either
        way the event is appended to this space's sampling record — this is
        the only way data becomes visible to :meth:`read`.
        """
        if configuration is None:
            rng = rng if rng is not None else np.random.default_rng()
            configuration = self.space.sample_configuration(rng)
        result = self.sample_batch([configuration], operation_id=operation_id)[0]
        if not result.ok:
            raise result.error
        return result.sample

    def sample_batch(
        self,
        configurations: Sequence[Configuration],
        operation_id: str = "adhoc",
        workers: int = 1,
        executor: Optional[Executor] = None,
        backend: Union[ExecutionBackend, str, None] = None,
        priorities: Optional[Sequence[float]] = None,
    ) -> list:
        """Sample a batch of points, fanning experiment execution out over an
        execution backend (paper §III-D: distributed investigation through
        the shared sample store).

        Semantics are *serial-equivalent*: the reconciled sample set and the
        sampling record are identical to sampling the same configurations one
        by one — duplicates within the batch are measured once and recorded
        as ``reused`` thereafter, reuse/measure decisions go through the
        common context, and record events are appended in submission order
        (atomic per-operation ``seq`` allocation makes this safe alongside
        concurrent writers in other threads or processes).

        Only experiment execution is parallel: each distinct configuration is
        one :class:`~repro.core.execution.WorkItem` on the resolved backend —
        ``backend`` names one of ``serial | thread | process | queue`` or is
        a ready :class:`~repro.core.execution.ExecutionBackend`; with None
        the legacy ``workers``/``executor`` knobs pick serial vs thread
        execution.  ``priorities`` (optional, one score per configuration —
        the optimizer's acquisition) rides on the work items: scheduling
        backends measure best-first, while results, records, and the
        reconciled sample set stay in submission order regardless.  Failed
        measurements do not abort the batch; they yield a
        :class:`BatchResult` with ``action='failed'`` carrying the error.
        Crash-isolating backends (process, queue) also contain *unexpected*
        experiment errors and worker deaths to their own slot as ``failed``
        results, instead of re-raising from the batch.
        """
        configs = list(configurations)
        if not configs:
            return []
        if priorities is not None and len(priorities) != len(configs):
            raise ValueError(
                f"priorities must match configurations: "
                f"{len(priorities)} != {len(configs)}")
        # Encapsulated: reject configurations outside Ω before any work runs.
        for config in configs:
            self.space.validate(config)
        self._maybe_sweep_claims()
        # one interning transaction/round-trip for the whole batch
        digests = self.store.put_configurations(configs)

        # Duplicates measure once: the first slot of each digest does the
        # experiment work, later slots transparently reuse (§III-C5).
        first_slot: dict = {}
        for i, digest in enumerate(digests):
            first_slot.setdefault(digest, i)
        unique = [i for i, digest in enumerate(digests) if first_slot[digest] == i]

        owned = not isinstance(backend, ExecutionBackend)
        engine = self.execution_backend(backend, workers=workers,
                                        executor=executor)
        try:
            for i in unique:
                engine.submit(WorkItem(
                    configs[i], digests[i], i,
                    priority=(float(priorities[i]) if priorities is not None
                              else 0.0)))
            completed = engine.drain()
        finally:
            if owned:
                engine.close()
        by_digest = {digests[r.item.tag]: (r.action, r.error)
                     for r in completed}

        # Time-Resolved: record events in submission order, one transaction.
        # Like the serial loop, a slot that crashed with a non-measurement
        # error gets no record; every other slot's event still lands before
        # the error propagates (its values are already durable).
        results, events, recorded = [], [], []
        crash: Optional[BaseException] = None
        for i, (config, digest) in enumerate(zip(configs, digests)):
            action, err = by_digest[digest]
            if action == "crashed":
                crash = crash if crash is not None else err
                continue
            if err is None and first_slot[digest] != i:
                action = "reused"
            events.append((digest, action))
            recorded.append(digest)
            results.append(BatchResult(config, None, action, err))
        self.store.append_records(self.space_id, operation_id, events)
        if crash is not None:
            raise crash
        for result, digest in zip(results, recorded):
            if result.error is None:
                result.sample = self._reconstruct(digest, result.configuration)
        return results

    def record_result(self, configuration: Configuration, digest: str,
                      action: str, error: Optional[MeasurementError],
                      operation_id: str) -> BatchResult:
        """Record ONE completed work item and reconstruct its sample.

        The pipelined ask/tell driver's tell path: unlike
        :meth:`sample_batch`, which barriers and records a whole batch in
        submission order, the pipelined engine records each trial the moment
        its backend reports completion — so events land in completion order,
        which *is* the submission order when ``max_inflight=1``.
        """
        self.store.append_record(self.space_id, operation_id, digest, action)
        result = BatchResult(configuration, None, action, error)
        if error is None:
            result.sample = self._reconstruct(digest, configuration)
        return result

    # -------------------------------------------------------------------- read

    def read(self) -> list:
        """The reconciled sample set {x}: only configurations in *this*
        space's sampling record, with values restricted to *this* action
        space's experiments."""
        out = []
        for digest in self.store.sampled_digests(self.space_id):
            config = self.store.get_configuration(digest)
            if config is None:  # pragma: no cover - store corruption guard
                continue
            out.append(self._reconstruct(digest, config))
        return out

    def read_one(self, configuration: Configuration) -> Optional[Sample]:
        digest = configuration.digest
        # indexed point query — not a rebuild of the full sampled-digest set
        # (RSSC's surrogate lookup calls this once per predicted point)
        if not self.store.has_record(self.space_id, digest):
            return None
        return self._reconstruct(digest, configuration)

    def _reconstruct(self, digest: str, config: Configuration) -> Sample:
        values = self.store.get_values(digest, self.actions.identifiers)
        props = {}
        for v in values:
            # last write wins within an experiment; measured values win over
            # predictions for the same property
            if v.name in props and props[v.name].predicted is False and v.predicted:
                continue
            props[v.name] = v
        return Sample(configuration=config, properties=props)

    # ------------------------------------------------------------- time series

    def timeseries(self, operation_id: Optional[str] = None) -> list:
        """The time-resolved sampling record (TRACE: Time-Resolved)."""
        return self.store.records_for(self.space_id, operation_id)

    def begin_operation(self, kind: str, meta: Optional[Mapping] = None) -> str:
        operation_id = f"{kind}-{uuid.uuid4().hex[:12]}"
        self.store.register_operation(operation_id, self.space_id, kind, meta)
        return operation_id

    # -------------------------------------------------------------- actionable

    def sampled_configurations(self) -> list:
        return [self.store.get_configuration(d)
                for d in self.store.sampled_digests(self.space_id)]

    def remaining_configurations(self) -> Iterator[Configuration]:
        """What has not been sampled yet, and (via A) how to measure it."""
        seen = set(self.store.sampled_digests(self.space_id, include_failed=True))
        for config in self.space.all_configurations():
            if config.digest not in seen:
                yield config

    def count_sampled(self) -> int:
        return len(self.store.sampled_digests(self.space_id))

    def failure_summary(self) -> dict:
        """Failed trials in this space by actuation phase, with the
        provisioned cost they still charged:
        ``{phase: {"count": n, "cost": charged}}``.  Failed rows recorded
        before failure provenance existed surface under phase ``"unknown"``
        with zero cost (the backfill contract — see
        :meth:`~repro.core.store.base.StoreBackend.failure_summary`)."""
        return self.store.failure_summary(self.space_id)

    def failures_for(self, configuration: Configuration) -> list:
        """Full failure provenance rows recorded for one configuration,
        restricted to this space's experiments (zombie retries included —
        the history is honest even where the summary de-duplicates)."""
        rows = self.store.failures_for(configuration.digest)
        ids = set(self.actions.identifiers)
        return [r for r in rows if r.get("experiment_id") in ids]

    # ------------------------------------------------------------ derived space

    def with_predictor(self, surrogate: SurrogateExperiment) -> "DiscoverySpace":
        """``A*_pred``: a *new* Discovery Space whose action space adds a
        surrogate predictor (paper §IV-4).  Provenance is preserved — the
        surrogate's values are marked ``predicted``, the original experiments
        remain in the action space as *deferred* (apply-on-demand), and
        measured values win over predictions on read."""
        from .actions import DeferredExperiment  # local: avoid cycle at import

        deferred = tuple(
            e if e.deferred else DeferredExperiment(e) for e in self.actions.experiments
        )
        return DiscoverySpace(
            space=self.space,
            actions=ActionSpace(experiments=(surrogate,) + deferred),
            store=self.store,
            claim_timeout_s=self.claim_timeout_s,
            lease_s=self.lease_s,
            clock=self.clock,
            autoscale=self.autoscale,
            meta=self.extra_meta,
        )

    def related(self, mapping: Mapping[str, Mapping], actions: Optional[ActionSpace] = None,
                ) -> "DiscoverySpace":
        """Define a target space A* differing by a value mapping (paper §IV-1)."""
        return DiscoverySpace(
            space=self.space.map_values(mapping),
            actions=actions if actions is not None else self.actions,
            store=self.store,
            claim_timeout_s=self.claim_timeout_s,
            lease_s=self.lease_s,
            clock=self.clock,
            autoscale=self.autoscale,
            meta=self.extra_meta,
        )

    def __repr__(self) -> str:  # pragma: no cover
        size = self.space.size if self.space.finite else "inf"
        return (f"DiscoverySpace(id={self.space_id[:8]}, |Ω|={size}, "
                f"|A|={len(self.actions.experiments)}, sampled={self.count_sampled()})")
