"""The served store: one process owning the database, many clients.

``python -m repro.core.store.server --db /path/store.db`` turns the
reference SQLite store into a *service*: investigations and workers — any
number, on any host that can reach the socket — talk to it through
:class:`~repro.core.store.client.ClientStore` instead of opening the
database file themselves.  This is the ExpoCloud controller/worker shape
(PAPERS.md) applied to the paper's §III-D rendezvous: the common context no
longer requires a shared filesystem, and every claim race
(``claim_experiment``, ``claim_work_batch``, ``steal_claim``) is arbitrated
inside the single server process, where SQLite's writer lock settles it
without cross-host file-locking semantics ever entering the picture.

Design:

* **thread per connection**, frames processed strictly in arrival order per
  connection — which is exactly what makes client-side *pipelining* sound
  (N requests written back-to-back are answered by N responses in the same
  order; see :mod:`repro.core.store.protocol`).
* **dispatch allowlist**: the wire can invoke exactly the
  :class:`~repro.core.store.base.StoreBackend` primitives, nothing else —
  a method name outside the table is an error response, never a getattr.
* **plain-data boundary**: rich types (Configuration, PropertyValue,
  RecordEntry) are coerced at this boundary (see the protocol module's
  docstring for the shapes); the store underneath is the stock
  :class:`~repro.core.store.sqlite.SampleStore` and behaves byte-identically
  to in-process use.

Crash behavior: the server holds no volatile coordination state — claims,
leases, and the work queue all live in the database — so killing it
mid-claim loses nothing that the lease machinery doesn't already recover.
Clients reconnect (with backoff) to a restarted server at the same URL, and
leases whose owners died in the gap expire and are reaped/re-queued exactly
as they would with the in-process backend (exercised by
``tests/test_store_server.py``).
"""

from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
from typing import Optional

from ..entities import PropertyValue
from .base import DEFAULT_LEASE_S, config_from_pairs
from .protocol import FrameError, recv_frame, send_frame
from .sqlite import SampleStore

__all__ = ["StoreServer", "main"]


def _record_tuple(rec) -> tuple:
    return (rec.space_id, rec.operation_id, rec.seq, rec.config_digest,
            rec.action, rec.created_at, rec.rowid)


def _pv_tuple(v: PropertyValue) -> tuple:
    return (v.name, v.value, v.experiment_id, v.predicted, v.timestamp)


def _pv_from(t) -> PropertyValue:
    name, value, experiment_id, predicted, timestamp = t
    return PropertyValue(name=name, value=float(value),
                         experiment_id=experiment_id,
                         predicted=bool(predicted), timestamp=timestamp)


class StoreServer:
    """Serve one :class:`SampleStore` over a TCP or unix-domain socket."""

    def __init__(self, store: SampleStore, host: str = "127.0.0.1",
                 port: int = 0, unix_path: Optional[str] = None):
        self.store = store
        self._lock = threading.Lock()
        self._conns: set = set()
        self._shutdown = threading.Event()
        if unix_path is not None:
            if os.path.exists(unix_path):
                os.unlink(unix_path)  # stale socket from a dead server
            self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self._sock.bind(unix_path)
            self.url = f"unix://{unix_path}"
        else:
            self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._sock.bind((host, port))
            bound_host, bound_port = self._sock.getsockname()[:2]
            self.url = f"tcp://{bound_host}:{bound_port}"
        self._sock.listen(128)
        self._accept_thread: Optional[threading.Thread] = None
        # Bound once: the wire may invoke exactly these methods.  Handlers
        # coerce plain wire data to rich types on the way in and back out.
        store_do = self.store
        self._handlers = {
            "ping": lambda: "pong",
            "register_space": lambda space_id, space_json, action_ids,
                space_digest="", meta=None: store_do.register_space(
                    space_id, space_json, action_ids, space_digest, meta),
            "list_spaces": store_do.list_spaces,
            "space_stats": store_do.space_stats,
            "register_operation": store_do.register_operation,
            "operations_for": store_do.operations_for,
            "put_configuration": lambda pairs: store_do.put_configuration(
                config_from_pairs(pairs)),
            "put_configurations": lambda pairs_list: store_do.put_configurations(
                [config_from_pairs(p) for p in pairs_list]),
            "get_configuration": self._get_configuration,
            "get_configurations": self._get_configurations,
            "put_values": lambda digest, values: store_do.put_values(
                digest, [_pv_from(v) for v in values]),
            "get_values": lambda digest, experiment_ids=None: [
                _pv_tuple(v) for v in store_do.get_values(digest, experiment_ids)],
            "measured_property_values": lambda space_id, prop,
                experiment_ids=None: [
                    [list(config.values), value] for config, value in
                    store_do.measured_property_values(space_id, prop,
                                                      experiment_ids)],
            "frontier": lambda space_id, properties, modes=None,
                experiment_ids=None: [
                    [list(config.values), list(values)] for config, values in
                    store_do.frontier(space_id, properties, modes,
                                      experiment_ids)],
            "has_values": store_do.has_values,
            "claim_experiment": store_do.claim_experiment,
            "release_claim": store_do.release_claim,
            "steal_claim": store_do.steal_claim,
            "claim_exists": store_do.claim_exists,
            "sweep_stale_claims": lambda grace_s=0.0:
                store_do.sweep_stale_claims(grace_s=grace_s),
            "renew_lease": store_do.renew_lease,
            "release_claims_owned_by": store_do.release_claims_owned_by,
            "enqueue_work": store_do.enqueue_work,
            "claim_work_batch": store_do.claim_work_batch,
            "finish_work_batch": lambda outcomes, owner=None:
                store_do.finish_work_batch(
                    [tuple(o) for o in outcomes], owner=owner),
            "fetch_work_results": lambda item_ids: {
                item_id: list(outcome) for item_id, outcome in
                store_do.fetch_work_results(item_ids).items()},
            "requeue_stale_work": lambda grace_s=0.0:
                store_do.requeue_stale_work(grace_s=grace_s),
            "pending_work": store_do.pending_work,
            "work_queue_stats": store_do.work_queue_stats,
            "next_seq": store_do.next_seq,
            "append_record": lambda *args: _record_tuple(
                store_do.append_record(*args)),
            "append_records": lambda space_id, operation_id, events: [
                _record_tuple(r) for r in store_do.append_records(
                    space_id, operation_id, [tuple(e) for e in events])],
            "records_for": lambda *args: [
                _record_tuple(r) for r in store_do.records_for(*args)],
            "records_since": lambda *args: [
                _record_tuple(r) for r in store_do.records_since(*args)],
            "last_record_rowid": store_do.last_record_rowid,
            "has_record": store_do.has_record,
            "sampled_digests": store_do.sampled_digests,
            "count_measured": store_do.count_measured,
            # failure provenance (actuation lifecycle): rows are plain dicts
            # already, so they cross the wire unchanged
            "record_failure": store_do.record_failure,
            "failures_for": store_do.failures_for,
            "failure_summary": store_do.failure_summary,
        }

    def _get_configuration(self, digest: str):
        config = self.store.get_configuration(digest)
        return None if config is None else list(config.values)

    def _get_configurations(self, digests):
        return {digest: list(config.values) for digest, config in
                self.store.get_configurations(digests).items()}

    # -- serving -------------------------------------------------------------

    def start(self) -> "StoreServer":
        """Serve on a daemon thread; returns self (for in-process tests)."""
        self._accept_thread = threading.Thread(
            target=self.serve_forever, name="store-server-accept", daemon=True)
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        while not self._shutdown.is_set():
            try:
                conn, _addr = self._sock.accept()
            except OSError:
                break  # listener closed by shutdown()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1) \
                if conn.family == socket.AF_INET else None
            with self._lock:
                self._conns.add(conn)
            threading.Thread(target=self._serve_connection, args=(conn,),
                             name="store-server-conn", daemon=True).start()

    def _serve_connection(self, conn: socket.socket) -> None:
        try:
            while True:
                frame = recv_frame(conn)
                if frame is None:
                    return  # client hung up cleanly
                request, codec = frame
                req_id, method, args = request
                handler = self._handlers.get(method)
                if handler is None:
                    response = [req_id, False,
                                ["UnknownMethod", f"no such method: {method}"]]
                else:
                    try:
                        response = [req_id, True, handler(*args)]
                    except Exception as err:  # ship the failure, keep serving
                        response = [req_id, False,
                                    [type(err).__name__, str(err)]]
                send_frame(conn, response, codec)
        except (FrameError, ConnectionError, OSError):
            pass  # client died mid-frame; its leases expire on their own
        finally:
            with self._lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def shutdown(self) -> None:
        self._shutdown.set()
        try:
            # closing alone does not wake a thread blocked in accept();
            # shutdown() does, making the accept loop observe the flag
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
        if self._accept_thread is not None and self._accept_thread.is_alive():
            self._accept_thread.join(timeout=5.0)
        self.store.close()


def main(argv: Optional[list] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.core.store.server",
        description="Serve a SampleStore database to many investigations/"
                    "workers over a socket (paper §III-D, served).")
    parser.add_argument("--db", required=True,
                        help="SQLite database path the server owns"
                             " (created if absent)")
    parser.add_argument("--host", default="127.0.0.1",
                        help="TCP bind address (default 127.0.0.1)")
    parser.add_argument("--port", type=int, default=0,
                        help="TCP port; 0 picks an ephemeral port"
                             " (printed on stdout)")
    parser.add_argument("--unix", default=None, metavar="PATH",
                        help="serve on a unix-domain socket at PATH instead"
                             " of TCP")
    args = parser.parse_args(argv)

    store = SampleStore(args.db)
    server = StoreServer(store, host=args.host, port=args.port,
                         unix_path=args.unix)
    # machine-parseable first line: launchers (and the conformance tests)
    # read the URL from here, then pass it to workers as --store
    print(f"STORE_URL={server.url}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
