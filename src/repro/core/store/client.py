"""``ClientStore``: the served store's client-side ``StoreBackend``.

Connects to a ``python -m repro.core.store.server`` process over the
length-prefixed frame protocol (:mod:`repro.core.store.protocol`) and
implements every store primitive as a request/response round-trip, so all
code above the interface — Discovery Spaces, execution backends, campaign
sync, the Investigation API — runs unmodified against a store it cannot
open as a file.

* ``path`` is the server URL (``tcp://host:port`` / ``unix:///sock``), so
  :attr:`~repro.core.execution.base.ExecutionContext.store_path` hands
  child worker processes exactly what they need to open their own handle
  via :func:`repro.core.store.open_store`.
* **one socket per thread** (mirroring the SQLite backend's per-thread
  connections): worker threads never interleave frames, and the server
  answers each connection strictly in order — the invariant that makes
  :meth:`_call_many` pipelining sound (N frames written back-to-back, N
  responses read back; one network round-trip for the batch).
* **reconnect with backoff**: a dropped connection (server restart, network
  blip) is retried transparently.  Mutating retries are safe for the same
  reason the store's own API is: writes are idempotent (content-addressed
  configuration interning, guarded UPDATEs) or at worst conservative —
  a ``claim_experiment`` whose first attempt won but whose response was
  lost returns False on retry (the claim exists), and the claimant then
  waits on its own claim until lease expiry recovers it; measure-once is
  never violated.
* the immutable-configuration read cache (from
  :class:`~repro.core.store.base.StoreBackend`) short-circuits repeat
  ``get_configuration`` calls entirely — at campaign scale most foreign-tell
  config lookups never touch the network.
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Iterable, Mapping, Optional, Sequence

from ..clock import Clock, SYSTEM_CLOCK
from ..entities import Configuration, PropertyValue
from .base import (DEFAULT_LEASE_S, RecordEntry, StoreBackend,
                   config_from_pairs)
from .protocol import DEFAULT_CODEC, FrameError, recv_frame, send_frame

__all__ = ["ClientStore", "StoreRemoteError", "parse_store_url"]


class StoreRemoteError(RuntimeError):
    """The server reported an exception while executing a request."""

    def __init__(self, exc_type: str, message: str):
        super().__init__(f"{exc_type}: {message}")
        self.exc_type = exc_type


def parse_store_url(url: str):
    """``tcp://host:port`` → ('tcp', (host, port)); ``unix://path`` →
    ('unix', path).  Raises ValueError on anything else."""
    if url.startswith("tcp://"):
        hostport = url[len("tcp://"):]
        host, _, port = hostport.rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"bad tcp store url: {url!r}")
        return "tcp", (host, int(port))
    if url.startswith("unix://"):
        path = url[len("unix://"):]
        if not path:
            raise ValueError(f"bad unix store url: {url!r}")
        return "unix", path
    raise ValueError(f"not a store url: {url!r}"
                     " (expected tcp://host:port or unix://path)")


def _pv_tuple(v: PropertyValue) -> tuple:
    return (v.name, v.value, v.experiment_id, v.predicted, v.timestamp)


def _pv_from(t) -> PropertyValue:
    name, value, experiment_id, predicted, timestamp = t
    return PropertyValue(name=name, value=float(value),
                         experiment_id=experiment_id,
                         predicted=bool(predicted), timestamp=timestamp)


def _record_from(t) -> RecordEntry:
    space_id, operation_id, seq, config_digest, action, created_at, rowid = t
    return RecordEntry(space_id, operation_id, int(seq), config_digest,
                       action, float(created_at), rowid=int(rowid))


class ClientStore(StoreBackend):
    """Store backend that talks to a ``repro.core.store.server`` process."""

    def __init__(self, url: str, clock: Optional[Clock] = None,
                 connect_timeout_s: float = 10.0, retries: int = 5,
                 codec: bytes = DEFAULT_CODEC):
        self.path = url  # the URL is the identity children reopen with
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        self._kind, self._addr = parse_store_url(url)
        self._connect_timeout_s = connect_timeout_s
        self._retries = max(1, int(retries))
        self._codec = codec
        self._local = threading.local()
        self._socks_lock = threading.Lock()
        self._socks: set = set()
        self._closed = False
        self._call("ping")  # fail fast on a wrong/downed URL

    # -- connection management ----------------------------------------------

    def _connect(self) -> socket.socket:
        if self._kind == "tcp":
            sock = socket.create_connection(self._addr,
                                            timeout=self._connect_timeout_s)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        else:
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.settimeout(self._connect_timeout_s)
            sock.connect(self._addr)
        sock.settimeout(None)  # requests block until the server answers
        return sock

    def _sock(self) -> socket.socket:
        sock = getattr(self._local, "sock", None)
        if sock is None:
            if self._closed:
                raise ConnectionError("store client is closed")
            sock = self._connect()
            self._local.sock = sock
            with self._socks_lock:
                self._socks.add(sock)
        return sock

    def _drop_sock(self) -> None:
        sock = getattr(self._local, "sock", None)
        if sock is not None:
            with self._socks_lock:
                self._socks.discard(sock)
            try:
                sock.close()
            except OSError:
                pass
            self._local.sock = None

    def _next_req_id(self) -> int:
        req_id = getattr(self._local, "req_id", 0) + 1
        self._local.req_id = req_id
        return req_id

    # -- request plumbing ----------------------------------------------------

    def _call(self, method: str, *args):
        """One request/response round-trip (with reconnect retries)."""
        return self._call_many([(method, list(args))])[0]

    def _call_many(self, calls: Sequence) -> list:
        """Pipeline: write every request frame, then read every response.

        One network round-trip for the whole batch — the mechanism behind
        the served backend's batched write paths staying near the
        in-process store's throughput.  Responses arrive in request order
        (per-connection ordering is a server guarantee); ``req_id`` echoes
        are still verified defensively.
        """
        if not calls:
            return []
        last_err: Optional[Exception] = None
        for attempt in range(self._retries):
            if attempt:
                self._drop_sock()
                # capped backoff so a restarting server is rejoined quickly
                # but a dead one isn't hammered
                time.sleep(min(0.05 * (2 ** (attempt - 1)), 1.0))
            try:
                sock = self._sock()
                expected = []
                for method, args in calls:
                    req_id = self._next_req_id()
                    expected.append(req_id)
                    send_frame(sock, [req_id, method, list(args)],
                               self._codec)
                results = []
                for req_id in expected:
                    frame = recv_frame(sock)
                    if frame is None:
                        raise FrameError("server closed connection")
                    response, _codec = frame
                    got_id, ok, payload = response
                    if got_id != req_id:
                        raise FrameError(
                            f"response out of order ({got_id} != {req_id})")
                    if not ok:
                        exc_type, message = payload
                        raise StoreRemoteError(exc_type, message)
                    results.append(payload)
                return results
            except StoreRemoteError:
                raise  # the server is healthy; the request itself failed
            except (ConnectionError, FrameError, OSError) as err:
                last_err = err
        self._drop_sock()
        raise ConnectionError(
            f"store server unreachable at {self.path}"
            f" after {self._retries} attempts: {last_err}")

    # -- primitives over the wire --------------------------------------------

    def register_space(self, space_id: str, space_json: Mapping,
                       action_ids: Sequence[str], space_digest: str = "",
                       meta: Optional[Mapping] = None) -> None:
        self._call("register_space", space_id, dict(space_json),
                   list(action_ids), space_digest, meta)

    def list_spaces(self) -> list:
        return self._call("list_spaces")

    def space_stats(self) -> dict:
        return self._call("space_stats")

    def register_operation(self, operation_id: str, space_id: str, kind: str,
                           meta: Optional[Mapping] = None) -> None:
        self._call("register_operation", operation_id, space_id, kind, meta)

    def operations_for(self, space_id: str) -> list:
        return self._call("operations_for", space_id)

    def put_configuration(self, config: Configuration) -> str:
        digest = self._call("put_configuration", list(config.values))
        self._config_put(digest, config)
        return digest

    def put_configurations(self, configs: Sequence[Configuration]) -> list:
        configs = list(configs)
        if not configs:
            return []
        digests = self._call("put_configurations",
                             [list(c.values) for c in configs])
        for digest, config in zip(digests, configs):
            self._config_put(digest, config)
        return digests

    def get_configuration(self, digest: str) -> Optional[Configuration]:
        cached = self._config_get(digest)
        if cached is not None:
            return cached
        pairs = self._call("get_configuration", digest)
        if pairs is None:
            return None
        config = config_from_pairs(pairs)
        self._config_put(digest, config)
        return config

    def get_configurations(self, digests: Sequence[str]) -> dict:
        out: dict = {}
        misses = []
        for digest in digests:
            cached = self._config_get(digest)
            if cached is not None:
                out[digest] = cached
            else:
                misses.append(digest)
        if misses:
            for digest, pairs in self._call("get_configurations",
                                            misses).items():
                config = config_from_pairs(pairs)
                self._config_put(digest, config)
                out[digest] = config
        return out

    def put_values(self, config_digest: str,
                   values: Iterable[PropertyValue]) -> None:
        self._call("put_values", config_digest,
                   [_pv_tuple(v) for v in values])

    def get_values(self, config_digest: str,
                   experiment_ids: Optional[Sequence[str]] = None) -> list:
        rows = self._call("get_values", config_digest,
                          list(experiment_ids)
                          if experiment_ids is not None else None)
        return [_pv_from(r) for r in rows]

    def measured_property_values(self, space_id: str, prop: str,
                                 experiment_ids: Optional[Sequence[str]] = None
                                 ) -> list:
        rows = self._call("measured_property_values", space_id, prop,
                          list(experiment_ids)
                          if experiment_ids is not None else None)
        return [(config_from_pairs(pairs), float(value))
                for pairs, value in rows]

    def frontier(self, space_id: str, properties: Sequence[str],
                 modes: Optional[Sequence[str]] = None,
                 experiment_ids: Optional[Sequence[str]] = None) -> list:
        rows = self._call("frontier", space_id, list(properties),
                          list(modes) if modes is not None else None,
                          list(experiment_ids)
                          if experiment_ids is not None else None)
        return [(config_from_pairs(pairs), tuple(float(v) for v in values))
                for pairs, values in rows]

    def has_values(self, config_digest: str, experiment_id: str) -> bool:
        return bool(self._call("has_values", config_digest, experiment_id))

    def _poll_cell(self, config_digest: str, experiment_id: str):
        # one round-trip per wait_for_values poll instead of two
        has, claimed = self._call_many([
            ("has_values", [config_digest, experiment_id]),
            ("claim_exists", [config_digest, experiment_id]),
        ])
        return bool(has), bool(claimed)

    def claim_experiment(self, config_digest: str, experiment_id: str,
                         owner: str = "",
                         lease_s: Optional[float] = None) -> bool:
        return bool(self._call("claim_experiment", config_digest,
                               experiment_id, owner, lease_s))

    def release_claim(self, config_digest: str, experiment_id: str) -> None:
        self._call("release_claim", config_digest, experiment_id)

    def steal_claim(self, config_digest: str, experiment_id: str,
                    owner: str, older_than_s: float) -> bool:
        return bool(self._call("steal_claim", config_digest, experiment_id,
                               owner, older_than_s))

    def claim_exists(self, config_digest: str, experiment_id: str) -> bool:
        return bool(self._call("claim_exists", config_digest, experiment_id))

    def sweep_stale_claims(self, *, grace_s: float = 0.0) -> int:
        return int(self._call("sweep_stale_claims", grace_s))

    def renew_lease(self, owner: str, lease_s: float,
                    max_age_s: Optional[float] = None) -> int:
        return int(self._call("renew_lease", owner, lease_s, max_age_s))

    def release_claims_owned_by(self, owner: str) -> int:
        return int(self._call("release_claims_owned_by", owner))

    def enqueue_work(self, space_id: str, config_digest: str,
                     priority: float = 0.0) -> str:
        return self._call("enqueue_work", space_id, config_digest, priority)

    def claim_work_batch(self, owner: str, limit: int = 1,
                         space_id: Optional[str] = None,
                         lease_s: float = DEFAULT_LEASE_S) -> list:
        return self._call("claim_work_batch", owner, limit, space_id, lease_s)

    def finish_work_batch(self, outcomes: Sequence[Sequence],
                          owner: Optional[str] = None) -> int:
        return int(self._call("finish_work_batch",
                              [list(o) for o in outcomes], owner))

    def fetch_work_results(self, item_ids: Sequence[str]) -> dict:
        results = self._call("fetch_work_results", list(item_ids))
        return {item_id: tuple(outcome)
                for item_id, outcome in results.items()}

    def requeue_stale_work(self, *, grace_s: float = 0.0) -> int:
        return int(self._call("requeue_stale_work", grace_s))

    def pending_work(self, space_id: Optional[str] = None) -> int:
        return int(self._call("pending_work", space_id))

    def work_queue_stats(self, space_id: Optional[str] = None,
                         latency_window: int = 20) -> dict:
        return self._call("work_queue_stats", space_id, latency_window)

    def next_seq(self, space_id: str, operation_id: str) -> int:
        return int(self._call("next_seq", space_id, operation_id))

    def append_record(self, space_id: str, operation_id: str,
                      config_digest: str, action: str) -> RecordEntry:
        return _record_from(self._call("append_record", space_id,
                                       operation_id, config_digest, action))

    def append_records(self, space_id: str, operation_id: str,
                       events: Sequence[Sequence[str]]) -> list:
        rows = self._call("append_records", space_id, operation_id,
                          [list(e) for e in events])
        return [_record_from(r) for r in rows]

    def records_for(self, space_id: str,
                    operation_id: Optional[str] = None) -> list:
        return [_record_from(r)
                for r in self._call("records_for", space_id, operation_id)]

    def records_since(self, space_id: str, after_rowid: int = 0,
                      limit: Optional[int] = None,
                      exclude_operation: Optional[str] = None,
                      upto_rowid: Optional[int] = None) -> list:
        rows = self._call("records_since", space_id, after_rowid, limit,
                          exclude_operation, upto_rowid)
        return [_record_from(r) for r in rows]

    def last_record_rowid(self, space_id: str) -> int:
        return int(self._call("last_record_rowid", space_id))

    def has_record(self, space_id: str, config_digest: str,
                   include_failed: bool = False) -> bool:
        return bool(self._call("has_record", space_id, config_digest,
                               include_failed))

    def sampled_digests(self, space_id: str,
                        include_failed: bool = False) -> list:
        return self._call("sampled_digests", space_id, include_failed)

    def count_measured(self, space_id: Optional[str] = None) -> int:
        return int(self._call("count_measured", space_id))

    def record_failure(self, config_digest: str, experiment_id: str,
                       phase: str, reason: str, attempts: int = 1,
                       cost: float = 0.0) -> None:
        self._call("record_failure", config_digest, experiment_id, phase,
                   reason, attempts, cost)

    def failures_for(self, config_digest: str,
                     experiment_id: Optional[str] = None) -> list:
        return [dict(r) for r in self._call("failures_for", config_digest,
                                            experiment_id)]

    def failure_summary(self, space_id: str) -> dict:
        return {phase: dict(stats) for phase, stats
                in self._call("failure_summary", space_id).items()}

    def close(self) -> None:
        self._closed = True
        with self._socks_lock:
            socks = list(self._socks)
            self._socks.clear()
        for sock in socks:
            try:
                sock.close()
            except OSError:
                pass
        self._local = threading.local()
