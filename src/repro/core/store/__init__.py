"""The shared sample store (paper §III-C3/§III-D), as a pluggable package.

* :class:`~repro.core.store.base.StoreBackend` — the interface everything
  above the store programs against.
* :class:`~repro.core.store.sqlite.SampleStore` — the SQLite-WAL reference
  backend (in-process; multi-process via a shared database file).
* :class:`~repro.core.store.client.ClientStore` — the served backend's
  client; pair with ``python -m repro.core.store.server``.
* :func:`open_store` — the one factory every entry point uses: a plain
  path opens SQLite, a ``tcp://``/``unix://`` URL connects to a server.

Importing :class:`SampleStore` from ``repro.core.store`` keeps working
exactly as it did when the store was a single module.
"""

from __future__ import annotations

from typing import Optional

from ..clock import Clock
from .base import (DEFAULT_LEASE_S, RecordEntry, StoreBackend,
                   config_from_pairs)
from .sqlite import SampleStore

__all__ = ["SampleStore", "StoreBackend", "RecordEntry", "DEFAULT_LEASE_S",
           "open_store", "config_from_pairs"]


def open_store(path: str, clock: Optional[Clock] = None) -> StoreBackend:
    """Open a store by identity string — the universal front door.

    ``tcp://host:port`` / ``unix:///path.sock`` connect a
    :class:`~repro.core.store.client.ClientStore` to a running
    ``python -m repro.core.store.server``; anything else (including
    ``:memory:``) opens the SQLite reference backend on that path.  Worker
    processes reopening ``ExecutionContext.store_path``, the spec CLI's
    ``--store``, and ``InvestigationSpec.store`` all resolve through here,
    so every entry point accepts both backends with no further plumbing.
    """
    if path.startswith(("tcp://", "unix://")):
        from .client import ClientStore  # socket machinery only when served
        return ClientStore(path, clock=clock)
    return SampleStore(path, clock=clock)
